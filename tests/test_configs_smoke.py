"""Per-architecture smoke tests: reduced variant, one forward + one train
step on CPU, asserting output shapes and no NaNs.  (Deliverable f.)"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import meta, transformer as T
from repro.optim import adamw
from repro.train import steps as ST


def _batch(cfg, key, B=2, S=32):
    s_text = S - cfg.num_img_tokens if cfg.num_img_tokens else S
    batch = {
        "tokens": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, s_text), 0, cfg.vocab_size),
    }
    if cfg.num_img_tokens:
        batch["img_embeds"] = jax.random.normal(key, (B, cfg.num_img_tokens, 1024)) * 0.1
    if cfg.is_encdec:
        batch["audio_frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512 and cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = meta.init_params(cfg, key)
    batch = _batch(cfg, key)
    h, aux = T.forward(cfg, params, batch["tokens"],
                       img_embeds=batch.get("img_embeds"),
                       audio_frames=batch.get("audio_frames"))
    B, S = batch["tokens"].shape
    S_tot = S + (cfg.num_img_tokens or 0)
    assert h.shape == (B, S_tot, cfg.d_model)
    logits = T.lm_logits(cfg, params, h)
    assert logits.shape == (B, S_tot, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    cls = T.classify(cfg, params, h)
    assert cls.shape == (B, cfg.num_query_classes)
    assert bool(jnp.all(jnp.isfinite(cls)))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = meta.init_params(cfg, key)
    state = ST.TrainState(params, adamw.init(params), jnp.zeros((), jnp.int32))
    step_fn = ST.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3), remat=True)
    batch = _batch(cfg, key)
    new_state, metrics = jax.jit(step_fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    assert int(new_state.step) == 1
    # params actually moved
    moved = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         new_state.params, params)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_microbatched_train_matches_shape(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = meta.init_params(cfg, key)
    state = ST.TrainState(params, adamw.init(params), jnp.zeros((), jnp.int32))
    step_fn = ST.make_train_step(cfg, adamw.AdamWConfig(lr=1e-3),
                                 remat=True, microbatches=2)
    batch = _batch(cfg, key, B=4)
    _, metrics = jax.jit(step_fn)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
