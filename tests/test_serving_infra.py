"""Bus/ParamDB, workload construction, fine-tune improvement, simulator
conservation invariants."""
import numpy as np
import pytest

from repro.serving.bus import Bus, ParamDB
from repro.serving.simulator import CloudEdgeSim, Item, LinkSpec, NodeSpec


def test_bus_topic_matching_and_wildcards():
    bus = Bus()
    got = []
    bus.subscribe("params/#", lambda t, p: got.append((t, p)))
    bus.subscribe("tasks/edge1", lambda t, p: got.append((t, p)))
    bus.publish("params/alpha", 0.8)
    bus.publish("tasks/edge1", "img")
    bus.publish("tasks/edge2", "img")        # no subscriber
    assert got == [("params/alpha", 0.8), ("tasks/edge1", "img")]
    assert bus.delivered == 2


def test_paramdb_replicates_on_write():
    bus = Bus()
    db = ParamDB(bus)
    seen = {}
    bus.subscribe("params/#", lambda t, p: seen.update({t: p}))
    db.put("t1", 0.25)
    db.put("Q1", 3)
    assert db.get("t1") == 0.25
    assert seen == {"params/t1": 0.25, "params/Q1": 3}
    assert db.writes == 2


def _items(n=50, seed=0):
    rng = np.random.default_rng(seed)
    return [Item(t_arrival=float(t), camera=int(t) % 4,
                 edge_device=int(t) % 2 + 1,
                 conf=float(rng.uniform()), is_query=bool(rng.random() < 0.2))
            for t in np.sort(rng.uniform(0, 30, n))]


@pytest.mark.parametrize("scheme", ["surveiledge", "surveiledge_fixed",
                                    "edge_only", "cloud_only"])
def test_simulator_conservation(scheme):
    items = _items()
    sim = CloudEdgeSim([NodeSpec(1, 0.2), NodeSpec(2, 0.2)], NodeSpec(0, 0.05),
                       LinkSpec(uplink_MBps=1.0), scheme=scheme, seed=0)
    r = sim.run(items)
    assert len(r.latencies) == len(items)            # every item answered once
    assert np.all(r.latencies > 0)
    if scheme == "edge_only":
        assert r.uploaded_bytes == 0
    if scheme == "cloud_only":
        assert r.uploaded_bytes == sum(i.nbytes for i in items)
        assert np.array_equal(r.decisions, r.truths)  # cloud == ground truth


def test_simulator_latency_grows_with_load():
    fast = [Item(i.t_arrival, i.camera, 1, i.conf, i.is_query)
            for i in _items(30, seed=1)]
    slow_edges = [NodeSpec(1, 2.0)]
    sim = CloudEdgeSim(slow_edges, NodeSpec(0, 0.05), LinkSpec(), scheme="edge_only", seed=0)
    r_slow = sim.run(fast)
    sim2 = CloudEdgeSim([NodeSpec(1, 0.05)], NodeSpec(0, 0.05), LinkSpec(),
                        scheme="edge_only", seed=0)
    r_fast = sim2.run(fast)
    assert r_slow.avg_latency > r_fast.avg_latency


def test_wan_uplink_serializes():
    """Uploads must queue on the shared link: cloud-only latency grows with
    item size under a thin uplink."""
    items = _items(40, seed=2)
    def run(nbytes):
        its = [Item(i.t_arrival, i.camera, i.edge_device, i.conf,
                    i.is_query, nbytes=nbytes) for i in items]
        sim = CloudEdgeSim([NodeSpec(1, 0.1)], NodeSpec(0, 0.05),
                           LinkSpec(uplink_MBps=0.2), scheme="cloud_only", seed=0)
        return sim.run(its).avg_latency
    assert run(400_000) > run(4_000) * 2
