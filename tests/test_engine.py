"""Continuous-batching decode engine + cascade server correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.speculative import cloud_greedy_generate
from repro.core.thresholds import ThresholdState
from repro.models import meta
from repro.serving.engine import CascadeServer, DecodeEngine, Request


@pytest.fixture(scope="module")
def models():
    cloud_cfg = get_config("qwen1.5-0.5b").reduced()
    edge_cfg = get_config("qwen1.5-0.5b").edge_variant()
    cloud = meta.init_params(cloud_cfg, jax.random.PRNGKey(0))
    edge = meta.init_params(edge_cfg, jax.random.PRNGKey(1))
    return edge_cfg, edge, cloud_cfg, cloud


def test_engine_matches_isolated_greedy(models):
    """Batched slot decoding == per-request greedy decoding."""
    _, _, cfg, params = models
    S, new = 8, 6
    prompts = [jax.random.randint(jax.random.PRNGKey(i), (S,), 0,
                                  cfg.vocab_size) for i in (2, 3, 4)]
    eng = DecodeEngine(cfg, params, slots=3, cache_len=S + new + 2)
    for i, p in enumerate(prompts):
        assert eng.admit(Request(rid=i, tokens=np.asarray(p), max_new=new))
    outs = {}
    while eng.active:
        for rid, gen in eng.step():
            outs[rid] = np.asarray(gen)
    for i, p in enumerate(prompts):
        want = np.asarray(cloud_greedy_generate(cfg, params, p[None],
                                                steps=new - 1))[0]
        np.testing.assert_array_equal(outs[i], want)


def test_engine_refills_freed_slots(models):
    _, _, cfg, params = models
    S = 8
    eng = DecodeEngine(cfg, params, slots=2, cache_len=64)
    p = np.zeros(S, np.int32)
    assert eng.admit(Request(rid=0, tokens=p, max_new=2))
    assert eng.admit(Request(rid=1, tokens=p, max_new=2))
    assert not eng.admit(Request(rid=2, tokens=p, max_new=2))  # full
    while eng.active:
        eng.step()
    assert eng.admit(Request(rid=2, tokens=p, max_new=2))      # freed


def test_midflight_admission_mixed_lengths(models):
    """A request admitted while others are mid-decode, with a DIFFERENT
    prompt length, still decodes exactly like isolated greedy."""
    _, _, cfg, params = models
    eng = DecodeEngine(cfg, params, slots=2, cache_len=40)
    pA = np.asarray(jax.random.randint(jax.random.PRNGKey(7), (8,), 0,
                                       cfg.vocab_size))
    pB = np.asarray(jax.random.randint(jax.random.PRNGKey(8), (14,), 0,
                                       cfg.vocab_size))
    assert eng.admit(Request(rid=0, tokens=pA, max_new=10))
    eng.step()
    eng.step()              # slot 0 is 2 tokens in...
    assert eng.admit(Request(rid=1, tokens=pB, max_new=5))   # ...admit B now
    outs = {}
    while eng.active:
        for rid, gen in eng.step():
            outs[rid] = np.asarray(gen)
    wantA = np.asarray(cloud_greedy_generate(cfg, params, pA[None], steps=9))[0]
    wantB = np.asarray(cloud_greedy_generate(cfg, params, pB[None], steps=4))[0]
    np.testing.assert_array_equal(outs[0], wantA)
    np.testing.assert_array_equal(outs[1], wantB)


def test_cascade_server_routes_and_serves(models):
    edge_cfg, edge, cloud_cfg, cloud = models
    S = 8
    reqs = [Request(rid=i,
                    tokens=np.asarray(jax.random.randint(
                        jax.random.PRNGKey(10 + i), (S,), 0,
                        cloud_cfg.vocab_size)),
                    max_new=4)
            for i in range(6)]
    # force everything through the cloud (alpha=1 => nothing edge-accepts;
    # beta=0 => nothing edge-rejects)
    srv = CascadeServer(edge_cfg, edge, cloud_cfg, cloud, slots=2,
                        cache_len=S + 8,
                        thresholds=ThresholdState(alpha=1.0, beta=0.0))
    results = srv.run(reqs)
    assert len(results) == 6
    for r in results.values():
        assert r.route == "cloud"
        assert r.output is not None and len(r.output) == 4
    # some requests waited for a later wave (2 slots x 3 waves)
    assert any(r.ticks_waited > 0 for r in results.values())


def test_cascade_server_edge_shortcuts(models):
    edge_cfg, edge, cloud_cfg, cloud = models
    reqs = [Request(rid=i, tokens=np.zeros(8, np.int32), max_new=2)
            for i in range(3)]
    # alpha<beta impossible; instead make the escalation band empty:
    # everything below beta -> edge_reject without touching the cloud
    srv = CascadeServer(edge_cfg, edge, cloud_cfg, cloud, slots=2,
                        cache_len=16,
                        thresholds=ThresholdState(alpha=0.5, beta=0.4999))
    results = srv.run(reqs)
    assert len(results) == 3
    assert all(r.route in ("edge_accept", "edge_reject")
               for r in results.values())
    assert srv.engine.ticks == 0        # cloud never ran
