"""prefill(S-1) + decode(1) must equal forward(S) at the last position."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.models import meta, transformer as T

TOL = {"default": 2e-4}


@pytest.mark.parametrize("arch", ASSIGNED)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        # capacity-based token dropping differs between batch compositions;
        # remove drops so the comparison is exact
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    key = jax.random.PRNGKey(3)
    params = meta.init_params(cfg, key)
    B, S = 2, 24
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    kw = {}
    if cfg.num_img_tokens:
        kw["img_embeds"] = jax.random.normal(key, (B, cfg.num_img_tokens, 1024)) * 0.1
    if cfg.is_encdec:
        kw["audio_frames"] = jax.random.normal(key, (B, cfg.enc_seq, cfg.d_model)) * 0.1
    h, _ = T.forward(cfg, params, tokens, **kw)
    want = T.lm_logits(cfg, params, h)[:, -1]
    _, cache = T.prefill(cfg, params, tokens[:, :-1], cache_len=S + 4, **kw)
    got, _ = T.decode_step(cfg, params, cache, tokens[:, -1])
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 2e-4, err


@pytest.mark.parametrize("arch", ["qwen3-8b", "mamba2-2.7b", "hymba-1.5b"])
def test_multi_step_decode_chain(arch):
    """Decoding T tokens one-by-one equals the full forward logits."""
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(4)
    params = meta.init_params(cfg, key)
    B, S = 2, 16
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    want = T.lm_logits(cfg, params, h)
    _, cache = T.prefill(cfg, params, tokens[:, :4], cache_len=S)
    for i in range(4, S):
        got, cache = T.decode_step(cfg, params, cache, tokens[:, i])
        err = float(jnp.max(jnp.abs(want[:, i] - got)))
        assert err < 5e-4, (i, err)


def test_sliding_window_decode_consistency():
    """With window w, decode must match a forward pass with the same mask."""
    cfg = get_config("qwen3-8b").reduced()
    key = jax.random.PRNGKey(5)
    params = meta.init_params(cfg, key)
    B, S, W = 1, 24, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens, window=W)
    want = T.lm_logits(cfg, params, h)[:, -1]
    _, cache = T.prefill(cfg, params, tokens[:, :-1], cache_len=S, window=W)
    got, _ = T.decode_step(cfg, params, cache, tokens[:, -1], window=W)
    err = float(jnp.max(jnp.abs(want - got)))
    assert err < 2e-4, err


def test_rotating_window_cache():
    """Cache shorter than the sequence: ring-buffer decode still matches the
    windowed forward."""
    cfg = get_config("qwen1.5-0.5b").reduced()
    key = jax.random.PRNGKey(6)
    params = meta.init_params(cfg, key)
    B, S, W = 1, 20, 8
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens, window=W)
    want = T.lm_logits(cfg, params, h)
    # prefill only the first W tokens, then ring-decode the rest
    _, cache = T.prefill(cfg, params, tokens[:, :W], cache_len=W, window=W)
    for i in range(W, S):
        got, cache = T.decode_step(cfg, params, cache, tokens[:, i], window=W)
        err = float(jnp.max(jnp.abs(want[:, i] - got)))
        assert err < 5e-4, (i, err)
