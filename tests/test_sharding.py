"""Sharding rules: divisibility invariants across all archs x modes."""
import numpy as np
import jax
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.distributed import sharding as SH
from repro.models import meta as M


def _fake_mesh(shape=(16, 16), axes=("data", "model")):
    """An abstract mesh for spec computation (no 512 devices needed)."""
    devs = np.asarray(jax.devices() * int(np.prod(shape)))[
        : int(np.prod(shape))].reshape(shape)
    return Mesh(devs, axes)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mode", ["train", "serve"])
def test_param_specs_divisible(arch, mode):
    cfg = get_config(arch)
    mesh = _fake_mesh()
    specs = SH.param_specs(cfg, mesh, mode)
    metas = M.model_meta(cfg)

    def check(pm, spec):
        assert len(spec) <= len(pm.shape)
        used = [a for a in spec if a is not None]
        assert len(used) == len(set(used)), f"axis reused: {spec}"
        for dim, ax in zip(pm.shape, tuple(spec) + (None,) * (len(pm.shape) - len(spec))):
            if ax is None:
                continue
            n = mesh.shape[ax] if isinstance(ax, str) else int(
                np.prod([mesh.shape[a] for a in ax]))
            assert dim % n == 0, (arch, pm.shape, spec)

    jax.tree.map(check, metas, specs,
                 is_leaf=lambda x: isinstance(x, (M.ParamMeta, P)))


@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-moe-1b-a400m", "mamba2-2.7b"])
def test_train_mode_fsdp_shards_embed_dim(arch):
    cfg = get_config(arch)
    mesh = _fake_mesh()
    spec = SH.spec_for_meta(cfg, M.model_meta(cfg)["embed"], mesh, "train")
    assert "data" in spec  # (V, D): D sharded on data in train


def test_batch_spec_divisibility_fallback():
    mesh = _fake_mesh()
    assert SH._batch_spec(mesh, 256) == "data"
    assert SH._batch_spec(mesh, 1) is None
    mesh3 = _fake_mesh((2, 16, 16), ("pod", "data", "model"))
    assert SH._batch_spec(mesh3, 256) == ("pod", "data")
    assert SH._batch_spec(mesh3, 2) == "pod"


def test_moe_experts_on_model_axis():
    cfg = get_config("granite-moe-1b-a400m")
    mesh = _fake_mesh()
    specs = SH.param_specs(cfg, mesh, "train")
    wi_spec = specs["layers"]["moe"]["wi"]
    assert wi_spec[1] == "model"        # (L, E, D, F): experts on model
    assert wi_spec[3] is None           # per-expert mlp unsharded for MoE


def test_nondivisible_heads_replicate():
    cfg = get_config("hymba-1.5b")      # 25 heads % 16 != 0
    mesh = _fake_mesh()
    specs = SH.param_specs(cfg, mesh, "serve")
    wq = specs["layers"]["attn"]["wq"]
    assert "model" not in tuple(wq)     # replicated rather than broken
