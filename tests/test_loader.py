"""Host-sharded data loader: determinism, shapes, host disjointness."""
import numpy as np

from repro.configs import get_config
from repro.data.loader import LoaderConfig, host_batches


def test_loader_shapes_and_determinism():
    cfg = get_config("qwen1.5-0.5b").reduced()
    lc = LoaderConfig(global_batch=8, seq_len=32, seed=5)
    a = next(host_batches(cfg, lc))
    b = next(host_batches(cfg, lc))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    assert a["tokens"].shape == (8, 32)
    assert a["labels"].shape == (8, 32)
    assert a["tokens"].max() < cfg.vocab_size


def test_loader_host_shards_disjoint():
    cfg = get_config("qwen1.5-0.5b").reduced()
    lc = LoaderConfig(global_batch=8, seq_len=32, seed=5)
    h0 = next(host_batches(cfg, lc, host_id=0, num_hosts=2))
    h1 = next(host_batches(cfg, lc, host_id=1, num_hosts=2))
    assert h0["tokens"].shape == (4, 32)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_loader_advances_per_step():
    cfg = get_config("qwen1.5-0.5b").reduced()
    it = host_batches(cfg, LoaderConfig(global_batch=4, seq_len=16))
    s0, s1 = next(it), next(it)
    assert not np.array_equal(s0["tokens"], s1["tokens"])


def test_loader_modality_extras():
    cfg = get_config("internvl2-1b").reduced()
    lc = LoaderConfig(global_batch=4, seq_len=32)
    b = next(host_batches(cfg, lc))
    assert b["tokens"].shape == (4, 32 - cfg.num_img_tokens)
    assert b["img_embeds"].shape == (4, cfg.num_img_tokens, 1024)
    wcfg = get_config("whisper-large-v3").reduced()
    bw = next(host_batches(wcfg, LoaderConfig(global_batch=2, seq_len=16)))
    assert bw["audio_frames"].shape == (2, wcfg.enc_seq, wcfg.d_model)