"""AdamW + schedules + checkpoint roundtrip."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as CK
from repro.optim import adamw, schedules


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray(1.5)}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=100.0)
    state = adamw.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(cfg, g, state, params)
    assert float(loss(params)) < 1e-3


def test_grad_clipping_bounds_update():
    params = {"w": jnp.zeros(4)}
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    state = adamw.init(params)
    g = {"w": jnp.full(4, 1e6)}
    new, state, metrics = adamw.apply(cfg, g, state, params)
    assert float(metrics["grad_norm"]) > 1e5
    assert float(jnp.max(jnp.abs(new["w"]))) < 2.0   # clipped step


def test_weight_decay_only_on_matrices():
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones(2)}
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.5, clip_norm=100.0)
    state = adamw.init(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw.apply(cfg, zero_g, state, params)
    assert float(jnp.max(jnp.abs(new["w"]))) < 1.0   # decayed
    np.testing.assert_allclose(np.asarray(new["b"]), 1.0)  # not decayed


def test_cosine_schedule_shape():
    sched = schedules.cosine_with_warmup(10, 100, floor=0.1)
    vals = [float(sched(jnp.asarray(s))) for s in range(0, 101, 10)]
    assert vals[0] == 0.0
    assert abs(vals[1] - 1.0) < 1e-6        # end of warmup
    assert vals[-1] <= vals[1]
    assert min(vals[1:]) >= 0.1 - 1e-6


def test_checkpoint_roundtrip():
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "n": {"b": jnp.asarray([1, 2], jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ck.npz")
        CK.save(path, tree, step=7)
        like = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)
        back = CK.restore(path, like)
        assert CK.latest_step(path) == 7
        for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
