"""Streaming windowed report aggregates (``metrics.StreamingWindows``):
equivalence against the exact per-item array path on a real run, plus
the cell-level edge cases — empty report, single-sample windows,
histogram under/overflow, a window wider than the whole run, and a
query retiring mid-window."""
import dataclasses

import numpy as np
import pytest

from repro.system import QueryReport, StreamingWindows, multi_query_city, \
    run_query
from repro.system.metrics import _Acc, merge_timelines

_WINDOW = 5.0


@pytest.fixture(scope="module")
def paired_reports():
    """The same deterministic run accumulated both ways: exact per-item
    arrays vs streaming windowed cells of width ``_WINDOW``."""
    base = multi_query_city(duration_s=30.0)
    exact = run_query(base)
    stream = run_query(dataclasses.replace(base, metrics_window_s=_WINDOW))
    return exact, stream


# --- equivalence against the array path ---------------------------------------


def test_stream_run_drops_per_item_arrays(paired_reports):
    exact, stream = paired_reports
    assert stream.stream is not None and exact.stream is None
    assert len(stream.latencies) == 0
    assert stream.n_items == exact.n_items == len(exact.latencies) > 0


def test_stream_f2_exact(paired_reports):
    """F2 reduces to confusion counts on both paths (one shared
    ``f_score_counts``), so it must agree exactly — not approximately."""
    exact, stream = paired_reports
    assert stream.f_score(2.0) == exact.f_score(2.0)
    assert stream.summary()["accuracy_F2"] == exact.summary()["accuracy_F2"]


def test_stream_latency_moments_match(paired_reports):
    exact, stream = paired_reports
    np.testing.assert_allclose(stream.avg_latency, exact.avg_latency,
                               rtol=1e-9)
    np.testing.assert_allclose(stream.latency_var, exact.latency_var,
                               rtol=1e-9)


def test_stream_p99_within_one_log_bucket(paired_reports):
    """The histogram read-out returns a bucket's upper edge clamped to
    the observed max: never below the sorted-array percentile's floor
    order stat's bucket, and at most one bucket width (~12%) above."""
    exact, stream = paired_reports
    assert stream.p99_latency <= exact.latencies.max()
    np.testing.assert_allclose(stream.p99_latency, exact.p99_latency,
                               rtol=0.15)


def test_stream_timeline_rows_exact(paired_reports):
    """Window rows carry counts and count-derived F2 — both exact, so
    the streaming timeline must equal the array path binned at the same
    width (including the omission of empty windows)."""
    exact, stream = paired_reports
    assert stream.accuracy_timeline() == exact.accuracy_timeline(
        window_s=_WINDOW)


def test_stream_per_query_rows_match(paired_reports):
    exact, stream = paired_reports
    pe, ps = exact.per_query_summary(), stream.per_query_summary()
    assert set(pe) == set(ps)
    for q in pe:
        assert ps[q]["n_items"] == pe[q]["n_items"]
        assert ps[q]["f2"] == pe[q]["f2"]
        # lifecycle facts come from the pipeline, not the accumulator
        assert ps[q]["train_scheme"] == pe[q]["train_scheme"]
        assert ps[q]["t_retire_s"] == pe[q]["t_retire_s"]


def test_stream_query_retiring_mid_window(paired_reports):
    """q1 retires at 85% of the run — mid-window for any 5 s binning.
    Its cell must stop growing at retirement yet keep its full history:
    the per-query row still reports every item it ever finished."""
    _, stream = paired_reports
    row = stream.per_query_summary()[1]
    assert row["t_retire_s"] is not None
    assert row["n_items"] == stream.stream.queries[1].n > 0
    # items from a retired query stay inside the total too
    assert stream.n_items == sum(c.n for c in stream.stream.queries.values())


# --- cell-level edge cases ----------------------------------------------------


def _report(**kw):
    z = np.zeros(0)
    zb = np.zeros(0, bool)
    base = dict(scenario="t", scheme="surveiledge", latencies=z,
                decisions=zb, truths=zb, finish_times=z, uploaded_bytes=0,
                lan_bytes=0, escalated=0, rerouted=0, kernel_launches=0,
                ticks=0, queue_timeline={}, per_node_busy={},
                per_node_served={})
    base.update(kw)
    return QueryReport(**base)


def test_empty_streaming_report_is_all_zero():
    r = _report(stream=StreamingWindows(_WINDOW))
    assert r.n_items == 0
    assert r.f_score() == 0.0
    assert r.avg_latency == 0.0 and r.p99_latency == 0.0
    assert r.latency_var == 0.0
    assert r.accuracy_timeline() == []
    assert r.per_query_summary() == {}


def test_empty_array_report_timeline_is_empty():
    assert _report().accuracy_timeline() == []


def test_window_wider_than_run_collapses_to_one_row():
    sw = StreamingWindows(1e6)
    for t, lat in ((0.5, 0.1), (40.0, 0.2), (99.0, 0.3)):
        sw.add(t, lat, True, True, query=0)
    rows = sw.timeline()
    assert rows == [{"t_start": 0.0, "n": 3, "f2": 1.0}]


def test_single_sample_window_p99_is_exact():
    a = _Acc()
    a.add(0.37, True, True)
    assert a.percentile(0.99) == 0.37
    assert a.mean == 0.37 and a.var == 0.0


def test_histogram_under_and_overflow_clamp_to_observed():
    lo, hi = _Acc(), _Acc()
    lo.add(1e-6, True, True)          # below the 1e-4 histogram floor
    hi.add(12345.0, True, True)       # above the 1e4 ceiling
    assert lo.percentile(0.99) == 1e-6
    assert hi.percentile(0.99) == 12345.0


def test_streaming_windows_rejects_nonpositive_width():
    with pytest.raises(ValueError, match="window_s"):
        StreamingWindows(0.0)


def test_merge_timelines():
    samples = [{0: 1, 7: 2}, {0: 3, 7: 0}, {0: 0, 7: 5}]
    out = merge_timelines(samples)
    assert set(out) == {0, 7}
    np.testing.assert_array_equal(out[0], [1, 3, 0])
    np.testing.assert_array_equal(out[7], [2, 0, 5])
    assert merge_timelines([]) == {}
