"""Dual-mode control plane: driver differential, admission, alerts.

The tentpole invariant: ``AsyncDriver`` in virtual time pops the SAME
event heap in the SAME order as the DES ``SimDriver`` — every decision,
metric, and byte counter bit-identical — so the control-plane features
(admission, tiers, alerts) are tested once and served unchanged.
"""
import asyncio
import time

import numpy as np
import pytest

from repro.serving.api import (
    AdmissionController,
    QueryAPI,
    TenantSpec,
    TokenBucket,
)
from repro.serving.bus import Bus
from repro.serving.engine import AsyncDriver, VirtualClock, WallClock
from repro.system import QueryPipeline, QuerySpec, run_query
from repro.system.scenario import (
    Scenario,
    city_scale,
    rush_hour,
    straggler_edge,
    synthetic_confidence_stream,
)


def _reports_identical(a, b):
    assert a.summary() == b.summary()
    np.testing.assert_array_equal(a.latencies, b.latencies)
    np.testing.assert_array_equal(a.decisions, b.decisions)
    np.testing.assert_array_equal(a.truths, b.truths)
    np.testing.assert_array_equal(a.finish_times, b.finish_times)
    assert a.alerts == b.alerts
    assert a.tier_latency == b.tier_latency
    assert a.queries == b.queries


# --- the tentpole differential: async(virtual) == sim -------------------------
@pytest.mark.asyncio
def test_async_driver_bit_exact_city_scale():
    sc = city_scale(duration_s=6.0, num_failures=2, interval_s=0.25)
    _reports_identical(run_query(sc),
                       run_query(sc, driver=AsyncDriver(VirtualClock())))


@pytest.mark.asyncio
def test_async_driver_bit_exact_rush_hour():
    """The full control plane (admission, tiers, alerts) under both
    drivers: sheds, breach counts, and alert streams all identical."""
    sc = rush_hour(duration_s=40.0, num_cameras=4)
    a = run_query(sc)
    b = run_query(sc, driver=AsyncDriver(VirtualClock()))
    _reports_identical(a, b)
    assert a.shed_queries > 0 and a.alerts      # the differential is
    #                                             vacuous on a quiet run


# --- admission unit tests -----------------------------------------------------
def test_token_bucket_refills_on_simulated_clock():
    tb = TokenBucket(rate=0.5, burst=2)
    assert tb.take(0.0) and tb.take(0.0)        # burst spent
    assert not tb.take(1.0)                     # only 0.5 refilled
    assert tb.take(2.0)                         # 1 token back
    assert not tb.take(2.0)


def test_admission_quota_exhaustion_order():
    """Quota is charged before backlog: a flooding tenant burns its own
    bucket even when the cloud is idle."""
    adm = AdmissionController((TenantSpec("a", rate=0.01, burst=1),),
                              backlog_limit_s=10.0)
    assert adm.admit(0.0, "a", 1, backlog_s=0.0) is None
    assert adm.admit(0.1, "a", 1, backlog_s=0.0) == "quota"
    assert adm.shed == {"quota": 1} and adm.admitted == 1


def test_admission_sheds_bottom_tier_first():
    """Tier allowances halve per tier: a backlog between the tier-1 and
    tier-2 lines sheds tier 2, admits tier 1, and tier 0 is exempt."""
    adm = AdmissionController(backlog_limit_s=8.0)
    backlog = 6.0                               # tier1 allows 8, tier2: 4
    assert adm.admit(0.0, "", 0, backlog) is None
    assert adm.admit(0.0, "", 1, backlog) is None
    assert adm.admit(0.0, "", 2, backlog) == "backlog"
    assert adm.admit(0.0, "", 0, backlog_s=1e9) is None   # tier 0 exempt


def test_rush_hour_admission_end_to_end():
    """The acceptance row: zero top-tier SLO breaches while lower tiers
    shed, with the sheds visible on the alert stream."""
    r = run_query(rush_hour(duration_s=40.0, num_cameras=4))
    s = r.summary()
    assert s["slo_breach_top_tier"] == 0
    assert s["shed_rate"] > 0
    assert s["shed_queries"] == r.alerts.get("quota", 0) \
        + r.alerts.get("backlog", 0)
    assert r.alerts.get("failover", 0) >= 1     # the mid-rush edge death
    assert r.shed_items > 0                     # shed queries' items drop
    # lower tiers actually felt the rush (no vacuous victory for tier 0)
    assert s["slo_breach_tier1"] > 0


def test_failover_alert_emitted():
    sc = straggler_edge(duration_s=10.0)
    assert sc.failures                          # preset kills an edge
    r = run_query(sc)
    assert r.alerts.get("failover", 0) == len(sc.failures)


def test_scenario_rejects_control_plane_with_superstep():
    import dataclasses
    with pytest.raises(ValueError, match="superstep"):
        dataclasses.replace(rush_hour(duration_s=40.0, num_cameras=4),
                            superstep=8)


# --- runtime submission through QueryAPI --------------------------------------
def test_query_api_live_submission_virtual_time():
    sc = rush_hour(duration_s=40.0, num_cameras=4)
    driver = AsyncDriver(VirtualClock())
    pipe = QueryPipeline(sc, driver=driver)
    api = QueryAPI(pipe)
    top = QuerySpec(100, t_arrive_s=14.0, tenant="metro-pd", tier=0)
    low = QuerySpec(101, t_arrive_s=18.0, tenant="hobby", tier=2)
    driver.call_at(top.t_arrive_s, lambda t: api.submit(t, top))
    driver.call_at(low.t_arrive_s, lambda t: api.submit(t, low))
    r = pipe.run(synthetic_confidence_stream(sc))
    assert driver.hooks_run == 2
    # tier 0 is backlog-exempt: it trains mid-rush and goes live; the
    # best-effort straggler meets the by-then-deep backlog and sheds
    assert api.status(100) == "live"
    assert api.status(101) == "shed"
    assert api.status(999) == "unknown"
    assert r.submitted_queries == len(sc.queries) + 2


def test_query_api_duplicate_and_retire():
    sc = rush_hour(duration_s=40.0, num_cameras=4)
    pipe = QueryPipeline(sc, driver=AsyncDriver(VirtualClock()))
    api = QueryAPI(pipe)
    pipe.setup(synthetic_confidence_stream(sc))
    api.submit(0.0, QuerySpec(100, tenant="metro-pd", tier=0))
    with pytest.raises(ValueError, match="already registered"):
        api.submit(0.0, QuerySpec(100, tenant="metro-pd", tier=0))
    api.retire(5.0, 100)
    pipe.driver.drive(pipe)
    r = pipe.finalize()
    assert api.status(100) == "retired"
    assert r.submitted_queries == len(sc.queries) + 1


# --- bus wildcard + unsubscribe (satellite fixes) -----------------------------
def test_bus_hash_wildcard_segment_boundary():
    bus = Bus()
    got = []
    bus.subscribe("edges/#", lambda t, p: got.append(t))
    bus.publish("edges", 1)
    bus.publish("edges/3/queue", 1)
    bus.publish("edges9/queue", 1)              # sibling namespace: no match
    assert got == ["edges", "edges/3/queue"]
    catch_all = []
    bus.subscribe("#", lambda t, p: catch_all.append(t))
    bus.publish("anything/at/all", 1)
    assert catch_all == ["anything/at/all"]


def test_bus_unsubscribe_during_delivery():
    bus = Bus()
    got = []

    def leaver(topic, payload):
        got.append(topic)
        assert bus.unsubscribe("x/#", leaver)

    bus.subscribe("x/#", leaver)
    bus.subscribe("x/#", lambda t, p: got.append("stay:" + t))
    assert bus.publish("x/1", 0) == 2           # snapshot: both delivered
    assert bus.publish("x/2", 0) == 1           # leaver is gone
    assert got == ["x/1", "stay:x/1", "stay:x/2"]
    assert not bus.unsubscribe("x/#", leaver)   # already removed


def test_cascade_server_queue_is_deque():
    """The O(n^2) pop(0) fix: the backlog queue must be a deque (head
    pops are O(1) under a rush), and run() must drain it in FIFO order."""
    import inspect

    from repro.serving import engine
    src = inspect.getsource(engine.CascadeServer)
    assert "collections.deque" in src
    assert "popleft" in src                     # O(1) head pop in run()
    assert "queue.pop(0)" not in src            # the old O(n^2) head pop


# --- wall clock (real time: slow tier) ----------------------------------------
@pytest.mark.slow
@pytest.mark.asyncio
def test_wall_clock_paces_real_time():
    """A 2-simulated-second gap at speed 20 must take ~0.1 wall seconds
    — and the pump must deliver events in order while actually sleeping."""
    clock = WallClock(speed=20.0)
    t0 = time.monotonic()
    asyncio.run(clock.sleep_until(2.0))
    elapsed = time.monotonic() - t0
    assert 0.05 <= elapsed < 1.0
    assert clock.now() >= 2.0


@pytest.mark.slow
@pytest.mark.asyncio
def test_wall_clock_driver_matches_sim():
    sc = Scenario(name="tiny", edge_speeds=(1.0,), num_cameras=2,
                  duration_s=3.0)
    a = run_query(sc)
    b = run_query(sc, driver=AsyncDriver(WallClock(speed=500.0)))
    _reports_identical(a, b)
