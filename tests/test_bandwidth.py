"""Bandwidth endgame: int8 wire codec round-trip/byte accounting,
``Transport.ship_update`` charging the real quantized size, the e2e
quantized-downlink reduction vs the fp reference (>= 3x within the F2
band), and speculative escalation (identical decisions, lower escalated
latency, flips feeding the feedback ring buffers, in-flight escalations
reconciling across query retirement and edge failure)."""
import dataclasses

import numpy as np
import pytest

from repro.distributed import quantize as QZ
from repro.serving.simulator import Item
from repro.system import (
    Scenario,
    drifting_city,
    multi_query_city,
    run_query,
    single_edge,
    straggler_edge,
    synthetic_confidence_stream,
)
from repro.system.events import Task
from repro.system.pipeline import QueryPipeline
from repro.system.transport import Transport

# --- wire codec round-trip ----------------------------------------------------


def test_wire_roundtrip_error_within_half_scale():
    rng = np.random.default_rng(0)
    for shape in [(2,), (7,), (4, 33), (3, 8, 8)]:
        x = rng.normal(size=shape).astype(np.float32) * rng.uniform(0.1, 40)
        p = QZ.encode_wire(x)
        got = QZ.decode_wire(p)
        assert got.shape == x.shape and got.dtype == np.float32
        # affine grid fitted to each channel's [min, max]: error is
        # bounded by scale/2 per element, no clipping error anywhere
        rows = x.reshape(p.scale.size, -1)
        err = np.abs(got.reshape(p.scale.size, -1) - rows)
        assert np.all(err <= p.scale[:, None] / 2 + 1e-7)


def test_wire_constant_channel_roundtrips_bit_exact():
    x = np.full((3, 17), 0.731, np.float32)
    x[1] = -2.5
    got = QZ.decode_wire(QZ.encode_wire(x))
    np.testing.assert_array_equal(got, x)


def test_wire_platt_pair_roundtrip_is_tight():
    """The payload feedback.py actually ships: a Platt (a, b) pair.  One
    channel spanning [b, a] — round-trip error <= (a - b) / 254 / 2."""
    ab = np.asarray([1.73, -0.42], np.float32)
    got = QZ.decode_wire(QZ.encode_wire(ab))
    assert np.all(np.abs(got - ab) <= (ab.max() - ab.min()) / 254 / 2 + 1e-7)


def test_wire_nbytes_exact():
    x = np.zeros((4, 300), np.float32)            # 4 channels of 300 values
    p = QZ.encode_wire(x)
    assert p.nbytes == QZ.WIRE_HEADER_NBYTES + 1200 + 8 * 4
    # the simulator-side accounting for a payload it never materializes:
    # 64 KB fp32 -> 16384 values -> 64 channels of (scale, zero) overhead
    assert QZ.quantized_wire_nbytes(64 * 1024) == \
        QZ.WIRE_HEADER_NBYTES + 16384 + 8 * 64
    # ~3.9x, never a free 4x: the overhead is charged
    assert 3.5 < (64 * 1024) / QZ.quantized_wire_nbytes(64 * 1024) < 4.0
    with pytest.raises(ValueError):
        QZ.quantized_wire_nbytes(-1)


@pytest.mark.slow
def test_wire_roundtrip_property_over_weight_shapes():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=40, deadline=None)
    @given(st.lists(st.integers(1, 17), min_size=1, max_size=3),
           st.floats(1e-3, 1e3), st.integers(0, 2 ** 31 - 1))
    def prop(shape, spread, seed):
        rng = np.random.default_rng(seed)
        x = (rng.normal(size=tuple(shape)) * spread).astype(np.float32)
        p = QZ.encode_wire(x)
        got = QZ.decode_wire(p)
        rows = x.reshape(p.scale.size, -1)
        err = np.abs(got.reshape(p.scale.size, -1) - rows)
        tol = p.scale[:, None] / 2 + 1e-6 * max(spread, 1.0)
        assert np.all(err <= tol)
        # wire size: header + one byte per value + 8 per channel, and the
        # channel count matches the leading dim (or 1 for vectors)
        channels = x.shape[0] if x.ndim >= 2 else 1
        assert p.scale.size == channels
        assert p.nbytes == QZ.WIRE_HEADER_NBYTES + x.size + 8 * channels

    prop()


# --- Transport.ship_update byte accounting ------------------------------------


def _transports():
    sc = single_edge(num_cameras=2, duration_s=1.0)
    return (Transport(dataclasses.replace(sc, quantize_downlink=True)),
            Transport(dataclasses.replace(sc, quantize_downlink=False)))


def test_ship_update_charges_exact_quantized_bytes():
    tq, tf = _transports()
    fp = 64 * 1024
    tq.ship_update(0.0, fp)
    tf.ship_update(0.0, fp)
    assert tq.downloaded_bytes == QZ.quantized_wire_nbytes(fp)
    assert tq.downlink_fp_bytes == fp
    # the fp path's charged bytes and reference coincide bit-exactly
    assert tf.downloaded_bytes == tf.downlink_fp_bytes == fp
    assert tq.downloaded_bytes < tf.downloaded_bytes


def test_ship_update_roundtrips_values_only_when_quantizing():
    tq, tf = _transports()
    vals = np.asarray([1.73, -0.42], np.float32)
    _, got_q = tq.ship_update(0.0, 8, values=vals)
    _, got_f = tf.ship_update(0.0, 8, values=vals)
    assert got_f is vals                     # fp path: bit-identical object
    assert got_q is not vals                 # quantized: codec round-trip
    np.testing.assert_allclose(got_q, vals, atol=0.01)
    assert not np.array_equal(got_q, vals) or np.ptp(vals) == 0


def test_ship_update_accumulates_across_shipments():
    tq, _ = _transports()
    for k in range(5):
        tq.ship_update(float(k), 4096)
    assert tq.downlink_fp_bytes == 5 * 4096
    assert tq.downloaded_bytes == 5 * QZ.quantized_wire_nbytes(4096)


# --- e2e: quantized shipping reduction within the accuracy band ---------------


@pytest.mark.parametrize("preset", [multi_query_city, drifting_city])
def test_quantized_downlink_reduction_within_f2_band(preset):
    """Acceptance: on multi_query_city and drifting_city the quantized
    downlink is >= 3x smaller than the fp reference with |dF2| <= 0.05."""
    sc = preset(num_cameras=6, duration_s=30.0, seed=0)
    assert sc.quantize_downlink and sc.speculative_escalation
    rq = run_query(sc)
    rf = run_query(dataclasses.replace(sc, quantize_downlink=False,
                                       speculative_escalation=False))
    assert rq.model_updates > 0              # the loop really shipped
    # within ONE row: fp-equivalent cost vs charged quantized bytes
    assert rq.downlink_fp_bytes >= 3 * rq.downloaded_bytes
    # and across the ablation pair the fp run's charged bytes match its
    # own reference while the quantized run's sit >= 3x below them
    assert rf.downloaded_bytes == rf.downlink_fp_bytes
    assert abs(rq.f_score(2.0) - rf.f_score(2.0)) <= 0.05


# --- speculative escalation ---------------------------------------------------


def _spec_pair(**kw):
    sc = single_edge(num_cameras=6, duration_s=30.0, seed=3,
                     **kw).with_scheme("surveiledge_fixed")
    stream = synthetic_confidence_stream(sc)
    on = run_query(dataclasses.replace(sc, speculative_escalation=True),
                   items=stream)
    off = run_query(dataclasses.replace(sc, speculative_escalation=False),
                    items=stream)
    return on, off


def test_speculation_serves_same_decisions_sooner():
    """Speculation is pure serving-time accounting: the cloud's verdict
    still decides every escalated item (decisions identical), but the
    latency clock stops at the provisional serve instant."""
    on, off = _spec_pair()
    assert on.escalated == off.escalated > 0
    assert on.provisional == on.reconciled == on.escalated
    assert off.provisional == off.reconciled == 0
    assert on.f_score(2.0) == off.f_score(2.0)
    assert on.n_items == off.n_items
    assert on.avg_latency < off.avg_latency
    s = on.summary()
    assert 0.0 <= s["reconciliation_flip_rate"] <= 1.0
    assert 0.0 < s["provisional_latency_s"] < off.avg_latency


def test_flip_feeds_feedback_and_serves_at_provisional_time():
    """A reconciliation that flips the verdict (provisional False, cloud
    True) must count as a flip, feed the feedback ring buffer like any
    cloud label, and finish the item at the PROVISIONAL serve time."""
    sc = Scenario(name="unit", edge_speeds=(1.0,), num_cameras=1,
                  duration_s=5.0, update_period_s=2.0,
                  speculative_escalation=True)
    p = QueryPipeline(sc)
    p.run([])                                # initialize run-scoped state
    it = Item(t_arrival=0.0, camera=0, edge_device=1, conf=0.4,
              is_query=True)
    task = Task(it, "reclassify", None, provisional=False,
                t_provisional=1.0)
    p.nodes.push(1, task)
    p.sched.on_enqueue(1)
    started, svc = p.nodes.begin(0.0, 1)
    p._on_done(9.0, 1, started, svc)
    assert p._reconciled == 1 and p._flips == 1
    assert p.feedback.labels_seen == 1
    buf = p.feedback.buffers[(0, 1)]
    assert len(buf) == 1 and buf[0][2] is True     # cloud truth, not the
    #                                                provisional verdict
    assert p._lat == [1.0]                   # t_provisional - t_arrival
    assert p._dec == [True]                  # ...but the RECONCILED answer


def test_agreeing_reconciliation_is_not_a_flip():
    sc = Scenario(name="unit", edge_speeds=(1.0,), num_cameras=1,
                  duration_s=5.0, speculative_escalation=True)
    p = QueryPipeline(sc)
    p.run([])
    it = Item(t_arrival=0.0, camera=0, edge_device=1, conf=0.9,
              is_query=True)
    task = Task(it, "reclassify", None, provisional=True, t_provisional=0.5)
    p.nodes.push(1, task)
    p.sched.on_enqueue(1)
    started, svc = p.nodes.begin(0.0, 1)
    p._on_done(4.0, 1, started, svc)
    assert p._reconciled == 1 and p._flips == 0


def test_inflight_escalations_reconcile_at_query_retire():
    """multi_query_city retires q1 near the end of the run while
    escalations ride the WAN: every served provisional verdict must still
    reconcile — retirement never strands a speculative answer."""
    sc = multi_query_city(num_cameras=6, duration_s=30.0, seed=1)
    r = run_query(sc)
    assert r.provisional == r.reconciled == r.escalated > 0
    assert any(spec.get("t_retire_s") is not None
               for spec in r.queries.values())


def test_inflight_escalations_reconcile_across_edge_failure():
    """An edge dying with speculative reclassify work queued on it must
    not lose the served verdicts: failover carries provisional state, so
    reconciled still equals provisional at run end."""
    # slow uplink makes the cloud expensive under Eq. 7, so escalations
    # land on peer edges — and straggler_edge kills one of those edges
    # two-thirds in, stranding queued reclassify work mid-speculation
    sc = dataclasses.replace(
        straggler_edge(num_cameras=6, duration_s=30.0, seed=5,
                       uplink_MBps=0.05),
        speculative_escalation=True)
    r = run_query(sc)
    assert r.rerouted > 0                    # the failure really happened
    assert r.provisional == r.reconciled > 0
