"""Roofline analytics sanity + input_specs shapes for all (arch x shape)."""
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import (INPUT_SHAPES, attn_cache_len, decode_window,
                                  input_specs)
from repro.launch import roofline as R


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_roofline_terms_positive_and_sane(arch, shape):
    cfg = get_config(arch)
    rl = R.analyze(cfg, INPUT_SHAPES[shape])
    assert rl.compute_s > 0 and rl.memory_s > 0 and rl.collective_s >= 0
    assert rl.dominant in ("compute", "memory", "collective")
    # the 6ND convention should be within ~3x of the exact matmul count
    assert 0.1 < rl.useful_ratio < 3.0, rl.useful_ratio


@pytest.mark.parametrize("arch", ASSIGNED)
def test_analytic_flops_ordering(arch):
    cfg = get_config(arch)
    f_train = R.analytic_flops(cfg, INPUT_SHAPES["train_4k"])
    f_prefill = R.analytic_flops(cfg, INPUT_SHAPES["prefill_32k"])
    f_decode = R.analytic_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert f_train > f_decode
    assert f_prefill > f_decode


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_input_specs_shapes(arch, shape):
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape]
    specs = input_specs(cfg, sh)
    B = sh.global_batch
    if sh.kind == "decode":
        assert specs["token"].shape == (B,)
    else:
        s_text = sh.seq_len - (cfg.num_img_tokens or 0)
        assert specs["tokens"].shape == (B, s_text)
        if cfg.is_encdec:
            assert specs["audio_frames"].shape == (B, cfg.enc_seq, cfg.d_model)
        if cfg.num_img_tokens:
            assert specs["img_embeds"].shape == (B, cfg.num_img_tokens, 1024)
    if sh.kind == "train":
        assert specs["labels"].shape == specs["tokens"].shape


def test_long_context_uses_window_for_attention_archs():
    for arch in ASSIGNED:
        cfg = get_config(arch)
        w = decode_window(cfg, INPUT_SHAPES["long_500k"])
        if cfg.has_attn:
            assert w == 8192
            assert attn_cache_len(cfg, INPUT_SHAPES["long_500k"]) == 8192
        else:
            assert w is None     # ssm needs no window


def test_decode_32k_is_full_attention():
    cfg = get_config("qwen3-8b")
    assert decode_window(cfg, INPUT_SHAPES["decode_32k"]) is None
    assert attn_cache_len(cfg, INPUT_SHAPES["decode_32k"]) == 32768
