"""SurveilEdge core: scheduler (Eq.7), thresholds (Eq.8-9), latency (Eq.10-17),
clustering, cascade."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cascade as C
from repro.core import clustering as CL
from repro.core import latency as LT
from repro.core.scheduler import CLOUD, Scheduler
from repro.core.thresholds import ThresholdState


# --- Eq. 7 ---------------------------------------------------------------------

def test_scheduler_argmin_qt():
    s = Scheduler([0, 1, 2])
    s.nodes[0].queue_len, s.nodes[0].estimator.t = 10, 1.0   # cost 10
    s.nodes[1].queue_len, s.nodes[1].estimator.t = 3, 2.0    # cost 6
    s.nodes[2].queue_len, s.nodes[2].estimator.t = 8, 0.5    # cost 4 <- min
    assert s.select_node() == 2
    assert s.select_node(exclude_cloud=True) == 2
    s.nodes[2].queue_len = 100
    assert s.select_node() == 1


def test_scheduler_updates_move_queue_and_latency():
    s = Scheduler([0, 1])
    s.on_enqueue(1)
    assert s.nodes[1].queue_len == 1
    t_before = s.nodes[1].estimator.t
    s.on_complete(1, 0.5)
    assert s.nodes[1].queue_len == 0
    assert s.nodes[1].estimator.t != t_before


# --- Eqs. 8-9 -------------------------------------------------------------------

def test_threshold_bounds_always_hold():
    th = ThresholdState()
    rng = np.random.default_rng(0)
    for _ in range(500):
        th = th.update(rng.integers(0, 50), rng.uniform(0, 3), 1.0)
        assert 0.5 <= th.alpha <= 1.0
        assert 0.0 <= th.beta < 0.5
        assert th.beta < th.alpha


def test_threshold_shrinks_under_load_and_widens_when_idle():
    # Eq. 8: drain > s -> alpha decreases (more edge-accepts, fewer uploads);
    # drain < s -> alpha increases (more reclassification on the cloud).
    th = ThresholdState(alpha=0.8)
    overloaded = th.update(queue_len=50, item_latency=1.0, interval_s=1.0)
    assert overloaded.alpha < th.alpha
    idle = th.update(queue_len=0, item_latency=0.01, interval_s=1.0)
    assert idle.alpha >= th.alpha  # widens the escalation bracket


def test_triage_regions():
    th = ThresholdState(alpha=0.8, beta=0.1)
    assert th.triage(0.95) == "accept"
    assert th.triage(0.05) == "reject"
    assert th.triage(0.5) == "escalate"


# --- Eq. 17 ---------------------------------------------------------------------

def test_adaptive_mean_damps_outliers():
    t = 0.1
    t_spike = LT.adaptive_mean(t, 10.0)          # huge outlier
    t_plain = (0.1 + 10.0) / 2
    assert t_spike < t_plain                     # damped vs plain mean
    assert t < t_spike                           # still moves toward it


def test_adaptive_mean_is_convex_combination():
    rng = np.random.default_rng(1)
    for _ in range(200):
        a, b = rng.uniform(0.01, 5, 2)
        m = LT.adaptive_mean(a, b)
        assert min(a, b) - 1e-9 <= m <= max(a, b) + 1e-9


def test_adaptive_mean_fixed_point():
    assert LT.adaptive_mean(0.7, 0.7) == pytest.approx(0.7)


# --- Eqs. 10-16 ------------------------------------------------------------------

def test_lognormal3_mle_recovers_parameters():
    rng = np.random.default_rng(2)
    gamma, mu, sigma = 0.05, -2.0, 0.5
    x = gamma + np.exp(rng.normal(mu, sigma, 4000))
    g, m, s2 = LT.fit_lognormal3(x)
    assert abs(g - gamma) < 0.02
    assert abs(m - mu) < 0.15
    assert abs(math.sqrt(s2) - sigma) < 0.1


def test_latency_estimator_predict_positive_and_bounded():
    est = LT.LatencyEstimator(t=0.1, refit_every=32)
    rng = np.random.default_rng(3)
    for _ in range(200):
        est.observe(float(0.02 + np.exp(rng.normal(-2.5, 0.4))))
    p = est.predict()
    assert 0.0 < p < 1.0


# --- clustering -------------------------------------------------------------------

def test_kmeans_separates_two_scene_types():
    rng = np.random.default_rng(4)
    road = rng.dirichlet([8, 1, 1, 1], size=10)
    plaza = rng.dirichlet([1, 8, 1, 1], size=10)
    profs = jnp.asarray(np.concatenate([road, plaza]))
    assign, centers, inertia = CL.kmeans(profs, 2)
    a = np.asarray(assign)
    assert len(set(a[:10])) == 1 and len(set(a[10:])) == 1
    assert a[0] != a[10]
    assert float(inertia) < 1.0


def test_proportion_vector_normalized():
    labels = jnp.asarray([0, 0, 1, 2, 2, 2], jnp.int32)
    pv = CL.proportion_vector(labels, 4)
    np.testing.assert_allclose(np.asarray(pv), [2 / 6, 1 / 6, 3 / 6, 0], atol=1e-6)


# --- cascade ----------------------------------------------------------------------

def test_cascade_batch_routes_and_combines():
    conf = jnp.asarray([0.95, 0.5, 0.02, 0.6])
    items = jnp.arange(4)

    def cloud_fn(x):                      # item 1 and 3 escalate
        return jnp.where(x % 2 == 1, 0.9, 0.1)

    out = C.cascade_batch(conf, cloud_fn, items,
                          alpha=jnp.float32(0.8), beta=jnp.float32(0.1),
                          capacity=4)
    assert int(out["n_escalated"]) == 2
    dec = np.asarray(out["decision"])
    assert dec[0]                  # edge accept
    assert not dec[2]              # edge reject
    assert dec[1] and dec[3]       # cloud accepted both escalations


def test_compact_escalated_overflow_is_bounded():
    routes = jnp.full((16,), C.ESCALATE, jnp.int32)
    idx, valid, n = C.compact_escalated(routes, capacity=4)
    assert int(n) == 16
    assert int(valid.sum()) == 4
    np.testing.assert_array_equal(np.asarray(idx), [0, 1, 2, 3])
