"""Multi-query runtime tests: (Q, E, N) kernel parity + Q-axis padding
invisibility, the query lifecycle's event ordering (arrival-during-
training, retire-with-in-flight-escalations), and the one-fused-launch-
per-tick invariant with concurrent live queries."""
import numpy as np
import pytest

from repro.core.finetune import scheme_train_time
from repro.kernels import ops, ref
from repro.serving.simulator import Item
from repro.system import (
    QuerySpec,
    Scenario,
    multi_query_city,
    query_churn,
    run_query,
    synthetic_confidence_stream,
)

# --- (Q, E, N) kernel parity --------------------------------------------------


def test_triage_fleet_query_axis_matches_per_query_fleet():
    rng = np.random.default_rng(7)
    Q, E, N = 3, 5, 21
    conf = rng.uniform(0, 1, (Q, E, N)).astype(np.float32)
    th = np.stack([rng.uniform(0.5, 1.0, (Q, E)),
                   rng.uniform(0.0, 0.45, (Q, E))], axis=-1).astype(np.float32)
    r3, s3, c3 = map(np.asarray, ops.triage_fleet(conf, th, capacity=4))
    assert r3.shape == (Q, E, N) and c3.shape == (Q, E)
    for q in range(Q):
        r2, s2, c2 = map(np.asarray,
                         ops.triage_fleet(conf[q], th[q], capacity=4))
        np.testing.assert_array_equal(r3[q], r2)
        np.testing.assert_array_equal(s3[q], s2)
        np.testing.assert_array_equal(c3[q], c2)


def test_triage_fleet_query_axis_matches_ref():
    rng = np.random.default_rng(8)
    conf = rng.uniform(0, 1, (2, 3, 17)).astype(np.float32)
    th = np.stack([rng.uniform(0.5, 1.0, (2, 3)),
                   rng.uniform(0.0, 0.45, (2, 3))], axis=-1).astype(np.float32)
    got = ops.triage_fleet(conf, th, capacity=6)
    want = ref.triage_fleet_ref(conf, th, 6)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_triage_fleet_query_axis_pad_rows_are_inert():
    """Bucket-padding the query axis (3 -> 4) must be invisible: the pad
    row's lanes are all conf=-1.0 under thresholds (1, 0), which can never
    escalate, claim a slot, or perturb real rows' compaction."""
    rng = np.random.default_rng(9)
    conf = rng.uniform(0, 1, (3, 4, 9)).astype(np.float32)   # Q=3 -> pads to 4
    th = np.tile(np.asarray([0.8, 0.1], np.float32), (3, 4, 1))
    r3, s3, c3 = map(np.asarray, ops.triage_fleet(conf, th, capacity=2))
    # growing Q by an explicitly-inert row gives identical leading rows
    conf4 = np.concatenate([conf, np.full((1, 4, 9), -1.0, np.float32)])
    th4 = np.concatenate([th, np.tile(np.asarray([1.0, 0.0], np.float32),
                                      (1, 4, 1))])
    r4, s4, c4 = map(np.asarray, ops.triage_fleet(conf4, th4, capacity=2))
    np.testing.assert_array_equal(r4[:3], r3)
    np.testing.assert_array_equal(s4[:3], s3)
    np.testing.assert_array_equal(c4[:3], c3)
    assert np.all(r4[3] == 1) and np.all(s4[3] == -1) and np.all(c4[3] == 0)


def test_calibrate_fleet_query_axis_matches_2d_fold():
    rng = np.random.default_rng(10)
    Q, E, N = 2, 3, 64
    scores = rng.uniform(0, 1, (Q, E, N)).astype(np.float32)
    truths = (rng.uniform(0, 1, (Q, E, N)) < scores).astype(np.float32)
    p3, n3 = map(np.asarray, ops.calibrate_fleet(scores, truths))
    assert p3.shape == (Q, E, 2) and n3.shape == (Q, E)
    p2, n2 = map(np.asarray, ops.calibrate_fleet(
        scores.reshape(Q * E, N), truths.reshape(Q * E, N)))
    np.testing.assert_allclose(p3, p2.reshape(Q, E, 2), atol=1e-6)
    np.testing.assert_array_equal(n3, n2.reshape(Q, E))
    pr, nr = ref.calibrate_fleet_ref(scores, truths, 8, 8)
    assert pr.shape == (Q, E, 2)
    np.testing.assert_allclose(p3, pr, atol=2e-3)
    np.testing.assert_array_equal(n3, nr)


@pytest.mark.slow
def test_triage_fleet_query_padding_invisibility_property():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        st.integers(1, 5),                  # queries (crosses the 2/4 buckets)
        st.integers(1, 4),                  # edges
        st.integers(1, 19),                 # lanes
        st.integers(1, 8),                  # capacity
        st.integers(0, 2 ** 31 - 1),
    )
    def prop(Q, E, N, capacity, seed):
        rng = np.random.default_rng(seed)
        conf = rng.uniform(0, 1, (Q, E, N)).astype(np.float32)
        th = np.stack([rng.uniform(0.5, 1.0, (Q, E)),
                       rng.uniform(0.0, 0.5, (Q, E))],
                      axis=-1).astype(np.float32)
        r3, s3, c3 = map(np.asarray,
                         ops.triage_fleet(conf, th, capacity=capacity))
        # the fused (Q, E, N) launch == Q independent (E, N) launches
        for q in range(Q):
            r2, s2, c2 = map(np.asarray, ops.triage_fleet(
                conf[q], th[q], capacity=capacity))
            np.testing.assert_array_equal(r3[q], r2)
            np.testing.assert_array_equal(s3[q], s2)
            np.testing.assert_array_equal(c3[q], c2)
        # ... and == the jnp reference on the raw (unpadded) tensor
        rr, sr, cr = map(np.asarray,
                         ref.triage_fleet_ref(conf, th, capacity))
        np.testing.assert_array_equal(r3, rr)
        np.testing.assert_array_equal(s3, sr)
        np.testing.assert_array_equal(c3, cr)

    prop()


# --- lifecycle helpers --------------------------------------------------------


def _instant_queries(n):
    """Queries that arrive at t=0 with zero training time (no_finetune),
    so only the (tiny) weight shipment separates arrival from serving."""
    return tuple(QuerySpec(q, 0.0, None, "no_finetune") for q in range(n))


def _mq_scenario(queries, **kw):
    return Scenario(name="mq_test", edge_speeds=kw.pop("edge_speeds",
                                                       (1.0, 1.0)),
                    num_cameras=kw.pop("num_cameras", 4),
                    duration_s=kw.pop("duration_s", 30.0),
                    queries=queries,
                    cq_nbytes=kw.pop("cq_nbytes", 1024),
                    offload_drain_s=kw.pop("offload_drain_s", 1e9), **kw)


def _items(specs):
    """[(t, edge, conf, is_query, query), ...] -> sorted Item list."""
    out = [Item(t_arrival=t, camera=0, edge_device=e, conf=c, is_query=g,
                query=q) for (t, e, c, g, q) in specs]
    out.sort(key=lambda it: it.t_arrival)
    return out


# --- one fused launch per tick with concurrent live queries -------------------


def test_three_live_queries_one_fused_launch_per_arrival_tick():
    """3 concurrent live queries, every item arriving after all weights
    delivered: kernel_launches == ticks-with-arrivals, NOT x3."""
    sc = _mq_scenario(_instant_queries(3))
    specs = []
    for k in range(5, 25):                      # ticks 5..24: all queries live
        for q in range(3):
            for e in (1, 2):
                specs.append((k + 0.3 + 0.01 * q, e, 0.5 + 0.1 * q, True, q))
    items = _items(specs)
    ticks_with_arrivals = {int(it.t_arrival // sc.interval_s) for it in items}
    r = run_query(sc, items=items)
    assert len(r.latencies) == len(items)
    assert r.kernel_launches == len(ticks_with_arrivals)
    assert r.kernel_launches < 2 * len(ticks_with_arrivals)   # never x Q
    assert r.summary()["launches_per_tick"] <= 1.0
    assert r.summary()["n_queries"] == 3


def test_triage_stage_counts_one_launch_for_multi_query_tick():
    """Unit-level fusion proof: a tick batch spanning 3 queries x 2 edges
    costs exactly ONE ops.triage_fleet call."""
    from repro.core.scheduler import Scheduler
    from repro.system.transport import Transport
    from repro.system.triage import TriageStage

    sc = _mq_scenario(_instant_queries(3))
    stage = TriageStage(sc, Scheduler([0, 1, 2]), Transport(sc))
    batches = {
        (q, e): [Item(t_arrival=0.0, camera=0, edge_device=e, conf=0.5,
                      is_query=True, query=q)]
        for q in range(3) for e in (1, 2)}
    before = stage.launches
    out = stage.triage_tick(batches)
    assert stage.launches == before + 1
    assert sorted(out) == sorted(batches)
    for key, (routes, slots, conf_used) in out.items():
        assert len(routes) == len(slots) == len(conf_used) == 1


# --- event ordering: arrival during training ----------------------------------


def test_arrival_during_training_defers_then_serves():
    """q1 arrives while the cloud is still fine-tuning q0: both trainings
    run, both queries eventually serve on every edge, every item is
    answered, and no item of a training query finishes before its
    training ended (its escalations were blocked by deferral)."""
    queries = (QuerySpec(0, 0.0, None, "surveiledge"),
               QuerySpec(1, 1.0, None, "surveiledge"))
    sc = _mq_scenario(queries, train_step_s=0.1)     # 4s per cluster fit
    train_s = scheme_train_time("surveiledge", sc.num_cameras, step_s=0.1)
    assert train_s == 4.0                            # q1 arrives inside q0's
    specs = [(0.5 + k, e, 0.5, True, q)              # escalation-band conf
             for k in range(20) for e in (1, 2) for q in (0, 1)]
    items = _items(specs)
    r = run_query(sc, items=items)
    assert len(r.latencies) == len(items)
    for q, sp in ((0, queries[0]), (1, queries[1])):
        row = r.per_query_summary()[q]
        assert row["train_s"] == pytest.approx(train_s)
        assert row["live_edges"] == [1, 2]
        assert row["deferred"] > 0                   # items waited on weights
        finished = r.finish_times[r.query_ids == q]
        assert finished.min() >= sp.t_arrive_s + train_s
    assert r.cloud_train_s == pytest.approx(2 * train_s)


# --- event ordering: retire with in-flight escalations ------------------------


def test_retire_with_inflight_escalations_still_answers_everything():
    """q0 retires at t=12 with escalations still riding the (slow) WAN:
    they complete after retirement and are counted — no lost answers —
    while items still waiting for q1's weights at ITS retirement are
    answered with the pre-trained prior."""
    queries = (QuerySpec(0, 0.0, 12.0, "no_finetune"),
               QuerySpec(1, 10.0, 14.0, "all_finetune"))  # trains past retire
    sc = _mq_scenario(queries, uplink_MBps=0.05, train_step_s=0.1,
                      duration_s=30.0)
    specs = []
    for k in range(2, 12):
        specs.append((k + 0.5, 1, 0.5, True, 0))     # escalation-band -> WAN
    for k in range(10, 14):
        specs.append((k + 0.5, 2, 0.9, True, 1))     # deferred forever
    items = _items(specs)
    r = run_query(sc, items=items)
    assert len(r.latencies) == len(items)            # nothing lost
    fin0 = r.finish_times[r.query_ids == 0]
    assert fin0.max() > 12.0                         # in-flight past retire
    assert r.escalated > 0
    # q1 never went live anywhere (training outlived it); its items were
    # flushed at retirement with the prior, not dropped
    row1 = r.per_query_summary()[1]
    assert row1["live_edges"] == []
    assert row1["n_items"] == 4
    fin1 = r.finish_times[r.query_ids == 1]
    assert fin1.min() >= 14.0                        # held until retirement


# --- presets end to end -------------------------------------------------------


def test_multi_query_city_smoke():
    sc = multi_query_city(num_cameras=6, duration_s=45.0, seed=1)
    assert len(sc.queries) == 3
    stream = synthetic_confidence_stream(sc)
    # per-query substreams respect the lifetime windows
    for sp in sc.queries:
        t1 = sp.t_retire_s if sp.t_retire_s is not None else float("inf")
        for it in stream:
            if it.query == sp.query:
                assert sp.t_arrive_s <= it.t_arrival < t1
    r = run_query(sc, items=stream)
    assert len(r.latencies) == len(stream)
    s = r.summary()
    assert s["kernel_launches"] <= s["ticks"]        # fused, never x Q
    assert s["launches_per_tick"] <= 1.0
    rows = r.per_query_summary()
    assert sorted(rows) == [0, 1, 2]
    # the Fig. 5 trade surfaces in the per-query rows: all_finetune pays
    # the longest training, no_finetune none at all but scores worst
    by_scheme = {row["train_scheme"]: row for row in rows.values()}
    assert by_scheme["all_finetune"]["train_s"] \
        > by_scheme["surveiledge"]["train_s"] > 0
    assert by_scheme["no_finetune"]["train_s"] == 0
    assert by_scheme["no_finetune"]["f2"] \
        < min(by_scheme["surveiledge"]["f2"], by_scheme["all_finetune"]["f2"])


def test_query_churn_smoke():
    sc = query_churn(num_cameras=6, duration_s=45.0, seed=2)
    stream = synthetic_confidence_stream(sc)
    r = run_query(sc, items=stream)
    assert len(r.latencies) == len(stream)
    assert r.kernel_launches <= r.ticks
    assert sorted(r.per_query_summary()) == [0, 1, 2, 3, 4]
    # retired queries answered everything they generated
    for sp in sc.queries:
        n_stream = sum(1 for it in stream if it.query == sp.query)
        assert r.per_query_summary()[sp.query]["n_items"] == n_stream


# --- validation ---------------------------------------------------------------


def test_query_spec_validation():
    with pytest.raises(ValueError, match="train_scheme"):
        QuerySpec(0, 0.0, None, "bogus")
    with pytest.raises(ValueError, match="t_retire_s"):
        QuerySpec(0, 5.0, 4.0)
    with pytest.raises(ValueError, match="duplicate query ids"):
        Scenario(name="dup", queries=(QuerySpec(0), QuerySpec(0, 1.0)))


def test_undeclared_query_id_in_stream_is_rejected():
    """An item tagged with a query no spec declares would defer forever
    and silently vanish from the report — the pipeline must refuse it."""
    sc = _mq_scenario((QuerySpec(1),), duration_s=5.0)
    rogue = _items([(0.5, 1, 0.9, True, 7)])
    with pytest.raises(ValueError, match="undeclared query ids"):
        run_query(sc, items=rogue)


def test_scheme_train_time_fig5_ordering():
    assert scheme_train_time("no_finetune", 8) == 0.0
    assert scheme_train_time("surveiledge", 8, step_s=0.05) \
        == pytest.approx(2.0)
    assert scheme_train_time("all_finetune", 8, step_s=0.05) \
        == pytest.approx(16.0)
    with pytest.raises(ValueError):
        scheme_train_time("resnet", 8)
