"""Pixel path end to end: frames -> motion mask -> boxes -> crops -> CQ
scores -> Item stream -> run_query, all CPU-only (interpret=True).

Covers Pallas/ref parity through the whole detection stage, the
bucket-padded crop-scoring launch, truth matching, the static-scene
zero-item invariant, and the pixel_city frames->report acceptance run
(stage timings nonzero, slow-marked full size in the non-blocking tier).
"""
import dataclasses
import functools

import jax
import numpy as np
import pytest

from repro.data import synthetic_video as SV
from repro.detection import pipeline as DP
from repro.detection.components import Box
from repro.kernels import ops
from repro.system import PixelFrontend, pixel_city, run_query
from repro.system.pixel_frontend import match_truth


def _busy_camera(seed, rate=2.0):
    cam = SV.make_cameras(1, seed=seed)[0]
    cam.base_rate, cam.busy_boost = rate, 0.0
    return cam


# --- detection stage: Pallas vs ref parity, frames through scores -------------


def test_detect_pallas_matches_ref_end_to_end():
    """The full frames -> mask -> boxes -> crops stage is identical under
    the Pallas kernels (interpret mode) and the pure-jnp reference."""
    rng = np.random.default_rng(0)
    frames, _ = SV.render_triple(_busy_camera(11), 0.0, rng)
    dets_p = DP.detect(frames, use_pallas=True)[0]
    dets_r = DP.detect(frames, use_pallas=False)[0]
    assert len(dets_p) == len(dets_r) > 0
    for dp, dr in zip(dets_p, dets_r):
        assert dp.box == dr.box
        np.testing.assert_array_equal(dp.crop, dr.crop)


def test_detection_scores_pallas_ref_parity():
    """Classifier confidences downstream of both detection paths agree."""
    rng = np.random.default_rng(1)
    frames, _ = SV.render_triple(_busy_camera(12), 0.0, rng)
    fe = PixelFrontend(seed=0)
    score = functools.partial(fe._conf_fn, fe.params)
    confs = []
    for use_pallas in (True, False):
        crops = np.stack([d.crop
                          for d in DP.detect(frames,
                                             use_pallas=use_pallas)[0]])
        tokens = SV.crops_to_tokens(crops, fe.cfg.vocab_size)
        confs.append(np.asarray(ops.score_crops(score, tokens)))
    np.testing.assert_allclose(confs[0], confs[1], rtol=1e-6)
    assert np.all((confs[0] >= 0) & (confs[0] <= 1))


def test_score_crops_bucket_padding_is_invisible():
    """Padding N up to the power-of-two bucket must not change the first N
    scores, and the padded launch shape must be the bucket size."""
    fe = PixelFrontend(seed=3)
    rng = np.random.default_rng(2)
    crops = np.stack([SV.object_crop(c % SV.NUM_CLASSES, rng)
                      for c in range(13)])
    tokens = SV.crops_to_tokens(crops, fe.cfg.vocab_size)
    seen = []

    def spy(t):
        seen.append(t.shape)
        return fe._conf_fn(fe.params, t)

    got = np.asarray(ops.score_crops(spy, tokens))
    assert seen == [(16, tokens.shape[1])]          # 13 -> bucket 16
    direct = np.asarray(fe._conf_fn(fe.params, jax.numpy.asarray(tokens)))
    np.testing.assert_allclose(got, direct, rtol=1e-5, atol=1e-6)


# --- truth matching -----------------------------------------------------------


def test_match_truth_picks_nearest_sprite_and_rejects_noise():
    truth = SV.FrameTruth(classes=[3, 7], boxes=[(10, 10), (60, 90)])
    on_moped = Box(8, 8, 28, 28, 441)        # center (18, 18) ~ sprite 0
    on_dog = Box(58, 88, 78, 108, 441)       # center (68, 98) ~ sprite 1
    far = Box(0, 60, 10, 70, 121)            # matches nothing
    assert match_truth(on_moped, truth) == 3
    assert match_truth(on_dog, truth) == 7
    assert match_truth(far, truth) is None


# --- the frontend -------------------------------------------------------------


def test_static_scene_yields_zero_items():
    """No moving objects -> no motion mask -> empty stream (sensor noise
    alone must never fabricate detections)."""
    sc = pixel_city(num_cameras=2, duration_s=3.0, burst_rate=0.0,
                    burst_boost=0.0)
    assert PixelFrontend(seed=0).stream(sc) == []


def test_pixel_frontend_items_are_well_formed():
    sc = pixel_city(num_cameras=3, num_edges=2, duration_s=4.0, seed=1)
    fe = PixelFrontend(seed=1)
    items = fe.stream(sc)
    assert len(items) > 0
    t = [it.t_arrival for it in items]
    assert t == sorted(t) and 0 <= t[0] and t[-1] < sc.duration_s
    for it in items:
        assert 0.0 <= it.conf <= 1.0
        assert it.edge_device in sc.edge_ids
        assert 0 <= it.camera < sc.num_cameras
        assert it.edge_device == it.camera % sc.num_edges + 1
        assert it.nbytes == fe.crop * fe.crop * 3
    # per-stage wall clock was recorded for the model-in-the-loop stages
    assert fe.timings["framediff_s"] > 0
    assert fe.timings["classify_s"] > 0


def test_pixel_frontend_stream_cache_reuses_render():
    sc = pixel_city(num_cameras=2, duration_s=3.0, seed=2)
    fe = PixelFrontend(seed=2)
    first = fe.stream(sc)
    launches = fe.launches
    again = fe.stream(sc)                     # same scenario -> cache hit
    assert again == first
    assert fe.launches == launches
    # a scheme change must hit, a stream-shaping change must miss
    assert fe.stream(sc.with_scheme("edge_only")) == first
    assert fe.launches == launches
    other = fe.stream(dataclasses.replace(sc, seed=9))
    assert fe.launches > launches
    assert other != first


def test_run_query_pixel_report_has_stage_timings():
    """frames -> triage -> allocation -> metrics, small enough for tier-1:
    the report carries nonzero framediff/classify/triage stage timings."""
    sc = pixel_city(num_cameras=4, num_edges=2, duration_s=5.0, seed=0)
    fe = PixelFrontend(seed=0)
    r = run_query(sc, frontend=fe)
    assert len(r.latencies) == len(fe.stream(sc)) > 0
    assert r.stage_timings["framediff_s"] > 0
    assert r.stage_timings["classify_s"] > 0
    assert r.stage_timings["triage_s"] > 0
    assert r.kernel_launches > 0
    # confidence-stream runs keep the frontend stages out of the report
    r_conf = run_query(sc)
    assert "framediff_s" not in r_conf.stage_timings
    assert "triage_s" in r_conf.stage_timings


@pytest.mark.slow
def test_run_query_pixel_city_full_acceptance():
    """The full pixel_city preset (12 cameras, 12 s), as the CI smoke job
    runs it: every scheme answers the whole stream off one render pass."""
    sc = pixel_city()
    fe = PixelFrontend(seed=0)
    n = len(fe.stream(sc))
    assert n > 0
    for scheme in ("surveiledge", "edge_only", "cloud_only"):
        r = run_query(sc.with_scheme(scheme), frontend=fe)
        assert len(r.latencies) == n
        assert np.isfinite(r.avg_latency)
    r = run_query(sc, frontend=fe)
    assert r.stage_timings["framediff_s"] > 0
    assert r.stage_timings["classify_s"] > 0
    assert r.stage_timings["triage_s"] > 0
