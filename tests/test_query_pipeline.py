"""Tests for the end-to-end query harness (repro.system) and its parts:
scheduler edge cases, batched-triage capacity overflow, and run_query
consistency invariants on tiny scenarios."""
import numpy as np
import pytest

from repro.core.scheduler import CLOUD, Scheduler
from repro.kernels import ops, ref
from repro.serving.simulator import Item
from repro.system import (
    Scenario,
    run_query,
    single_edge,
    straggler_edge,
    synthetic_confidence_stream,
)
from repro.system.events import Task
from repro.system.pipeline import QueryPipeline

# --- Eq. 7 scheduler edge cases ----------------------------------------------


def test_select_node_tie_breaks_to_lowest_id():
    s = Scheduler([0, 1, 2])
    # all queues empty -> every cost is 0 -> the cloud (node 0) wins
    assert s.select_node() == CLOUD
    assert s.select_node(exclude_cloud=True) == 1
    assert s.select_node(exclude_cloud=True, exclude={1}) == 2


def test_select_node_exclude_cloud_never_returns_cloud():
    s = Scheduler([0, 1, 2])
    # pile work on the edges so the cloud is by far the cheapest
    for _ in range(50):
        s.on_enqueue(1)
        s.on_enqueue(2)
    assert s.select_node() == CLOUD
    assert s.select_node(exclude_cloud=True) in (1, 2)


def test_select_node_raises_when_nothing_eligible():
    s = Scheduler([0, 1])
    with pytest.raises(ValueError):
        s.select_node(exclude_cloud=True, exclude={1})
    s.mark_down(1)
    with pytest.raises(ValueError):
        s.select_node(exclude_cloud=True)
    s.mark_up(1)
    assert s.select_node(exclude_cloud=True) == 1


def test_select_node_skips_downed_nodes():
    s = Scheduler([0, 1, 2])
    s.mark_down(1)
    assert s.select_node(exclude_cloud=True) == 2


def test_select_node_extra_cost_steers_away():
    s = Scheduler([0, 1])
    # idle cloud would win the tie; an uplink-backlog charge flips it
    assert s.select_node() == CLOUD
    assert s.select_node(extra_cost={CLOUD: 10.0}) == 1


# --- latency-estimator regressions (Eq. 7 inputs must stay unbiased) ---------


def test_cloud_estimator_unbiased_by_wan_congestion():
    """One WAN congestion burst must not inflate the cloud's t_0: transfer
    time belongs to Transport (and Eq. 7's wan_backlog charge), never to
    the node latency estimator.  Before the fix, svc + tx_s fed the
    estimator and a saturated 0.05 MB/s uplink (~1 s per 49 KB crop)
    dragged t_0 orders of magnitude above the true service time."""
    sc = single_edge(num_cameras=6, duration_s=40.0, seed=3,
                     uplink_MBps=0.05).with_scheme("surveiledge_fixed")
    stream = synthetic_confidence_stream(sc)
    p = QueryPipeline(sc)
    r = p.run(stream)
    assert r.escalated > 20                  # the uplink really was stressed
    assert r.wan_transfer_s > 10.0           # ...and transport accounts it
    cloud_svc = sc.edge_service_s / sc.cloud_speedup
    est = p.sched.nodes[CLOUD].estimator
    assert len(est._history) > 0
    # the estimate converges to the true (jittered) service time, not to
    # service + transfer
    assert est.t < 3.0 * cloud_svc


def test_edge_estimator_unbiased_by_reclassify_mix():
    """An edge serving a classify/reclassify mix must still estimate the
    per-CQ-item latency: reclassify observations run reclassify_factor x
    slower and are normalized back, so drain_time (Eqs. 7-9) stays
    anchored to the queue's base service rate."""
    sc = Scenario(name="mix", edge_speeds=(1.0,), num_cameras=1,
                  duration_s=5.0, reclassify_factor=4.0)
    p = QueryPipeline(sc)
    p.run([])                                # initialize run-scoped state
    it = Item(t_arrival=0.0, camera=0, edge_device=1, conf=0.9,
              is_query=True)
    for k in range(300):
        phase = "classify" if k % 2 == 0 else "reclassify"
        task = Task(it, phase, True if phase == "classify" else None)
        p.nodes.push(1, task)
        p.sched.on_enqueue(1)
        started, svc = p.nodes.begin(0.0, 1)
        p._on_done(svc, 1, started, svc)
    est = p.sched.nodes[1].estimator
    # unbiased: ~1.0x the base CQ service time (lognormal jitter only);
    # the pre-fix mixed estimate sat near (1 + factor)/2 = 2.5x
    assert est.t < 1.5 * sc.edge_service_s
    assert est.t > 0.6 * sc.edge_service_s


# --- batched triage: capacity overflow ---------------------------------------


def test_triage_batched_overflow_leaves_tail_unescalated():
    conf = np.full(20, 0.5, np.float32)           # all in the [beta,alpha] band
    routes, slots, count = ops.triage_batched(
        conf, alpha=0.8, beta=0.1, capacity=4)
    routes, slots = np.asarray(routes), np.asarray(slots)
    assert int(count) == 20                       # count reports all escalated
    np.testing.assert_array_equal(slots[:4], [0, 1, 2, 3])
    assert np.all(slots[4:] == -1)                # overflow: no buffer slot
    assert np.all(routes == 2)


@pytest.mark.parametrize("n,cap", [(3, 1), (17, 4), (64, 64), (100, 8)])
def test_triage_batched_matches_ref_under_overflow(n, cap):
    rng = np.random.default_rng(n)
    conf = rng.uniform(0, 1, n).astype(np.float32)
    got = ops.triage_batched(conf, alpha=0.7, beta=0.2, capacity=cap)
    want = ref.triage_ref(conf, 0.7, 0.2, cap)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_triage_batched_thresholds_are_runtime_data():
    """Adapting alpha/beta between calls must not change results vs ref
    (and hits the same cached jit trace — no per-threshold recompiles)."""
    conf = np.linspace(0, 1, 33, dtype=np.float32)
    for a, b in [(0.9, 0.05), (0.8, 0.1), (0.55, 0.3), (0.7, 0.2)]:
        got = ops.triage_batched(conf, alpha=a, beta=b, capacity=16)
        want = ref.triage_ref(conf, a, b, 16)
        for g, w in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


# --- run_query smoke: consistency invariants ---------------------------------


@pytest.fixture(scope="module")
def tiny_report():
    sc = single_edge(num_cameras=3, duration_s=30.0, seed=5)
    stream = synthetic_confidence_stream(sc)
    return sc, stream, run_query(sc, items=stream)


def test_run_query_answers_every_item_exactly_once(tiny_report):
    _, stream, r = tiny_report
    assert len(r.latencies) == len(stream)
    assert len(r.decisions) == len(stream)
    assert len(r.truths) == len(stream)


def test_run_query_metrics_are_monotonically_consistent(tiny_report):
    _, stream, r = tiny_report
    # completions are emitted in nondecreasing simulation time
    assert np.all(np.diff(r.finish_times) >= -1e-9)
    # nothing finishes before it arrives, and nothing takes negative time
    assert np.all(r.latencies >= 0)
    # queue samples are counts over exactly `ticks` scheduler intervals
    for node, q in r.queue_timeline.items():
        assert len(q) == r.ticks
        assert np.all(q >= 0)
    # escalations and bandwidth are consistent: uploads are whole crops and
    # only escalated / rerouted items ever leave an edge
    nbytes = stream[0].nbytes
    assert r.uploaded_bytes % nbytes == 0
    assert r.uploaded_bytes + r.lan_bytes \
        <= (r.escalated + r.rerouted) * nbytes
    assert 0.0 <= r.f_score() <= 1.0


def test_run_query_one_fused_launch_per_tick(tiny_report):
    sc, stream, r = tiny_report
    # one fused fleet-triage launch per tick-with-arrivals — NOT per edge:
    # the (E, N) tick matrix goes through ops.triage_fleet in one call
    ticks_with_arrivals = {int(it.t_arrival // sc.interval_s)
                           for it in stream}
    assert r.kernel_launches == len(ticks_with_arrivals)
    assert r.kernel_launches <= r.ticks


def test_run_query_edge_only_never_launches_triage(tiny_report):
    sc, stream, _ = tiny_report
    r = run_query(sc.with_scheme("edge_only"), items=stream)
    assert r.kernel_launches == 0
    assert r.escalated == 0
    assert r.uploaded_bytes == 0
    r = run_query(sc.with_scheme("cloud_only"), items=stream)
    assert r.kernel_launches == 0
    assert r.uploaded_bytes == len(stream) * stream[0].nbytes


def test_run_query_survives_edge_failure():
    sc = straggler_edge(num_cameras=4, duration_s=30.0, seed=3)
    stream = synthetic_confidence_stream(sc)
    r = run_query(sc, items=stream)
    # every item is still answered exactly once, despite edge 1 dying
    assert len(r.latencies) == len(stream)
    # the dead edge's queue is empty from the failure tick onward
    fail_tick = int(sc.failures[0][0] / sc.interval_s)
    assert np.all(r.queue_timeline[1][fail_tick + 1:] == 0)
    # its stranded + re-homed work went somewhere that costs bandwidth
    assert r.rerouted > 0
    assert r.uploaded_bytes + r.lan_bytes > 0
    # edge_only failover stays on the surviving edges: LAN traffic only,
    # and peers answer with the CQ model, not ground truth
    r_eo = run_query(sc.with_scheme("edge_only"), items=stream)
    assert len(r_eo.latencies) == len(stream)
    assert r_eo.uploaded_bytes == 0
    assert r_eo.lan_bytes > 0
    assert r_eo.f_score() < 1.0


def test_run_query_adaptive_sheds_under_burst():
    base = Scenario(name="burst-test", edge_speeds=(1.0,), num_cameras=6,
                    duration_s=40.0, burst_boost=9.0, burst_rate=1.5,
                    seed=7)
    stream = synthetic_confidence_stream(base)
    adaptive = run_query(base, items=stream)
    fixed = run_query(base.with_scheme("surveiledge_fixed"), items=stream)
    # the allocator + adaptive thresholds keep the overloaded system's
    # latency below frozen-threshold local-first operation
    assert adaptive.avg_latency < fixed.avg_latency
    assert adaptive.rerouted > 0
