"""int8 weight + KV-cache quantization: roundtrip and accuracy bounds."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.distributed import quantize as QZ
from repro.models import layers as L, meta, transformer as T


def test_quantize_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64, 32)) * 3.0
    q = QZ.quantize_leaf(x, stacked=True)
    back = QZ.dequantize_leaf(q, jnp.float32)
    # symmetric int8: error <= scale/2 per element
    err = jnp.abs(back - x)
    bound = q["s"].reshape(4, 1, 32) / 2 + 1e-6
    assert bool(jnp.all(err <= bound))
    assert q["q"].dtype == jnp.int8
    assert q["s"].shape == (4, 32)      # stacked: per (layer, out-channel)


def test_quantize_tree_skips_norms_and_keeps_scan_axis():
    cfg = get_config("qwen3-8b").reduced()
    params = meta.init_params(cfg, jax.random.PRNGKey(0))
    qp = QZ.quantize_tree(params, cfg)
    # norms stay fp
    assert not isinstance(qp["layers"]["norm1"]["scale"], dict)
    # weights are quantized with leading layer dim intact
    wq = qp["layers"]["attn"]["wq"]
    assert wq["q"].dtype == jnp.int8
    assert wq["q"].shape[0] == cfg.num_layers
    assert wq["s"].shape[0] == cfg.num_layers
    # dequant restores structure
    back = QZ.dequant_tree(qp, jnp.float32)
    assert back["layers"]["attn"]["wq"].shape == params["layers"]["attn"]["wq"].shape


@pytest.mark.parametrize("arch", ["qwen3-8b", "granite-moe-1b-a400m",
                                  "hymba-1.5b"])
def test_int8_weights_forward_close(arch):
    cfg = get_config(arch).reduced()
    params = meta.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.bfloat16)
    qp = QZ.quantize_tree(params, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    want = T.lm_logits(cfg, params, h).astype(jnp.float32)
    hq, _ = T.forward(cfg, qp, tokens)
    got = T.lm_logits(cfg, qp, hq).astype(jnp.float32)
    rel = float(jnp.max(jnp.abs(want - got)) / jnp.max(jnp.abs(want)))
    assert rel < 0.06, rel


def test_int8_kv_cache_decode_close():
    cfg = dataclasses.replace(get_config("qwen3-8b").reduced(),
                              kv_cache_dtype="int8")
    params = meta.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                cfg.vocab_size)
    h, _ = T.forward(cfg, params, tokens)
    want = T.lm_logits(cfg, params, h)[:, -1]
    _, cache = T.prefill(cfg, params, tokens[:, :-1], cache_len=28)
    assert cache["layers"]["k"].dtype == jnp.int8
    got, _ = T.decode_step(cfg, params, cache, tokens[:, -1])
    rel = float(jnp.max(jnp.abs(want - got)) / jnp.max(jnp.abs(want)))
    assert rel < 0.02, rel


def test_quantize_kv_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 4, 16))
    q, s = L.quantize_kv(x)
    back = L.dequantize_kv(q, s, jnp.float32)
    assert float(jnp.max(jnp.abs(back - x))) <= float(jnp.max(s)) / 2 + 1e-5
