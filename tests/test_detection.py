"""Frame-difference detection pipeline: planted objects are found."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.data import synthetic_video as SV
from repro.detection import components, pipeline


def test_motion_mask_finds_moving_object():
    cam = SV.make_cameras(1, seed=3)[0]
    rng = np.random.default_rng(0)
    # force exactly one object
    cam.class_mix = np.eye(SV.NUM_CLASSES)[1]
    cam.base_rate, cam.busy_boost = 1.0, 0.0
    for _ in range(5):
        frames, truth = SV.render_triple(cam, 0.0, rng)
        if len(truth.classes) == 1:
            break
    assert len(truth.classes) >= 1
    mask = pipeline.motion_mask(*(jnp.asarray(frames[i][None]) for i in range(3)))
    assert int((np.asarray(mask) > 0).sum()) > 20   # something moved


def test_label_components_two_blobs():
    m = np.zeros((1, 40, 40), np.int32)
    m[0, 2:8, 2:8] = 255
    m[0, 20:30, 25:35] = 255
    lab = np.asarray(components.label_components(jnp.asarray(m)))
    fg = lab[0][lab[0] >= 0]
    assert len(np.unique(fg)) == 2


def test_extract_boxes_filters_small_and_elongated():
    lab = -np.ones((40, 40), np.int32)
    lab[5:20, 5:20] = 1         # big blob -> kept
    lab[30, 30] = 2             # single pixel -> dropped (min_area)
    lab[35, 2:30] = 3           # 1x28 line -> dropped (aspect)
    boxes = components.extract_boxes(lab, min_area=12, max_aspect=6.0)
    assert len(boxes) == 1
    assert boxes[0].area == 225


def test_detect_end_to_end_crop_shapes():
    cam = SV.make_cameras(1, seed=5)[0]
    cam.base_rate, cam.busy_boost = 2.0, 0.0
    rng = np.random.default_rng(1)
    frames, truth = SV.render_triple(cam, 0.0, rng)
    dets = pipeline.detect(frames, crop=32)
    for d in dets[0]:
        assert d.crop.shape == (32, 32, 3)


def test_detection_recall_on_planted_objects():
    """Most planted sprites should produce a detection (recall-oriented,
    as the paper emphasizes)."""
    cam = SV.make_cameras(1, seed=7)[0]
    cam.base_rate, cam.busy_boost = 1.5, 0.0
    rng = np.random.default_rng(2)
    found, total = 0, 0
    for _ in range(8):
        frames, truth = SV.render_triple(cam, 0.0, rng)
        dets = pipeline.detect(frames)[0]
        total += len(truth.classes)
        for (y, x) in truth.boxes:
            hit = any(abs((d.box.y0 + d.box.y1) / 2 - (y + SV.SPRITE / 2)) < 16
                      and abs((d.box.x0 + d.box.x1) / 2 - (x + SV.SPRITE / 2)) < 16
                      for d in dets)
            found += bool(hit)
    if total == 0:
        pytest.skip("no objects sampled")
    assert found / total > 0.6, (found, total)
