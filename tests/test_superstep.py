"""Scan-superstep differential harness: ``superstep=K`` must be
bit-exact against the ``superstep=1`` per-tick reference driver (and,
for the fixed scheme, against the true pre-superstep legacy loop),
boundary events must SPLIT supersteps rather than be absorbed by them,
sharded execution must match single-device, and the metropolis preset
must actually buy the >= 10x host-loop reduction it exists for."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.kernels.buckets import MAX_FLEET_ROWS
from repro.system import (
    QuerySpec,
    Scenario,
    city_scale,
    drifting_city,
    metropolis,
    multi_query_city,
    run_query,
)

# summary keys that legitimately differ between segmentations of the same
# run: one fused launch replaces many per-tick launches
_LAUNCH_KEYS = ("kernel_launches", "launches_per_tick", "supersteps")


def _strip_launch_keys(summary):
    return {k: v for k, v in summary.items() if k not in _LAUNCH_KEYS}


def _assert_bit_exact(ra, rb):
    """Everything observable except the launch accounting must be
    IDENTICAL — latencies, decisions, truths, query ids, final per-edge
    thresholds, per-query lifecycle facts, and the summary row."""
    np.testing.assert_array_equal(ra.latencies, rb.latencies)
    np.testing.assert_array_equal(ra.decisions, rb.decisions)
    np.testing.assert_array_equal(ra.truths, rb.truths)
    np.testing.assert_array_equal(ra.finish_times, rb.finish_times)
    np.testing.assert_array_equal(ra.query_ids, rb.query_ids)
    assert ra.thresholds == rb.thresholds
    assert ra.queries == rb.queries
    assert _strip_launch_keys(ra.summary()) == _strip_launch_keys(
        rb.summary())


def _pair(base: Scenario, ka, kb):
    ra = run_query(dataclasses.replace(base, superstep=ka))
    rb = run_query(dataclasses.replace(base, superstep=kb))
    return ra, rb


# --- differential: K=1 reference vs K=N fused, per preset ---------------------


def test_city_scale_superstep_bit_exact():
    base = city_scale(duration_s=6.0, num_failures=2, interval_s=0.25)
    ra, rb = _pair(base, 1, 16)
    _assert_bit_exact(ra, rb)
    assert rb.supersteps < ra.supersteps  # fusion actually happened


def test_multi_query_city_superstep_bit_exact():
    base = multi_query_city(duration_s=30.0)
    ra, rb = _pair(base, 1, 25)
    _assert_bit_exact(ra, rb)
    assert rb.supersteps < ra.supersteps


def test_drifting_city_superstep_bit_exact():
    """Calibration deliveries (ModelUpdate) are boundaries: the fused run
    must split at each one so rows see exactly the calibration the
    per-tick driver would have applied."""
    base = drifting_city(duration_s=30.0)
    ra, rb = _pair(base, 1, 10)
    _assert_bit_exact(ra, rb)
    assert rb.model_updates == ra.model_updates > 0


def test_fixed_scheme_superstep_matches_true_legacy():
    """``surveiledge_fixed`` never refreshes thresholds and never sheds,
    so ``superstep=K`` must be bit-exact against ``superstep=None`` —
    the UNTOUCHED pre-superstep per-tick live-signal loop, not just the
    K=1 reference."""
    base = multi_query_city(duration_s=30.0).with_scheme(
        "surveiledge_fixed")
    ra = run_query(dataclasses.replace(base, superstep=None))
    rb = run_query(dataclasses.replace(base, superstep=16))
    _assert_bit_exact(ra, rb)


# --- boundary events split supersteps, never get absorbed ---------------------


@pytest.mark.slow
def test_random_boundaries_split_supersteps_property():
    """Hypothesis property: for random K and random boundary placements
    (edge failures + a query retire landing anywhere in the run, i.e.
    mid-superstep almost surely), ``superstep=K`` stays bit-exact vs the
    K=1 reference.  A superstep that absorbed a boundary instead of
    splitting at it would triage post-boundary ticks with stale
    liveness/calibration state and diverge."""
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    duration = 12.0

    @settings(max_examples=10, deadline=None)
    @given(k=st.integers(min_value=2, max_value=25),
           fail_frac=st.floats(min_value=0.05, max_value=0.95),
           retire_frac=st.floats(min_value=0.05, max_value=0.95),
           seed=st.integers(min_value=0, max_value=3))
    def prop(k, fail_frac, retire_frac, seed):
        base = Scenario(
            name="boundary_prop", num_cameras=8, duration_s=duration,
            interval_s=0.25, edge_speeds=(1.0, 0.5, 1.0),
            edge_service_s=0.04, escalation_capacity=4,
            failures=((duration * fail_frac, 2),),
            queries=(QuerySpec(0, 0.0, None, "surveiledge"),
                     QuerySpec(1, duration * 0.1,
                               duration * retire_frac, "no_finetune")),
            train_step_s=duration / 2000.0, seed=seed)
        _assert_bit_exact(*_pair(base, 1, k))

    prop()


# --- metropolis: scale smoke + determinism + sharding -------------------------


@pytest.fixture(scope="module")
def metro_report():
    """One shrunken metropolis run shared by the scale assertions (the
    full preset is a minutes-long benchmark; 1024 cameras over 12 s keeps
    the >= 1024-edge fleet and the boundary structure)."""
    return run_query(metropolis(num_cameras=1024, duration_s=12.0))


def test_metropolis_host_loop_reduction(metro_report):
    """The acceptance bar: one fused launch per boundary-free run must
    replace >= 10 per-tick host-loop iterations, while the
    one-launch-per-triaged-tick budget stays intact (launches can only
    ever be FEWER than ticks, never more)."""
    r = metro_report
    assert r.supersteps > 0
    assert r.triaged_ticks / r.supersteps >= 10.0
    assert r.kernel_launches <= r.triaged_ticks
    assert r.summary()["launches_per_tick"] <= 1.0


def test_metropolis_streams_report_aggregates(metro_report):
    """Streaming aggregates replace the per-item arrays: O(window)
    report memory with the item count still legible via ``n_items``."""
    r = metro_report
    assert len(r.latencies) == 0 and len(r.decisions) == 0
    assert r.stream is not None and r.n_items == r.stream.n > 0
    assert 0.0 < r.summary()["accuracy_F2"] <= 1.0
    rows = r.accuracy_timeline()
    assert rows and sum(row["n"] for row in rows) == r.n_items
    per_q = r.per_query_summary()
    assert len(per_q) >= 12  # dozens of concurrent CQs is the point
    assert sum(row["n_items"] for row in per_q.values()) == r.n_items


def test_metropolis_determinism_same_seed(metro_report):
    """Two same-seed runs produce byte-identical reports — the fused
    scan + shard_map path must not introduce any run-to-run jitter."""
    again = run_query(metropolis(num_cameras=1024, duration_s=12.0))
    assert again.summary() == metro_report.summary()
    assert again.per_query_summary() == metro_report.per_query_summary()
    assert again.accuracy_timeline() == metro_report.accuracy_timeline()
    assert again.thresholds == metro_report.thresholds


@pytest.mark.skipif(
    jax.device_count() < 8,
    reason="sharded-vs-single-device equivalence needs >= 8 devices "
           "(run under XLA_FLAGS=--xla_force_host_platform_device_count=8"
           ", see `make test-sharded`)")
def test_metropolis_sharded_matches_single_device(metro_report):
    """With >= 8 host devices, the ``shard_fleet`` row-axis shard_map
    must be bit-exact vs the single-device program (rows are independent
    — shard-local execution IS the semantics)."""
    solo = run_query(metropolis(num_cameras=1024, duration_s=12.0,
                                shard_fleet=False))
    assert solo.summary() == metro_report.summary()
    assert solo.per_query_summary() == metro_report.per_query_summary()
    assert solo.thresholds == metro_report.thresholds


# --- config validation against the kernel bucket table ------------------------


def test_scenario_rejects_empty_edge_fleet():
    with pytest.raises(ValueError, match="at least one edge"):
        Scenario(name="bad", edge_speeds=())


def test_scenario_rejects_zero_escalation_capacity():
    with pytest.raises(ValueError, match="escalation_capacity"):
        Scenario(name="bad", escalation_capacity=0)


def test_scenario_rejects_fleet_over_bucket_table():
    queries = tuple(QuerySpec(q, 0.0, None, "no_finetune")
                    for q in range(64))
    with pytest.raises(ValueError, match="bucket table"):
        Scenario(name="bad", edge_speeds=(1.0,) * (MAX_FLEET_ROWS // 16),
                 queries=queries)


def test_scenario_rejects_bad_superstep():
    with pytest.raises(ValueError, match="superstep"):
        Scenario(name="bad", superstep=0)


def test_scenario_rejects_bad_metrics_window():
    with pytest.raises(ValueError, match="metrics_window_s"):
        Scenario(name="bad", metrics_window_s=0.0)
