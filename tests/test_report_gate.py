"""The CI report regression gate: tolerance-band math, structural
breaches, and the acceptance-criteria negative test (a synthetic -0.1 F2
perturbation must fail the gate)."""
import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from report_gate import compare_report, gate  # noqa: E402


def _doc():
    return {
        "scenario": "toy",
        "frontend": "confidence",
        "schemes": {
            "surveiledge": {
                "accuracy_F2": 0.90,
                "avg_latency_s": 2.0,
                "p99_latency_s": 8.0,
                "bandwidth_MB": 10.0,
                "lan_MB": 4.0,
                "downloaded_MB": 1.0,
                "queries": {
                    "0": {"f2": 0.95, "avg_latency_s": 1.5},
                    "1": {"f2": 0.85, "avg_latency_s": 3.0},
                },
            },
            "cloud_only": {
                "accuracy_F2": 0.99,
                "avg_latency_s": 12.0,
                "p99_latency_s": 40.0,
                "bandwidth_MB": 90.0,
                "lan_MB": 0.0,
                "downloaded_MB": 0.0,
            },
        },
    }


def test_identical_reports_pass():
    assert compare_report(_doc(), _doc()) == []


def test_f2_regression_breaches():
    """The acceptance criterion's negative test: -0.1 absolute F2 is
    double the +/-0.05 band and must breach."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0] and "surveiledge" in breaches[0]


def test_f2_within_band_passes():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.04
    assert compare_report(_doc(), fresh) == []


def test_latency_and_bandwidth_relative_bands():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["avg_latency_s"] *= 1.20   # inside 25%
    fresh["schemes"]["cloud_only"]["bandwidth_MB"] *= 0.80
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["avg_latency_s"] = 12.0 * 1.30
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "avg_latency_s" in breaches[0]


def test_near_zero_baseline_uses_absolute_floor():
    """lan_MB baseline 0.0: a 0.04 MB wobble sits under the floor, a
    0.5 MB jump does not."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.04
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.5
    assert any("lan_MB" in b for b in compare_report(_doc(), fresh))


def test_per_query_rows_are_gated():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["queries"]["1"]["f2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "/q1" in breaches[0]
    # a dropped per-query row is structural, not silent
    del fresh["schemes"]["surveiledge"]["queries"]["1"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_missing_scheme_breaches():
    fresh = copy.deepcopy(_doc())
    del fresh["schemes"]["cloud_only"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_gate_dir_pairing(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    assert gate(str(fresh_dir), str(base_dir)) == []
    # a fresh report with no committed baseline is a breach...
    (fresh_dir / "new-confidence.json").write_text(json.dumps(_doc()))
    assert any("no committed baseline" in b
               for b in gate(str(fresh_dir), str(base_dir)))
    # ... and so is a stale baseline with no fresh run
    os.remove(fresh_dir / "new-confidence.json")
    (base_dir / "old-confidence.json").write_text(json.dumps(_doc()))
    assert any("no fresh run" in b for b in gate(str(fresh_dir),
                                                 str(base_dir)))


def test_gate_end_to_end_perturbation(tmp_path):
    """Dir-level negative test: one perturbed metric in one file fails the
    whole gate with a pointed message."""
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    bad = _doc()
    bad["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(bad))
    breaches = gate(str(fresh_dir), str(base_dir))
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0]
