"""The CI report regression gate: tolerance-band math, structural
breaches, and the acceptance-criteria negative test (a synthetic -0.1 F2
perturbation must fail the gate)."""
import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from report_gate import compare_report, gate  # noqa: E402


def _doc():
    return {
        "scenario": "toy",
        "frontend": "confidence",
        "schemes": {
            "surveiledge": {
                "accuracy_F2": 0.90,
                "avg_latency_s": 2.0,
                "p99_latency_s": 8.0,
                "bandwidth_MB": 10.0,
                "lan_MB": 4.0,
                "downloaded_MB": 1.0,
                "queries": {
                    "0": {"f2": 0.95, "avg_latency_s": 1.5},
                    "1": {"f2": 0.85, "avg_latency_s": 3.0},
                },
            },
            "cloud_only": {
                "accuracy_F2": 0.99,
                "avg_latency_s": 12.0,
                "p99_latency_s": 40.0,
                "bandwidth_MB": 90.0,
                "lan_MB": 0.0,
                "downloaded_MB": 0.0,
            },
        },
    }


def test_identical_reports_pass():
    assert compare_report(_doc(), _doc()) == []


def test_f2_regression_breaches():
    """The acceptance criterion's negative test: -0.1 absolute F2 is
    double the +/-0.05 band and must breach."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0] and "surveiledge" in breaches[0]


def test_f2_within_band_passes():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.04
    assert compare_report(_doc(), fresh) == []


def test_latency_and_bandwidth_relative_bands():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["avg_latency_s"] *= 1.20   # inside 25%
    fresh["schemes"]["cloud_only"]["bandwidth_MB"] *= 0.80
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["avg_latency_s"] = 12.0 * 1.30
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "avg_latency_s" in breaches[0]


def test_near_zero_baseline_uses_absolute_floor():
    """lan_MB baseline 0.0: a 0.04 MB wobble sits under the floor, a
    0.5 MB jump does not."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.04
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.5
    assert any("lan_MB" in b for b in compare_report(_doc(), fresh))


def test_per_query_rows_are_gated():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["queries"]["1"]["f2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "/q1" in breaches[0]
    # a dropped per-query row is structural, not silent
    del fresh["schemes"]["surveiledge"]["queries"]["1"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_missing_scheme_breaches():
    fresh = copy.deepcopy(_doc())
    del fresh["schemes"]["cloud_only"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_gate_dir_pairing(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    assert gate(str(fresh_dir), str(base_dir)) == []
    # a fresh report with no committed baseline is a breach...
    (fresh_dir / "new-confidence.json").write_text(json.dumps(_doc()))
    assert any("no committed baseline" in b
               for b in gate(str(fresh_dir), str(base_dir)))
    # ... and so is a stale baseline with no fresh run
    os.remove(fresh_dir / "new-confidence.json")
    (base_dir / "old-confidence.json").write_text(json.dumps(_doc()))
    assert any("no fresh run" in b for b in gate(str(fresh_dir),
                                                 str(base_dir)))


def test_gate_end_to_end_perturbation(tmp_path):
    """Dir-level negative test: one perturbed metric in one file fails the
    whole gate with a pointed message."""
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    bad = _doc()
    bad["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(bad))
    breaches = gate(str(fresh_dir), str(base_dir))
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0]


# --- kernel-bench gate (BENCH_pixel_cascade.json) ----------------------------

from report_gate import bench_gate  # noqa: E402


def _bench_doc():
    return {
        "pallas_compiled_available": False,
        "interpret_knob": True,
        "shapes": {
            "B4_96x128": {
                "rows": {
                    "staged_interpret": {"us_per_call": 2000.0,
                                         "Mpx_s": 24.0,
                                         "substrate": "pallas_interpret",
                                         "pallas_launches": 3},
                    "fused_compiled": {"us_per_call": 500.0, "Mpx_s": 98.0,
                                       "substrate": "xla_ref",
                                       "pallas_launches": 0},
                },
            },
        },
    }


def _bench_pair(tmp_path, base, fresh):
    bp = tmp_path / "base.json"
    fp = tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    return str(fp), str(bp)


def test_bench_identical_passes(tmp_path):
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), _bench_doc())) == []


def test_bench_throughput_regression_breaches(tmp_path):
    """The acceptance band: >30% slower must breach."""
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 60.0
    breaches = bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh))
    assert len(breaches) == 1 and "throughput" in breaches[0]


def test_bench_gate_is_one_sided(tmp_path):
    """Getting faster (even 10x) never breaches — regressions only."""
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 980.0
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh)) == []


def test_bench_small_slowdown_within_band_passes(tmp_path):
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 70.0
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh)) == []


def test_bench_substrate_flip_breaches(tmp_path):
    """Interpret baseline vs newly-compiled fresh run must be re-blessed,
    not silently absorbed by the band."""
    fresh = copy.deepcopy(_bench_doc())
    row = fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]
    row["substrate"] = "pallas_compiled"
    row["Mpx_s"] = 500.0
    breaches = bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh))
    assert len(breaches) == 1 and "substrate" in breaches[0]


def test_bench_missing_shape_and_row_breach(tmp_path):
    fresh = copy.deepcopy(_bench_doc())
    del fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]
    base = copy.deepcopy(_bench_doc())
    base["shapes"]["B8_64x64"] = {"rows": {}}
    breaches = bench_gate(*_bench_pair(tmp_path, base, fresh))
    assert any("missing from fresh" in b for b in breaches)
    assert any("B8_64x64" in b for b in breaches)


def test_bench_gate_on_committed_baseline():
    """The committed BENCH_pixel_cascade.json gates cleanly against
    itself and satisfies the acceptance bar: every shape's fused
    compiled throughput >= 2x its staged interpret baseline."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_pixel_cascade.json")
    assert bench_gate(path, path) == []
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["shapes"], "committed bench must not be empty"
    for key, shape in doc["shapes"].items():
        rows = shape["rows"]
        assert rows["fused_interpret"]["pallas_launches"] == 1
        assert rows["staged_interpret"]["pallas_launches"] == 3
        ratio = (rows["fused_compiled"]["Mpx_s"]
                 / rows["staged_interpret"]["Mpx_s"])
        assert ratio >= 2.0, (key, ratio)
        assert "roofline_fraction" in shape["roofline"]["fused"]


# --- bandwidth-endgame columns + fresh-row consistency ------------------------

from report_gate import (  # noqa: E402
    Check,
    TOLERANCES,
    row_consistency,
    write_summary_md,
)


def test_downlink_columns_are_gated():
    """The new bandwidth columns sit under tolerance bands like the
    legacy ones: fp-reference drift and flip-rate drift both breach."""
    assert "downlink_fp_MB" in TOLERANCES
    assert "uplink_bytes_per_TP" in TOLERANCES
    assert "reconciliation_flip_rate" in TOLERANCES
    assert "provisional_latency_s" in TOLERANCES
    base = _doc()
    row = base["schemes"]["surveiledge"]
    row.update(downlink_fp_MB=4.0, reconciliation_flip_rate=0.02,
               provisional_latency_s=1.0, uplink_bytes_per_TP=50000.0)
    fresh = copy.deepcopy(base)
    fresh["schemes"]["surveiledge"]["downlink_fp_MB"] = 8.0   # +100%
    breaches = compare_report(base, fresh)
    assert len(breaches) == 1 and "downlink_fp_MB" in breaches[0]
    fresh = copy.deepcopy(base)
    fresh["schemes"]["surveiledge"]["reconciliation_flip_rate"] = 0.3
    breaches = compare_report(base, fresh)
    assert len(breaches) == 1 and "reconciliation_flip_rate" in breaches[0]
    # within-band wobbles pass
    fresh = copy.deepcopy(base)
    fresh["schemes"]["surveiledge"]["reconciliation_flip_rate"] = 0.05
    fresh["schemes"]["surveiledge"]["downlink_fp_MB"] = 4.5
    assert compare_report(base, fresh) == []


def test_row_consistency_updates_without_downlink():
    bad = {"model_updates": 3, "downloaded_MB": 0.0, "downloaded_bytes": 0}
    msgs = row_consistency("toy/surveiledge", bad)
    assert len(msgs) == 1 and "zero downlink" in msgs[0]
    ok = {"model_updates": 3, "downloaded_bytes": 24}
    assert row_consistency("toy/surveiledge", ok) == []


def test_row_consistency_quantized_exceeding_fp_fails():
    """Satellite bugfix: model_updates > 0 with quantized bytes LARGER
    than the row's fp reference is a wire-accounting bug, not drift."""
    bad = {"model_updates": 2, "downloaded_bytes": 5000,
           "downlink_fp_bytes": 4000}
    msgs = row_consistency("toy/surveiledge", bad)
    assert len(msgs) == 1 and "fp-equivalent" in msgs[0]
    ok = {"model_updates": 2, "downloaded_bytes": 1300,
          "downlink_fp_bytes": 4000}
    assert row_consistency("toy/surveiledge", ok) == []


def test_gate_fails_on_quantized_exceeding_fp_end_to_end():
    """compare_report applies the consistency check to FRESH rows even
    when the baseline pair is otherwise within tolerance."""
    base = _doc()
    fresh = copy.deepcopy(base)
    fresh["schemes"]["surveiledge"].update(
        model_updates=2, downloaded_bytes=5000, downlink_fp_bytes=4000)
    breaches = compare_report(base, fresh)
    assert any("fp-equivalent" in b for b in breaches)


# --- --summary-md verdict table ----------------------------------------------


def test_summary_md_lists_failures_before_passes(tmp_path):
    checks = []
    base = _doc()
    fresh = copy.deepcopy(base)
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.2
    compare_report(base, fresh, checks)
    assert any(not c.ok for c in checks)
    assert any(c.ok for c in checks)
    out = tmp_path / "summary.md"
    write_summary_md(str(out), checks)
    text = out.read_text()
    assert "accuracy_F2" in text
    assert text.index("❌") < text.index("<details>")
    assert "✅" in text and "| artifact |" in text
    # appends (GITHUB_STEP_SUMMARY semantics), never truncates
    write_summary_md(str(out), checks)
    assert len(out.read_text()) > len(text)


def test_summary_md_records_passing_metrics_too(tmp_path):
    checks = []
    compare_report(_doc(), _doc(), checks)
    assert checks and all(c.ok for c in checks)
    out = tmp_path / "summary.md"
    write_summary_md(str(out), checks)
    text = out.read_text()
    assert "0 breach(es)" in text and "❌" not in text


def test_bench_gate_collects_checks(tmp_path):
    checks = []
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 60.0
    bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh), checks=checks)
    bad = [c for c in checks if not c.ok]
    assert len(bad) == 1 and bad[0].metric == "Mpx_s"
    assert isinstance(bad[0], Check) and bad[0].tol.endswith("one-sided")


# --- --bench-substrate filter (PR-time CPU runner) ---------------------------


def test_bench_substrate_filter_skips_other_substrates(tmp_path):
    """A regression in an xla_ref (compiled-tier) row must NOT fail a
    gate restricted to pallas_interpret rows — compiled rows remain
    nightly/TPU business on a PR CPU runner."""
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 10.0
    pair = _bench_pair(tmp_path, _bench_doc(), fresh)
    assert bench_gate(*pair, substrates=["pallas_interpret"]) == []
    assert bench_gate(*pair) != []           # unfiltered still catches it


def test_bench_substrate_filter_still_gates_matching_rows(tmp_path):
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["staged_interpret"]["Mpx_s"] = 1.0
    pair = _bench_pair(tmp_path, _bench_doc(), fresh)
    breaches = bench_gate(*pair, substrates=["pallas_interpret"])
    assert len(breaches) == 1 and "staged_interpret" in breaches[0]
