"""The CI report regression gate: tolerance-band math, structural
breaches, and the acceptance-criteria negative test (a synthetic -0.1 F2
perturbation must fail the gate)."""
import copy
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                "benchmarks"))

from report_gate import compare_report, gate  # noqa: E402


def _doc():
    return {
        "scenario": "toy",
        "frontend": "confidence",
        "schemes": {
            "surveiledge": {
                "accuracy_F2": 0.90,
                "avg_latency_s": 2.0,
                "p99_latency_s": 8.0,
                "bandwidth_MB": 10.0,
                "lan_MB": 4.0,
                "downloaded_MB": 1.0,
                "queries": {
                    "0": {"f2": 0.95, "avg_latency_s": 1.5},
                    "1": {"f2": 0.85, "avg_latency_s": 3.0},
                },
            },
            "cloud_only": {
                "accuracy_F2": 0.99,
                "avg_latency_s": 12.0,
                "p99_latency_s": 40.0,
                "bandwidth_MB": 90.0,
                "lan_MB": 0.0,
                "downloaded_MB": 0.0,
            },
        },
    }


def test_identical_reports_pass():
    assert compare_report(_doc(), _doc()) == []


def test_f2_regression_breaches():
    """The acceptance criterion's negative test: -0.1 absolute F2 is
    double the +/-0.05 band and must breach."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0] and "surveiledge" in breaches[0]


def test_f2_within_band_passes():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["accuracy_F2"] -= 0.04
    assert compare_report(_doc(), fresh) == []


def test_latency_and_bandwidth_relative_bands():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["avg_latency_s"] *= 1.20   # inside 25%
    fresh["schemes"]["cloud_only"]["bandwidth_MB"] *= 0.80
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["avg_latency_s"] = 12.0 * 1.30
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "avg_latency_s" in breaches[0]


def test_near_zero_baseline_uses_absolute_floor():
    """lan_MB baseline 0.0: a 0.04 MB wobble sits under the floor, a
    0.5 MB jump does not."""
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.04
    assert compare_report(_doc(), fresh) == []
    fresh["schemes"]["cloud_only"]["lan_MB"] = 0.5
    assert any("lan_MB" in b for b in compare_report(_doc(), fresh))


def test_per_query_rows_are_gated():
    fresh = copy.deepcopy(_doc())
    fresh["schemes"]["surveiledge"]["queries"]["1"]["f2"] -= 0.1
    breaches = compare_report(_doc(), fresh)
    assert len(breaches) == 1 and "/q1" in breaches[0]
    # a dropped per-query row is structural, not silent
    del fresh["schemes"]["surveiledge"]["queries"]["1"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_missing_scheme_breaches():
    fresh = copy.deepcopy(_doc())
    del fresh["schemes"]["cloud_only"]
    assert any("missing" in b for b in compare_report(_doc(), fresh))


def test_gate_dir_pairing(tmp_path):
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    assert gate(str(fresh_dir), str(base_dir)) == []
    # a fresh report with no committed baseline is a breach...
    (fresh_dir / "new-confidence.json").write_text(json.dumps(_doc()))
    assert any("no committed baseline" in b
               for b in gate(str(fresh_dir), str(base_dir)))
    # ... and so is a stale baseline with no fresh run
    os.remove(fresh_dir / "new-confidence.json")
    (base_dir / "old-confidence.json").write_text(json.dumps(_doc()))
    assert any("no fresh run" in b for b in gate(str(fresh_dir),
                                                 str(base_dir)))


def test_gate_end_to_end_perturbation(tmp_path):
    """Dir-level negative test: one perturbed metric in one file fails the
    whole gate with a pointed message."""
    base_dir, fresh_dir = tmp_path / "base", tmp_path / "fresh"
    base_dir.mkdir(), fresh_dir.mkdir()
    (base_dir / "toy-confidence.json").write_text(json.dumps(_doc()))
    bad = _doc()
    bad["schemes"]["surveiledge"]["accuracy_F2"] -= 0.1
    (fresh_dir / "toy-confidence.json").write_text(json.dumps(bad))
    breaches = gate(str(fresh_dir), str(base_dir))
    assert len(breaches) == 1
    assert "accuracy_F2" in breaches[0]


# --- kernel-bench gate (BENCH_pixel_cascade.json) ----------------------------

from report_gate import bench_gate  # noqa: E402


def _bench_doc():
    return {
        "pallas_compiled_available": False,
        "interpret_knob": True,
        "shapes": {
            "B4_96x128": {
                "rows": {
                    "staged_interpret": {"us_per_call": 2000.0,
                                         "Mpx_s": 24.0,
                                         "substrate": "pallas_interpret",
                                         "pallas_launches": 3},
                    "fused_compiled": {"us_per_call": 500.0, "Mpx_s": 98.0,
                                       "substrate": "xla_ref",
                                       "pallas_launches": 0},
                },
            },
        },
    }


def _bench_pair(tmp_path, base, fresh):
    bp = tmp_path / "base.json"
    fp = tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    return str(fp), str(bp)


def test_bench_identical_passes(tmp_path):
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), _bench_doc())) == []


def test_bench_throughput_regression_breaches(tmp_path):
    """The acceptance band: >30% slower must breach."""
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 60.0
    breaches = bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh))
    assert len(breaches) == 1 and "throughput" in breaches[0]


def test_bench_gate_is_one_sided(tmp_path):
    """Getting faster (even 10x) never breaches — regressions only."""
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 980.0
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh)) == []


def test_bench_small_slowdown_within_band_passes(tmp_path):
    fresh = copy.deepcopy(_bench_doc())
    fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]["Mpx_s"] = 70.0
    assert bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh)) == []


def test_bench_substrate_flip_breaches(tmp_path):
    """Interpret baseline vs newly-compiled fresh run must be re-blessed,
    not silently absorbed by the band."""
    fresh = copy.deepcopy(_bench_doc())
    row = fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]
    row["substrate"] = "pallas_compiled"
    row["Mpx_s"] = 500.0
    breaches = bench_gate(*_bench_pair(tmp_path, _bench_doc(), fresh))
    assert len(breaches) == 1 and "substrate" in breaches[0]


def test_bench_missing_shape_and_row_breach(tmp_path):
    fresh = copy.deepcopy(_bench_doc())
    del fresh["shapes"]["B4_96x128"]["rows"]["fused_compiled"]
    base = copy.deepcopy(_bench_doc())
    base["shapes"]["B8_64x64"] = {"rows": {}}
    breaches = bench_gate(*_bench_pair(tmp_path, base, fresh))
    assert any("missing from fresh" in b for b in breaches)
    assert any("B8_64x64" in b for b in breaches)


def test_bench_gate_on_committed_baseline():
    """The committed BENCH_pixel_cascade.json gates cleanly against
    itself and satisfies the acceptance bar: every shape's fused
    compiled throughput >= 2x its staged interpret baseline."""
    path = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                        "BENCH_pixel_cascade.json")
    assert bench_gate(path, path) == []
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["shapes"], "committed bench must not be empty"
    for key, shape in doc["shapes"].items():
        rows = shape["rows"]
        assert rows["fused_interpret"]["pallas_launches"] == 1
        assert rows["staged_interpret"]["pallas_launches"] == 3
        ratio = (rows["fused_compiled"]["Mpx_s"]
                 / rows["staged_interpret"]["Mpx_s"])
        assert ratio >= 2.0, (key, ratio)
        assert "roofline_fraction" in shape["roofline"]["fused"]
