"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# hypothesis-heavy: runs hundreds of examples per property, so the module
# lives in the slow tier (`make test-slow` / the non-blocking CI job)
pytestmark = pytest.mark.slow

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (pip install -r "
           "requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core import cascade as C
from repro.core import latency as LT
from repro.core.thresholds import ThresholdState
from repro.kernels import ops, ref

fin = dict(allow_nan=False, allow_infinity=False)


@given(st.floats(1e-3, 1e3, **fin), st.floats(1e-3, 1e3, **fin))
def test_adaptive_mean_convex(a, b):
    m = LT.adaptive_mean(a, b)
    assert min(a, b) - 1e-9 <= m <= max(a, b) + 1e-9


@given(st.lists(st.tuples(st.integers(0, 100), st.floats(0, 10, **fin)),
                min_size=1, max_size=60))
def test_threshold_invariants_under_any_load_sequence(seq):
    th = ThresholdState()
    for q, t in seq:
        th = th.update(q, t, 1.0)
        assert 0.5 <= th.alpha <= 1.0
        assert 0.0 <= th.beta < 0.5
        # triage is total: every confidence maps to exactly one region
        for c in (0.0, th.beta, (th.alpha + th.beta) / 2, th.alpha, 1.0):
            assert th.triage(c) in ("accept", "reject", "escalate")


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 300), st.floats(0, 1, **fin), st.floats(0, 1, **fin),
       st.integers(1, 64), st.integers(0, 2 ** 31 - 1))
def test_triage_compaction_properties(n, a, b, cap, seed):
    alpha, beta = max(a, b), min(a, b)
    conf = jax.random.uniform(jax.random.PRNGKey(seed), (n,))
    routes, slots, count = ref.triage_ref(conf, alpha, beta, cap)
    routes, slots = np.asarray(routes), np.asarray(slots)
    esc_idx = np.flatnonzero(routes == 2)
    # count is exact
    assert int(count) == len(esc_idx)
    # slots are a stable, dense prefix of [0, cap)
    got = slots[slots >= 0]
    assert list(got) == list(range(min(len(esc_idx), cap)))
    # non-escalated items never get a slot
    assert np.all(slots[routes != 2] == -1)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 2), st.integers(8, 48), st.integers(8, 48),
       st.integers(0, 2 ** 31 - 1))
def test_morphology_order_properties(b, h, w, seed):
    x = (jax.random.uniform(jax.random.PRNGKey(seed), (b, h, w)) > 0.6
         ).astype(jnp.int32) * 255
    d = ops.dilate3x3(x, use_pallas=False)
    e = ops.erode3x3(x, use_pallas=False)
    # extensivity / anti-extensivity
    assert bool(jnp.all(d >= x))
    assert bool(jnp.all(e <= x))
    # duality on binary masks: erode(x) == 255 - dilate(255 - x)
    dual = 255 - np.asarray(ops.dilate3x3(255 - x, use_pallas=False))
    np.testing.assert_array_equal(np.asarray(e), dual)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 250))
def test_framediff_static_scene_is_silent(seed, thresh):
    """No motion => empty mask regardless of threshold (property: the
    detector never hallucinates on identical frames)."""
    f = jax.random.randint(jax.random.PRNGKey(seed), (1, 32, 128, 3), 0, 256)
    mask = ops.framediff(f, f, f, threshold=thresh, use_pallas=False)
    assert int(jnp.sum(mask)) == 0


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 40), st.integers(0, 2 ** 31 - 1))
def test_compact_escalated_is_injective(n, seed):
    conf = jax.random.uniform(jax.random.PRNGKey(seed), (n,))
    routes = C.triage(conf, jnp.float32(0.7), jnp.float32(0.2))
    idx, valid, cnt = C.compact_escalated(routes, capacity=n)
    idx, valid = np.asarray(idx), np.asarray(valid)
    taken = idx[valid]
    assert len(np.unique(taken)) == len(taken)          # no duplicates
    assert all(routes[i] == C.ESCALATE for i in taken)  # only escalated
