"""Flash-attention Pallas kernel vs unfused oracle: shape/dtype/GQA sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _qkv(key, B, H, KV, Sq, Sk, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, H, Sq, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, Sk, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, Sk, hd), dtype)
    return q, k, v


@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 2, 2, 128, 32),      # MHA, exact blocks
    (2, 4, 2, 256, 64),      # GQA 2:1
    (1, 8, 2, 128, 32),      # GQA 4:1
    (1, 2, 1, 192, 16),      # padding needed (192 % 128 != 0)
])
def test_flash_matches_ref_causal(B, H, KV, S, hd):
    q, k, v = _qkv(jax.random.PRNGKey(0), B, H, KV, S, S, hd)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_noncausal():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 2, 128, 128, 32)
    got = ops.flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    want = ref.mha_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_cross_lengths():
    """Sq != Sk (query chunk against a longer KV cache)."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 4, 4, 64, 256, 32)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 2, 2, 128, 128, 32, jnp.bfloat16)
    got = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_flash_impl_equivalent_in_model():
    """cfg.attn_impl='flash' must be numerically equivalent to 'chunked'."""
    import dataclasses
    from repro.configs import get_config
    from repro.models import meta, transformer as T
    cfg = get_config("qwen3-8b").reduced()
    params = meta.init_params(cfg, jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                cfg.vocab_size)
    h1, _ = T.forward(cfg, params, tokens)
    h2, _ = T.forward(dataclasses.replace(cfg, attn_impl="flash"),
                      params, tokens)
    assert float(jnp.max(jnp.abs(h1 - h2))) < 1e-5


def test_flash_causality_property():
    """Perturbing a future key must not change earlier outputs."""
    q, k, v = _qkv(jax.random.PRNGKey(4), 1, 2, 2, 128, 128, 32)
    base = np.asarray(ops.flash_attention(q, k, v, causal=True,
                                          block_q=64, block_k=64))
    k2 = k.at[:, :, -1, :].set(99.0)
    v2 = v.at[:, :, -1, :].set(-99.0)
    pert = np.asarray(ops.flash_attention(q, k2, v2, causal=True,
                                          block_q=64, block_k=64))
    np.testing.assert_allclose(base[:, :, :-1], pert[:, :, :-1],
                               atol=1e-6, rtol=1e-6)
    assert not np.allclose(base[:, :, -1], pert[:, :, -1])