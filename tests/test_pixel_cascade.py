"""Fused pixel-cascade kernel: bit-exactness, launch budget, compiled mode.

The fused kernel's contract is strict equality: fused == staged (three
separate Pallas launches) == the independent NumPy oracle, over every
frame size / threshold / bucket-padding placement.  On top of that, the
launch-budget acceptance — a pixel_city tick's whole framediff ->
morphology -> score chain in <= 2 Pallas launches — is asserted with a
monkeypatched launch counter, and a compiled-mode (interpret=False)
parity test runs wherever the backend can lower Pallas (skips cleanly on
CPU, runs for real under ``REPRO_PALLAS_INTERPRET=0`` on TPU — the
``tier1-compiled`` CI job).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import pallas as pallas_mod

from repro.data import synthetic_video as SV
from repro.detection import components, pipeline as DP
from repro.kernels import ops, ref
from repro.kernels import pixel_cascade as PC
from repro.kernels.buckets import (MAX_FRAME_ELEMS, MIN_FRAME_SIDE,
                                   validate_frame_hw)
from repro.kernels.runtime import compiled_available, interpret_default
from repro.system.scenario import Scenario, pixel_city

# Pallas-launching tests need either interpret mode (the repo default) or
# a backend that can lower compiled Pallas; under REPRO_PALLAS_INTERPRET=0
# on plain CPU (the tier1-compiled job on a CPU runner) they skip cleanly.
needs_lowering = pytest.mark.skipif(
    not interpret_default() and not compiled_available(),
    reason="REPRO_PALLAS_INTERPRET=0 but this backend cannot lower "
           "compiled Pallas (CPU) — compiled tier runs on TPU runtimes")


def _frames(rng, B, H, W):
    return rng.integers(0, 256, (3, B, H, W, 3)).astype(np.int32)


def _assert_cascade_exact(fs, threshold=40):
    f0, f1, f2 = (jnp.asarray(fs[i]) for i in range(3))
    mask_f, cnt_f = ops.pixel_cascade(f0, f1, f2, threshold=threshold)
    mask_s, cnt_s = ops.pixel_cascade(f0, f1, f2, threshold=threshold,
                                      fused=False)
    mask_np, cnt_np = ref.pixel_cascade_np(fs[0], fs[1], fs[2], threshold)
    np.testing.assert_array_equal(np.asarray(mask_f), np.asarray(mask_s))
    np.testing.assert_array_equal(np.asarray(mask_f), mask_np)
    np.testing.assert_array_equal(np.asarray(cnt_f), np.asarray(cnt_s))
    np.testing.assert_array_equal(np.asarray(cnt_f), cnt_np)


# --- bit-exactness: fused == staged == independent NumPy oracle --------------


@needs_lowering
def test_fused_matches_staged_and_oracle_fixed_shapes():
    """Default camera frame, band-exact, sub-band, and non-lane widths."""
    rng = np.random.default_rng(0)
    for (B, H, W) in [(2, 96, 128), (1, 32, 128), (1, 33, 40),
                      (3, 16, 300), (2, 100, 96), (1, 64, 129)]:
        _assert_cascade_exact(_frames(rng, B, H, W))


@needs_lowering
def test_fused_seeded_shape_sweep():
    """Seeded sweep over bucket-padding placements: H straddling band
    multiples, W straddling lane multiples, thresholds across the range.
    Always runs (no hypothesis dependency)."""
    rng = np.random.default_rng(7)
    for _ in range(12):
        H = int(rng.integers(16, 140))
        W = int(rng.integers(16, 280))
        B = int(rng.integers(1, 4))
        thr = int(rng.integers(0, 250))
        _assert_cascade_exact(_frames(rng, B, H, W), threshold=thr)


def test_fused_property_hypothesis():
    """Hypothesis property over random frame sizes, thresholds, and
    padding placements (skips where hypothesis isn't installed — the
    seeded sweep above keeps the coverage)."""
    if not interpret_default() and not compiled_available():
        pytest.skip("no Pallas lowering on this backend")
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.integers(16, 130), st.integers(16, 260),
           st.integers(1, 3), st.integers(0, 254), st.integers(0, 2**31 - 1))
    def prop(H, W, B, thr, seed):
        rng = np.random.default_rng(seed)
        _assert_cascade_exact(_frames(rng, B, H, W), threshold=thr)

    prop()


@needs_lowering
def test_sparse_motion_counts():
    """Counts equal the true foreground population on a nearly-static
    scene (one moving block), including a camera with zero motion."""
    B, H, W = 2, 96, 128
    base = np.full((B, H, W, 3), 30, np.int32)
    f0, f1, f2 = base.copy(), base.copy(), base.copy()
    # camera 0: a block whose framediff survives the AND of both diffs
    f1[0, 40:56, 60:76] = 200
    mask_f, cnt_f = ops.pixel_cascade(*(jnp.asarray(x)
                                        for x in (f0, f1, f2)))
    mask_np, cnt_np = ref.pixel_cascade_np(f0, f1, f2, 40)
    np.testing.assert_array_equal(np.asarray(mask_f), mask_np)
    np.testing.assert_array_equal(np.asarray(cnt_f), cnt_np)
    assert int(cnt_f[1]) == 0


# --- compiled mode -----------------------------------------------------------


@pytest.mark.skipif(not compiled_available(),
                    reason="backend cannot lower compiled Pallas (CPU "
                           "supports interpret only)")
def test_compiled_fused_matches_oracle():
    """interpret=False fused launch, bit-exact vs the NumPy oracle."""
    rng = np.random.default_rng(3)
    fs = _frames(rng, 2, 96, 128)
    f0, f1, f2 = (PC.pad_frames(jnp.asarray(fs[i])) for i in range(3))
    mask, counts = PC._cascade_call(f0, f1, f2, threshold=40, maxval=255,
                                    true_hw=(96, 128), interpret=False)
    mask_np, cnt_np = ref.pixel_cascade_np(fs[0], fs[1], fs[2], 40)
    np.testing.assert_array_equal(np.asarray(mask)[:, :96, :128], mask_np)
    np.testing.assert_array_equal(np.asarray(counts).sum(axis=1), cnt_np)


# --- launch budget -----------------------------------------------------------


@needs_lowering
def test_pixel_tick_launch_budget(monkeypatch):
    """A pixel tick's framediff->morphology chain is ONE fused Pallas
    launch (<= 2 is the acceptance bar; score_crops is a jit'd model
    apply, not a Pallas program), vs three on the staged path.

    Counted at trace time by monkeypatching ``pallas_call`` on the shared
    pallas module — so the frame shape must be FRESH (never traced in
    this process); jit caches replay traced launches without re-entering
    ``pallas_call``.
    """
    launches = {"n": 0}
    real = pallas_mod.pallas_call

    def counting(*a, **kw):
        launches["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(pallas_mod, "pallas_call", counting)
    rng = np.random.default_rng(5)
    # fresh, never-traced frame shape (prime-ish H/W)
    fs = _frames(rng, 2, 67, 131)
    f = tuple(jnp.asarray(fs[i]) for i in range(3))

    launches["n"] = 0
    ops.pixel_cascade(*f, threshold=41)
    assert launches["n"] == 1
    assert launches["n"] <= 2          # the acceptance bar

    launches["n"] = 0
    ops.pixel_cascade(*f, threshold=41, fused=False)
    assert launches["n"] == 3          # staged reference: 3 launches


@needs_lowering
def test_pixel_city_tick_detect_launch_budget(monkeypatch):
    """End-to-end: a pixel_city-style fleet tick through ``detect`` stays
    within the <= 2 Pallas-launch budget on the fused path."""
    launches = {"n": 0}
    real = pallas_mod.pallas_call

    def counting(*a, **kw):
        launches["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(pallas_mod, "pallas_call", counting)
    sc = pixel_city(num_cameras=3)
    cam = SV.make_cameras(sc.num_cameras, seed=sc.seed)[0]
    rng = np.random.default_rng(9)
    # fresh batch shape: 3 cameras at a never-traced 61x133 frame
    batch = rng.integers(0, 256, (3, 3, 61, 133, 3)).astype(np.int32)
    assert (cam.height, cam.width) == (96, 128)   # city preset sanity
    launches["n"] = 0
    DP.detect(batch, threshold=40, fused=True)
    assert launches["n"] <= 2


# --- detect integration ------------------------------------------------------


@needs_lowering
def test_detect_fused_matches_staged_end_to_end():
    """Boxes and crops identical under fused and staged detection."""
    rng = np.random.default_rng(0)
    cam = SV.make_cameras(1, seed=11)[0]
    cam.base_rate, cam.busy_boost = 2.0, 0.0
    frames, _ = SV.render_triple(cam, 0.0, rng)
    dets_f = DP.detect(frames, fused=True)[0]
    dets_s = DP.detect(frames, fused=False)[0]
    assert len(dets_f) == len(dets_s) > 0
    for df, ds in zip(dets_f, dets_s):
        assert df.box == ds.box
        np.testing.assert_array_equal(df.crop, ds.crop)


@needs_lowering
def test_static_scene_skips_ccl(monkeypatch):
    """A motionless tick returns empties WITHOUT running the CCL
    fixpoint — the fused kernel's counts short-circuit it."""
    called = {"n": 0}
    real = components.label_components

    def counting(mask):
        called["n"] += 1
        return real(mask)

    monkeypatch.setattr(components, "label_components", counting)
    static = np.full((2, 3, 96, 128, 3), 55, np.int32)
    out = DP.detect(static, fused=True)
    assert out == [[], []]
    assert called["n"] == 0


# --- Scenario.frame_hw validation -------------------------------------------


def test_frame_hw_validation_rejects_tiny_and_huge():
    with pytest.raises(ValueError, match="minimum frame side"):
        validate_frame_hw("t", MIN_FRAME_SIDE - 1, 128)
    with pytest.raises(ValueError, match="tile table's limit"):
        validate_frame_hw("t", 4096, 4096)
    validate_frame_hw("t", 96, 128)          # default camera frame: fine


def test_scenario_rejects_bad_frame_hw():
    sc = pixel_city(num_cameras=2)
    with pytest.raises(ValueError, match="minimum frame side"):
        dataclasses.replace(sc, frame_hw=(8, 128))
    big_hw = (2048, int(MAX_FRAME_ELEMS / 2048) + 129)
    with pytest.raises(ValueError, match="tile table's limit"):
        dataclasses.replace(sc, frame_hw=big_hw)
    ok = dataclasses.replace(sc, frame_hw=(48, 64))   # validates cleanly
    assert ok.frame_hw == (48, 64)
