"""Cascade speculative decoding: output-identical to cloud greedy."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import speculative as SP
from repro.models import meta


@pytest.fixture(scope="module")
def pair():
    cloud_cfg = get_config("qwen1.5-0.5b").reduced()
    edge_cfg = get_config("qwen1.5-0.5b").edge_variant()
    cloud = meta.init_params(cloud_cfg, jax.random.PRNGKey(0))
    edge = meta.init_params(edge_cfg, jax.random.PRNGKey(1))
    return edge_cfg, edge, cloud_cfg, cloud


def test_speculative_equals_cloud_greedy(pair):
    edge_cfg, edge, cloud_cfg, cloud = pair
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                                cloud_cfg.vocab_size)
    want = SP.cloud_greedy_generate(cloud_cfg, cloud, prompt, steps=10)
    got, stats = SP.speculative_generate(edge_cfg, edge, cloud_cfg, cloud,
                                         prompt, steps=10, k=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats.proposed >= stats.accepted >= 0
    assert stats.cloud_steps >= 1


def test_speculative_self_draft_accepts_everything(pair):
    """Drafting with the cloud model itself must accept every proposal."""
    _, _, cloud_cfg, cloud = pair
    prompt = jax.random.randint(jax.random.PRNGKey(3), (1, 8), 0,
                                cloud_cfg.vocab_size)
    got, stats = SP.speculative_generate(cloud_cfg, cloud, cloud_cfg, cloud,
                                         prompt, steps=8, k=4)
    want = SP.cloud_greedy_generate(cloud_cfg, cloud, prompt, steps=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert stats.acceptance_rate == pytest.approx(1.0)
    assert stats.tokens_per_cloud_step > 1.5


def test_verify_prefix_logic():
    V = 16
    draft = jnp.asarray([[3, 5, 7]])
    logits = jnp.zeros((1, 3, V))
    logits = logits.at[0, 0, 3].set(9.0)     # agrees
    logits = logits.at[0, 1, 5].set(9.0)     # agrees
    logits = logits.at[0, 2, 9].set(9.0)     # disagrees -> cloud says 9
    n, nxt = SP.verify_prefix(logits, draft)
    assert int(n[0]) == 2
    assert int(nxt[0]) == 9
