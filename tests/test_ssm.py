"""Mamba-2 SSD: chunked dual form vs naive recurrence, decode chain, conv."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import meta, ssm as S


def _inputs(key, B=2, Sq=64, nh=8, hd=16, G=1, N=16):
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (B, Sq, nh, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, Sq, nh)))
    A = -jnp.exp(jax.random.uniform(ks[2], (nh,), minval=0.0, maxval=1.0))
    Bm = jax.random.normal(ks[3], (B, Sq, G, N))
    Cm = jax.random.normal(ks[4], (B, Sq, G, N))
    D = jax.random.normal(ks[5], (nh,))
    return x, dt, A, Bm, Cm, D


@pytest.mark.parametrize("Sq,chunk", [(32, 8), (64, 32), (96, 32), (64, 64)])
def test_ssd_chunked_matches_reference(Sq, chunk):
    cfg = get_config("mamba2-2.7b").reduced()
    import dataclasses
    cfg = dataclasses.replace(cfg, ssm_chunk=chunk)
    x, dt, A, Bm, Cm, D = _inputs(jax.random.PRNGKey(0), Sq=Sq)
    y1, s1 = S.ssd_chunked(cfg, x, dt, A, Bm, Cm, D)
    y2, s2 = S.ssd_reference(cfg, x, dt, A, Bm, Cm, D)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-3
    assert float(jnp.max(jnp.abs(s1 - s2))) < 2e-3


def test_ssd_with_initial_state():
    cfg = get_config("mamba2-2.7b").reduced()
    x, dt, A, Bm, Cm, D = _inputs(jax.random.PRNGKey(1), Sq=64)
    B, _, nh, hd = x.shape
    N = Bm.shape[-1]
    s0 = jax.random.normal(jax.random.PRNGKey(2), (B, nh, hd, N))
    y1, s1 = S.ssd_chunked(cfg, x, dt, A, Bm, Cm, D, init_state=s0)
    y2, s2 = S.ssd_reference(cfg, x, dt, A, Bm, Cm, D, init_state=s0)
    assert float(jnp.max(jnp.abs(y1 - y2))) < 2e-3
    assert float(jnp.max(jnp.abs(s1 - s2))) < 2e-3


def test_ssd_decode_chain_matches_chunked():
    """Step-by-step decode over S tokens == chunked scan over the sequence."""
    cfg = get_config("mamba2-2.7b").reduced()
    x, dt, A, Bm, Cm, D = _inputs(jax.random.PRNGKey(3), Sq=32)
    y_full, s_full = S.ssd_reference(cfg, x, dt, A, Bm, Cm, D)
    B, Sq, nh, hd = x.shape
    N = Bm.shape[-1]
    state = jnp.zeros((B, nh, hd, N))
    for t in range(Sq):
        y_t, state = S.ssd_decode_step(cfg, state, x[:, t], dt[:, t], A,
                                       Bm[:, t], Cm[:, t], D)
        assert float(jnp.max(jnp.abs(y_t - y_full[:, t]))) < 2e-3
    assert float(jnp.max(jnp.abs(state - s_full))) < 2e-3


def test_causal_conv_matches_explicit():
    key = jax.random.PRNGKey(4)
    B, Sq, C, W = 2, 16, 8, 4
    x = jax.random.normal(key, (B, Sq, C))
    w = jax.random.normal(jax.random.PRNGKey(5), (W, C))
    y, _ = S.causal_conv(x, w)
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    want = sum(xp[:, i:i + Sq, :] * w[i] for i in range(W))
    assert float(jnp.max(jnp.abs(y - want))) < 1e-5


def test_causal_conv_cache_streaming():
    """Conv over a stream in two halves == conv over the full sequence."""
    key = jax.random.PRNGKey(6)
    B, Sq, C, W = 2, 16, 8, 4
    x = jax.random.normal(key, (B, Sq, C))
    w = jax.random.normal(jax.random.PRNGKey(7), (W, C))
    y_full, _ = S.causal_conv(x, w)
    y1, cache = S.causal_conv(x[:, :9], w)
    y2, _ = S.causal_conv(x[:, 9:], w, cache)
    y_cat = jnp.concatenate([y1, y2], axis=1)
    assert float(jnp.max(jnp.abs(y_cat - y_full))) < 1e-5
