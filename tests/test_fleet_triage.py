"""Fleet-scale engine tests: the fused (E, N) triage kernel vs E independent
batched calls (hypothesis property), one-launch-per-tick on multi-edge
fleets, per-edge threshold divergence under asymmetric load, and the
city_scale smoke invariants."""
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.serving.simulator import Item
from repro.system import (
    Scenario,
    city_scale,
    homogeneous_multi_edge,
    run_query,
    synthetic_confidence_stream,
)

# --- ops.triage_fleet vs independent per-edge triage --------------------------


def _pack(batches, pad=-1.0):
    """Variable-length per-edge confidence lists -> padded (E, N) matrix."""
    n = max((len(b) for b in batches), default=0)
    conf = np.full((len(batches), max(n, 1)), pad, np.float32)
    for i, b in enumerate(batches):
        conf[i, :len(b)] = b
    return conf


def test_triage_fleet_matches_per_edge_batched():
    rng = np.random.default_rng(3)
    batches = [list(rng.uniform(0, 1, n)) for n in (5, 1, 17, 9)]
    th = np.asarray([[0.9, 0.05], [0.8, 0.1], [0.55, 0.3], [0.7, 0.2]],
                    np.float32)
    routes, slots, counts = ops.triage_fleet(_pack(batches), th, capacity=4)
    routes, slots = np.asarray(routes), np.asarray(slots)
    for e, b in enumerate(batches):
        rb, sb, cb = ops.triage_batched(
            np.asarray(b, np.float32), alpha=float(th[e, 0]),
            beta=float(th[e, 1]), capacity=4)
        np.testing.assert_array_equal(routes[e, :len(b)], np.asarray(rb))
        np.testing.assert_array_equal(slots[e, :len(b)], np.asarray(sb))
        assert int(np.asarray(counts)[e]) == int(cb)
        # pad lanes: always reject, never a slot, never counted
        assert np.all(routes[e, len(b):] == 1)
        assert np.all(slots[e, len(b):] == -1)


def test_triage_fleet_matches_ref_fleet():
    rng = np.random.default_rng(11)
    conf = rng.uniform(0, 1, (7, 33)).astype(np.float32)
    th = np.stack([rng.uniform(0.5, 1.0, 7), rng.uniform(0.0, 0.45, 7)],
                  axis=1).astype(np.float32)
    got = ops.triage_fleet(conf, th, capacity=8)
    want = ref.triage_fleet_ref(conf, th, 8)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


@pytest.mark.slow
def test_triage_fleet_property_matches_independent_calls():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(
        st.lists(st.integers(0, 24), min_size=1, max_size=6),
        st.integers(1, 16),
        st.integers(0, 2 ** 31 - 1),
    )
    def prop(lengths, capacity, seed):
        rng = np.random.default_rng(seed)
        batches = [list(rng.uniform(0, 1, n)) for n in lengths]
        th = np.stack(
            [rng.uniform(0.5, 1.0, len(lengths)),
             rng.uniform(0.0, 0.5, len(lengths))], axis=1).astype(np.float32)
        routes, slots, counts = ops.triage_fleet(
            _pack(batches), th, capacity=capacity)
        routes, slots, counts = (np.asarray(routes), np.asarray(slots),
                                 np.asarray(counts))
        for e, b in enumerate(batches):
            if b:
                rb, sb, cb = ops.triage_batched(
                    np.asarray(b, np.float32), alpha=float(th[e, 0]),
                    beta=float(th[e, 1]), capacity=capacity)
                np.testing.assert_array_equal(routes[e, :len(b)],
                                              np.asarray(rb))
                np.testing.assert_array_equal(slots[e, :len(b)],
                                              np.asarray(sb))
                assert int(counts[e]) == int(cb)
            else:
                assert int(counts[e]) == 0
            # pad lanes never claim escalation slots (or routes != reject)
            assert np.all(routes[e, len(b):] == 1)
            assert np.all(slots[e, len(b):] == -1)

    prop()


# --- one fused launch per tick on a multi-edge fleet --------------------------


def test_multi_edge_fleet_is_one_launch_per_tick():
    sc = homogeneous_multi_edge(num_cameras=6, duration_s=30.0, seed=2)
    stream = synthetic_confidence_stream(sc)
    ticks_with_arrivals = {int(it.t_arrival // sc.interval_s)
                           for it in stream}
    assert sc.num_edges == 3
    r = run_query(sc, items=stream)
    assert len(r.latencies) == len(stream)
    # ONE launch per tick-with-arrivals for the whole fleet, not per edge
    assert r.kernel_launches == len(ticks_with_arrivals)
    assert r.kernel_launches < len(ticks_with_arrivals) * sc.num_edges
    # the frozen-threshold cascade fleet-launches identically
    rf = run_query(sc.with_scheme("surveiledge_fixed"), items=stream)
    assert rf.kernel_launches == len(ticks_with_arrivals)


# --- per-edge adaptive thresholds ---------------------------------------------


def test_per_edge_thresholds_diverge_under_asymmetric_load():
    """One drowning edge and one idle edge in the same run: the loaded
    edge's Eqs. 8-9 state tightens its [beta, alpha] escalation bracket
    (alpha falls, beta rises) while the idle edge's widens past its start,
    which a single fleet-global threshold pair cannot do."""
    sc = Scenario(name="asym", edge_speeds=(1.0, 1.0), num_cameras=2,
                  duration_s=60.0, offload_drain_s=1e9, seed=1)
    items = []
    for k in range(60):
        for i in range(20):      # edge 1: ~1.6s of service arriving per 1s
            items.append(Item(t_arrival=k + i / 25.0, camera=0,
                              edge_device=1, conf=0.95, is_query=True))
        items.append(Item(t_arrival=k + 0.5, camera=1, edge_device=2,
                          conf=0.95, is_query=True))
    items.sort(key=lambda it: it.t_arrival)
    r = run_query(sc, items=items)
    a_loaded, b_loaded = r.thresholds[1]
    a_idle, b_idle = r.thresholds[2]
    assert a_loaded < 0.8 < a_idle       # 0.8 is the shared starting alpha
    assert b_loaded > b_idle
    # and both still satisfy the Eqs. 8-9 clamps
    for a, b in r.thresholds.values():
        assert 0.5 <= a <= 1.0
        assert 0.0 <= b < 0.5


# --- city_scale smoke ---------------------------------------------------------


def test_city_scale_smoke_invariants():
    sc = city_scale(duration_s=10.0, seed=0)
    assert sc.num_edges >= 64
    assert sc.num_cameras >= 512
    assert len({e for _, e in sc.failures}) == len(sc.failures) >= 2
    stream = synthetic_confidence_stream(sc)
    assert len(stream) > 1000
    r = run_query(sc, items=stream)
    # every item is answered exactly once despite rolling edge failures
    assert len(r.latencies) == len(stream)
    assert len(r.decisions) == len(stream)
    assert np.all(r.latencies >= 0)
    assert np.all(np.diff(r.finish_times) >= -1e-9)
    # the whole 64-edge fleet still costs ONE kernel launch per tick
    ticks_with_arrivals = {int(it.t_arrival // sc.interval_s)
                           for it in stream}
    assert r.kernel_launches == len(ticks_with_arrivals)
    assert r.kernel_launches == r.ticks      # 512 cameras: every tick busy
    # per-edge threshold state exists for the whole fleet
    assert sorted(r.thresholds) == list(sc.edge_ids)
