"""Feedback-loop tests: calibrate-kernel Pallas/ref parity, padding
invisibility, Platt-fit recovery of a known logistic map, scenario
validation (ValueError, never assert), the drifting_city closed loop
beating the update_period_s=None ablation with exactly one fused
calibrate launch per update event, and report-loader consistency
rejection."""
import dataclasses
import json

import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.system import (
    Scenario,
    apply_calibration,
    drifting_city,
    run_query,
    synthetic_confidence_stream,
)

# --- ops.calibrate_fleet vs the independent NumPy oracle ----------------------


def _label_fleet(seed, lengths, n=None, a=2.0, b=0.5):
    """Per-edge (scores, truths) from a known logistic: y ~ Bernoulli of
    sigmoid(a * logit(s) + b).  Pad lanes score -1.0, truth 0."""
    rng = np.random.default_rng(seed)
    n = n if n is not None else max(lengths) if lengths else 1
    scores = np.full((len(lengths), max(n, 1)), -1.0, np.float32)
    truths = np.zeros((len(lengths), max(n, 1)), np.float32)
    for e, length in enumerate(lengths):
        s = rng.uniform(0.02, 0.98, length)
        p = 1.0 / (1.0 + np.exp(-(a * np.log(s / (1 - s)) + b)))
        scores[e, :length] = s
        truths[e, :length] = rng.uniform(0, 1, length) < p
    return scores, truths


def test_calibrate_fleet_pallas_matches_numpy_ref():
    scores, truths = _label_fleet(0, [200, 150, 7, 40, 0])
    truths[3, :40] = 1.0                     # single-class row -> identity
    got_p, got_c = ops.calibrate_fleet(scores, truths)
    want_p, want_c = ops.calibrate_fleet(scores, truths, use_pallas=False)
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(want_p),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_array_equal(np.asarray(got_c), np.asarray(want_c))


def test_calibrate_fleet_padding_is_invisible():
    scores, truths = _label_fleet(1, [64, 33, 90])
    base, _ = ops.calibrate_fleet(scores, truths)
    wide = np.full((6, scores.shape[1] + 55), -1.0, np.float32)
    wide_t = np.zeros_like(wide)
    wide[:3, :scores.shape[1]] = scores
    wide_t[:3, :scores.shape[1]] = truths
    padded, counts = ops.calibrate_fleet(wide, wide_t)
    padded = np.asarray(padded)
    np.testing.assert_allclose(padded[:3], np.asarray(base), atol=1e-5)
    # pad edge rows are fully masked: identity params, zero counts
    np.testing.assert_allclose(padded[3:], [[1.0, 0.0]] * 3)
    assert np.all(np.asarray(counts)[3:] == 0)


def test_calibrate_fleet_degenerate_rows_fall_back_to_identity():
    scores, truths = _label_fleet(2, [40, 4, 40, 0])
    truths[2, :40] = 0.0                     # all-negative labels
    params, counts = ops.calibrate_fleet(scores, truths, min_count=8)
    params = np.asarray(params)
    assert not np.allclose(params[0], [1.0, 0.0])   # healthy row did fit
    np.testing.assert_allclose(params[1:], [[1.0, 0.0]] * 3)
    np.testing.assert_array_equal(np.asarray(counts), [40, 4, 40, 0])


def test_calibrate_fleet_recovers_known_logistic():
    scores, truths = _label_fleet(3, [4000], a=2.0, b=0.5)
    params, _ = ops.calibrate_fleet(scores, truths)
    a, b = np.asarray(params)[0]
    # Platt target smoothing + the MAP prior bias the fit slightly toward
    # the identity; with 4000 labels the pull is small
    assert abs(a - 2.0) < 0.3
    assert abs(b - 0.5) < 0.3


@pytest.mark.slow
def test_calibrate_fleet_padding_property():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=20, deadline=None)
    @given(st.lists(st.integers(0, 48), min_size=1, max_size=5),
           st.integers(0, 4), st.integers(0, 60),
           st.integers(0, 2 ** 31 - 1))
    def prop(lengths, extra_rows, extra_cols, seed):
        scores, truths = _label_fleet(seed, lengths)
        base, base_c = ops.calibrate_fleet(scores, truths)
        E, N = scores.shape
        wide = np.full((E + extra_rows, N + extra_cols), -1.0, np.float32)
        wide_t = np.zeros_like(wide)
        wide[:E, :N] = scores
        wide_t[:E, :N] = truths
        padded, padded_c = ops.calibrate_fleet(wide, wide_t)
        np.testing.assert_allclose(np.asarray(padded)[:E],
                                   np.asarray(base), atol=1e-4)
        np.testing.assert_array_equal(np.asarray(padded_c)[:E],
                                      np.asarray(base_c))
        np.testing.assert_allclose(np.asarray(padded)[E:],
                                   [[1.0, 0.0]] * extra_rows)

    prop()


def test_apply_calibration_identity_is_bit_exact():
    conf = np.linspace(0.0, 1.0, 33, dtype=np.float32)
    assert apply_calibration(conf, 1.0, 0.0) is conf
    # a real map is monotone and stays in (0, 1) without overflow warnings
    with np.errstate(over="raise"):
        out = apply_calibration(conf, 6.0, -8.0)
    assert np.all(np.diff(out) >= 0)
    assert np.all((out >= 0) & (out <= 1))


# --- scenario validation (ValueError, never assert) ---------------------------


def test_with_scheme_unknown_raises_value_error():
    sc = drifting_city()
    with pytest.raises(ValueError, match="unknown scheme"):
        sc.with_scheme("bogus")


def test_fixed_thresholds_validated_at_construction():
    with pytest.raises(ValueError, match="alpha"):
        Scenario(name="bad", fixed_thresholds=(0.3, 0.1))
    with pytest.raises(ValueError, match="beta"):
        Scenario(name="bad", fixed_thresholds=(0.8, 0.6))
    with pytest.raises(ValueError, match="update_period_s"):
        Scenario(name="bad", update_period_s=0.0)
    # the valid corner is accepted
    Scenario(name="ok", fixed_thresholds=(0.5, 0.0))


# --- the closed loop on drifting_city -----------------------------------------


@pytest.fixture(scope="module")
def drift_runs():
    sc = drifting_city(num_cameras=8, duration_s=60.0, seed=0)
    stream = synthetic_confidence_stream(sc)
    closed = run_query(sc, items=stream)
    ablation = run_query(
        dataclasses.replace(sc, update_period_s=None), items=stream)
    return sc, stream, closed, ablation


def test_drift_stream_actually_drifts(drift_runs):
    sc, stream, _, _ = drift_runs
    pre_q = [it.conf for it in stream
             if it.is_query and it.t_arrival < sc.drift_at_s]
    post_q = [it.conf for it in stream
              if it.is_query and it.t_arrival >= sc.drift_at_s]
    assert np.mean(pre_q) > 0.7 > np.mean(post_q)


def test_closed_loop_beats_open_loop_on_drift(drift_runs):
    _, _, closed, ablation = drift_runs
    assert closed.model_updates > 0
    assert closed.downloaded_bytes > 0
    assert ablation.model_updates == 0
    assert ablation.downloaded_bytes == 0
    assert closed.f_score() > ablation.f_score()


def test_closed_loop_recovers_after_drift(drift_runs):
    sc, _, closed, ablation = drift_runs
    # windows fully past the drift: the recalibrated system climbs back,
    # the frozen one stays down
    def post_drift_mean(r):
        wins = [w["f2"] for w in r.accuracy_timeline(window_s=10.0)
                if w["t_start"] >= sc.drift_at_s + 10.0]
        assert wins
        return float(np.mean(wins))
    assert post_drift_mean(closed) > post_drift_mean(ablation)


def test_one_fused_calibrate_launch_per_update_event(drift_runs, monkeypatch):
    sc, stream, _, _ = drift_runs
    calls = {"n": 0}
    real = ops.calibrate_fleet

    def counting(*args, **kw):
        calls["n"] += 1
        return real(*args, **kw)

    monkeypatch.setattr(ops, "calibrate_fleet", counting)
    r = run_query(sc, items=stream)
    # fleet-wide recalibration is ONE ops.calibrate_fleet call per update
    # event — never one per edge
    assert r.model_updates > 0
    assert calls["n"] == r.model_updates
    assert calls["n"] < r.model_updates * sc.num_edges


def test_feedback_loop_off_by_default(drift_runs):
    _, stream, _, _ = drift_runs
    sc = Scenario(name="plain", edge_speeds=(1.0, 1.0), num_cameras=4,
                  duration_s=20.0)
    r = run_query(sc, items=[it for it in stream if it.t_arrival < 20.0])
    assert r.model_updates == 0
    assert r.downloaded_bytes == 0


# --- report loader consistency ------------------------------------------------


def test_load_report_rejects_updates_without_downlink(tmp_path):
    import importlib.util
    import pathlib
    script = pathlib.Path(__file__).resolve().parents[1] \
        / "examples" / "run_scenarios.py"
    spec = importlib.util.spec_from_file_location("run_scenarios", script)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    good = {"scenario": "drifting_city", "frontend": "confidence",
            "schemes": {"surveiledge": {"model_updates": 3,
                                        "downloaded_MB": 0.0,
                                        "downloaded_bytes": 24}}}
    path = tmp_path / "ok.json"
    path.write_text(json.dumps(good))
    # tiny payloads round to 0.0 MB but the raw byte gate sees them
    assert mod.load_report(str(path))["scenario"] == "drifting_city"
    bad = {"scenario": "drifting_city", "frontend": "confidence",
           "schemes": {"surveiledge": {"model_updates": 3,
                                       "downloaded_MB": 0.0,
                                       "downloaded_bytes": 0}}}
    path = tmp_path / "bad.json"
    path.write_text(json.dumps(bad))
    with pytest.raises(ValueError, match="downlink"):
        mod.load_report(str(path))
