"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("B,H,W", [(1, 32, 128), (2, 50, 200), (3, 96, 128),
                                   (1, 33, 129), (2, 64, 256)])
@pytest.mark.parametrize("threshold", [10, 40, 128])
def test_framediff_matches_ref(B, H, W, threshold):
    keys = [jax.random.PRNGKey(i) for i in range(3)]
    f = [jax.random.randint(k, (B, H, W, 3), 0, 256) for k in keys]
    got = ops.framediff(*f, threshold=threshold)
    want = ref.framediff_ref(*(x.astype(jnp.int32) for x in f), threshold)
    assert got.shape == (B, H, W)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("B,H,W", [(1, 32, 64), (2, 50, 100), (1, 96, 128),
                                   (2, 33, 65)])
def test_morphology_matches_ref(B, H, W):
    key = jax.random.PRNGKey(0)
    x = (jax.random.uniform(key, (B, H, W)) > 0.7).astype(jnp.int32) * 255
    np.testing.assert_array_equal(np.asarray(ops.dilate3x3(x)),
                                  np.asarray(ref.dilate3x3_ref(x)))
    np.testing.assert_array_equal(np.asarray(ops.erode3x3(x)),
                                  np.asarray(ref.erode3x3_ref(x)))


def test_dilate_then_erode_is_closing():
    """Morphological closing fills single-pixel holes and keeps blobs."""
    x = np.zeros((1, 32, 32), np.int32)
    x[0, 10:20, 10:20] = 255
    x[0, 14, 14] = 0                      # hole
    y = ops.erode3x3(ops.dilate3x3(jnp.asarray(x)))
    y = np.asarray(y)
    assert y[0, 14, 14] == 255            # hole filled
    assert y[0, 0, 0] == 0                # background untouched


@pytest.mark.parametrize("N", [8, 100, 1000, 4096])
@pytest.mark.parametrize("alpha,beta", [(0.8, 0.1), (0.55, 0.3), (1.0, 0.0)])
def test_triage_matches_ref(N, alpha, beta):
    conf = jax.random.uniform(jax.random.PRNGKey(N), (N,))
    cap = max(N // 4, 4)
    got = ops.triage(conf, alpha=alpha, beta=beta, capacity=cap)
    want = ref.triage_ref(conf, alpha, beta, cap)
    for g, w in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w))


def test_triage_compaction_is_stable_and_dense():
    conf = jnp.asarray([0.9, 0.5, 0.2, 0.05, 0.5, 0.6])
    routes, slots, count = ops.triage(conf, alpha=0.8, beta=0.1, capacity=8)
    # escalated = indices 1,2,4,5 -> slots 0,1,2,3 in order
    assert int(count) == 4
    np.testing.assert_array_equal(np.asarray(slots), [-1, 0, 1, -1, 2, 3])
    np.testing.assert_array_equal(np.asarray(routes), [0, 2, 2, 1, 2, 2])


@pytest.mark.parametrize("dtype", [jnp.uint8, jnp.int32, jnp.int16])
def test_framediff_input_dtypes(dtype):
    B, H, W = 1, 32, 128
    f = [jax.random.randint(jax.random.PRNGKey(i), (B, H, W, 3), 0, 255
                            ).astype(dtype) for i in range(3)]
    got = ops.framediff(*f, threshold=30)
    want = ref.framediff_ref(*(x.astype(jnp.int32) for x in f), 30)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
