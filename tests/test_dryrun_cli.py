"""Dry-run launcher CLI smoke (subprocess: needs its own XLA device count)."""
import json
import os
import subprocess
import sys
import tempfile

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(args, timeout=300):
    env = dict(os.environ, PYTHONPATH=os.path.join(ROOT, "src"))
    env.pop("XLA_FLAGS", None)
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)


@pytest.mark.parametrize("mesh", ["single", "multi"])
def test_dryrun_cli_compiles_and_records(mesh):
    with tempfile.TemporaryDirectory() as d:
        r = _run(["--arch", "qwen1.5-0.5b", "--shape", "long_500k",
                  "--mesh", mesh, "--out", d])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "All dry-runs compiled successfully" in r.stdout
        recs = os.listdir(d)
        assert len(recs) == 1
        rec = json.load(open(os.path.join(d, recs[0])))
        assert rec["chips"] == (512 if mesh == "multi" else 256)
        assert rec["memory"]["peak_bytes"] > 0
        assert "flops" in rec["cost"]
        assert rec["window"] == 8192      # long-context sliding window


def test_dryrun_cli_perf_knobs():
    r = _run(["--arch", "qwen1.5-0.5b", "--shape", "decode_32k",
              "--mesh", "single", "--kv-dtype", "int8", "--serve-1d"])
    assert r.returncode == 0, r.stderr[-2000:]
