"""End-to-end behaviour tests: the four query schemes reproduce the paper's
qualitative orderings (Table II structure) on a synthetic workload."""
import numpy as np
import pytest

from repro.serving.simulator import CloudEdgeSim, LinkSpec, NodeSpec
from repro.serving.workload import build_workload


@pytest.fixture(scope="module")
def workload():
    return build_workload(num_cameras=6, num_edges=3, duration_s=90.0,
                          finetune_steps=40, seed=0)


def _run(wl, scheme, edge_s=0.30, cloud_s=0.05, up=0.5):
    edges = [NodeSpec(i, service_s=edge_s) for i in (1, 2, 3)]
    cloud = NodeSpec(0, service_s=cloud_s)
    sim = CloudEdgeSim(edges, cloud, LinkSpec(uplink_MBps=up, rtt_s=0.1),
                       scheme=scheme, seed=1)
    return sim.run(wl.items)


def test_edge_model_actually_learned(workload):
    assert workload.edge_accuracy > 0.75


def test_every_item_answered_exactly_once(workload):
    for scheme in ("surveiledge", "surveiledge_fixed", "edge_only", "cloud_only"):
        r = _run(workload, scheme)
        assert len(r.latencies) == len(workload.items)
        assert len(r.decisions) == len(workload.items)


def test_scheme_orderings_match_paper(workload):
    se = _run(workload, "surveiledge")
    fx = _run(workload, "surveiledge_fixed")
    eo = _run(workload, "edge_only")
    co = _run(workload, "cloud_only")
    # accuracy: cloud-only (ground truth) >= surveiledge > edge-only
    assert co.f_score() == pytest.approx(1.0)
    assert se.f_score() > eo.f_score()
    assert se.f_score() > fx.f_score() - 0.02
    # latency: surveiledge beats cloud-only, edge-only and fixed (overload)
    assert se.avg_latency < co.avg_latency
    assert se.avg_latency < eo.avg_latency
    assert se.avg_latency < fx.avg_latency
    # bandwidth: edge-only ships nothing; surveiledge ships less than cloud-only
    assert eo.uploaded_bytes == 0
    assert 0 < se.uploaded_bytes <= co.uploaded_bytes
    # latency variance: the allocator reduces variance vs fixed
    assert se.latency_var < fx.latency_var


def test_adaptive_thresholds_react(workload):
    sim_edges = [NodeSpec(i, service_s=0.30) for i in (1, 2, 3)]
    sim = CloudEdgeSim(sim_edges, NodeSpec(0, service_s=0.05),
                       LinkSpec(uplink_MBps=0.5), scheme="surveiledge", seed=2)
    sim.run(workload.items)
    th = sim.sched.thresholds
    assert 0.5 <= th.alpha <= 1.0 and th.beta < 0.5
    # parameter DB saw replicated updates
    assert sim.db.writes > len(workload.items)


def test_heterogeneous_edges_offload(workload):
    """A slow edge under SurveilEdge should not dominate tail latency the
    way it does in edge-only (Table IV structure)."""
    def run(scheme):
        edges = [NodeSpec(1, service_s=0.9), NodeSpec(2, service_s=0.3),
                 NodeSpec(3, service_s=0.15)]
        sim = CloudEdgeSim(edges, NodeSpec(0, service_s=0.05),
                           LinkSpec(uplink_MBps=0.5), scheme=scheme, seed=3)
        return sim.run(workload.items)

    se, eo = run("surveiledge"), run("edge_only")
    assert se.p99_latency < eo.p99_latency
    assert se.avg_latency < eo.avg_latency
