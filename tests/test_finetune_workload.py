"""CQ fine-tuning actually learns; workload wiring is sound."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import finetune as FT
from repro.data import synthetic_video as SV
from repro.models import meta as M
from repro.serving.workload import _binary_batches, build_workload


@pytest.fixture(scope="module")
def edge_cfg():
    full = get_config("surveiledge-cls")
    return dataclasses.replace(full.edge_variant(), num_query_classes=2,
                               vocab_size=full.vocab_size)


def test_finetune_improves_over_init(edge_cfg):
    rng = np.random.default_rng(0)
    profile = np.ones(SV.NUM_CLASSES) / SV.NUM_CLASSES
    ev = next(_binary_batches(np.random.default_rng(9), edge_cfg, profile,
                              None, SV.QUERY_CLASS, batch=256))
    params = M.init_params(edge_cfg, jax.random.PRNGKey(0))
    acc0 = FT.accuracy_of(edge_cfg, params, *ev)
    res = FT.finetune(edge_cfg, params,
                      _binary_batches(rng, edge_cfg, profile, None,
                                      SV.QUERY_CLASS),
                      steps=50, lr=1e-3, eval_set=ev)
    assert res.accuracy > max(acc0, 0.65)
    assert res.train_seconds > 0


def test_head_only_touches_only_head(edge_cfg):
    rng = np.random.default_rng(1)
    profile = np.ones(SV.NUM_CLASSES) / SV.NUM_CLASSES
    params = M.init_params(edge_cfg, jax.random.PRNGKey(1))
    res = FT.finetune(edge_cfg, params,
                      _binary_batches(rng, edge_cfg, profile, None,
                                      SV.QUERY_CLASS),
                      steps=5, lr=1e-2, head_only=True)
    # backbone unchanged, head moved
    same = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                        params["layers"], res.params["layers"])
    assert max(jax.tree.leaves(same)) == 0.0
    dh = float(jnp.max(jnp.abs(params["cls_head"]["w"]
                               - res.params["cls_head"]["w"])))
    assert dh > 0


def test_workload_confidences_informative():
    wl = build_workload(num_cameras=4, num_edges=2, duration_s=40.0,
                        finetune_steps=40, seed=3)
    conf = np.asarray([i.conf for i in wl.items])
    truth = np.asarray([i.is_query for i in wl.items])
    assert len(wl.items) > 30
    if truth.any() and (~truth).any():
        # trained edge model separates query/non-query on average
        assert conf[truth].mean() > conf[~truth].mean() + 0.1
    assert set(np.unique([i.edge_device for i in wl.items])) <= {1, 2}
