"""MoE: sort-based dispatch vs dense reference, aux loss, capacity behavior."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L, meta


def _dense_ref(cfg, lp, x):
    logits = jnp.einsum("bsd,de->bse", x, lp["router"])
    probs = jax.nn.softmax(logits, -1)
    topw, topi = jax.lax.top_k(probs, cfg.top_k)
    topw = topw / topw.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, lp["wi"])
    g = jnp.einsum("bsd,edf->bsef", x, lp["wg"])
    out_e = jnp.einsum("bsef,efd->bsed", jax.nn.silu(g) * h, lp["wo"])
    onehot = jax.nn.one_hot(topi, cfg.num_experts)
    w_e = jnp.einsum("bske,bsk->bse", onehot, topw)
    return jnp.einsum("bsed,bse->bsd", out_e, w_e)


@pytest.mark.parametrize("arch", ["granite-moe-1b-a400m", "phi3.5-moe-42b-a6.6b"])
def test_moe_no_drop_matches_dense(arch):
    cfg = get_config(arch).reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.num_experts))
    lp = jax.tree.map(lambda a: a[0],
                      meta.init_params(cfg, jax.random.PRNGKey(0))["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model)) * 0.5
    y, aux = L.moe_apply(cfg, lp, x)
    y_ref = _dense_ref(cfg, lp, x)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
    assert 0.5 < float(aux) < 4.0          # balanced-ish at random init


def test_moe_capacity_drops_some_tokens_when_tight():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    cfg = dataclasses.replace(cfg, capacity_factor=0.1)   # very tight
    lp = jax.tree.map(lambda a: a[0],
                      meta.init_params(cfg, jax.random.PRNGKey(0))["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y, _ = L.moe_apply(cfg, lp, x)
    y_ref = _dense_ref(cfg, lp, x)
    # dropped tokens -> zero output rows vs reference
    diff = jnp.abs(y - y_ref).max(-1)
    assert float((diff > 1e-4).mean()) > 0.05


def test_moe_grad_flows_to_all_parts():
    cfg = get_config("granite-moe-1b-a400m").reduced()
    params = meta.init_params(cfg, jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda a: a[0], params["layers"]["moe"])
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))

    def loss(lp):
        y, aux = L.moe_apply(cfg, lp, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g = jax.grad(loss)(lp)
    for k, v in g.items():
        assert float(jnp.max(jnp.abs(v))) > 0, f"no grad to {k}"
