"""Cross-camera TrackQuery tests: fused similarity/association kernel
parity (fixed + property shapes), greedy one-to-one and query-mask
invariants, the kinded QuerySpec surface, keyword-only run_query, the
one-fused-launch-per-tick budget, hand-off determinism across reruns and
drivers, the predictive-handoff-beats-ablation acceptance, and the
edge_health snapshot on QueryReport."""
import dataclasses

import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.serving.simulator import Item
from repro.system import (
    QuerySpec,
    crowd_flow,
    homogeneous_multi_edge,
    run_query,
    single_edge,
    straggler_edge,
    vehicle_pursuit,
)

# --- ops.associate_tracks: Pallas vs ref parity -------------------------------


def _rand_problem(rng, m, k, d, nq=2):
    emb = rng.normal(size=(m, d)).astype(np.float32)
    emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-12)
    trk = rng.normal(size=(k, d)).astype(np.float32)
    trk /= np.maximum(np.linalg.norm(trk, axis=1, keepdims=True), 1e-12)
    cq = rng.integers(0, nq, m).astype(np.int32)
    tq = rng.integers(0, nq, k).astype(np.int32)
    thr = rng.uniform(-0.5, 0.9, m).astype(np.float32)
    return emb, trk, cq, tq, thr


@pytest.mark.parametrize("m,k,d", [(5, 7, 16), (1, 1, 4), (16, 16, 32),
                                   (9, 30, 20), (33, 3, 8)])
def test_associate_pallas_matches_ref(m, k, d):
    rng = np.random.default_rng(m * 100 + k)
    emb, trk, cq, tq, thr = _rand_problem(rng, m, k, d)
    ap, sp = ops.associate_tracks(emb, trk, cq, tq, thr)
    ar, sr = ops.associate_tracks(emb, trk, cq, tq, thr, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ap), np.asarray(ar))
    np.testing.assert_allclose(np.asarray(sp), np.asarray(sr),
                               rtol=1e-5, atol=1e-5)


def test_associate_empty_table_and_empty_crops():
    rng = np.random.default_rng(0)
    emb, trk, cq, tq, thr = _rand_problem(rng, 4, 6, 8)
    a, s = ops.associate_tracks(emb, trk[:0], cq, tq[:0], thr)
    assert np.all(np.asarray(a) == -1)
    a2, _ = ops.associate_tracks(emb[:0], trk, cq[:0], tq, thr[:0])
    assert np.asarray(a2).shape == (0,)


def test_associate_greedy_one_to_one_and_query_mask():
    rng = np.random.default_rng(7)
    emb, trk, cq, tq, thr = _rand_problem(rng, 24, 10, 16, nq=3)
    a, s = ops.associate_tracks(emb, trk, cq, tq, thr)
    a = np.asarray(a)
    claimed = a[a >= 0]
    assert len(claimed) == len(set(claimed)), "a track claimed twice"
    for i, j in enumerate(a):
        if j >= 0:
            assert cq[i] == tq[j], "association crossed query boundaries"
            assert np.asarray(s)[i] >= thr[i] - 1e-6


def test_associate_ref_prefers_best_available():
    # two crops chase the same track: the earlier crop wins it, the later
    # one falls to its next-best (greedy in crop order)
    trk = np.eye(3, dtype=np.float32)
    emb = np.stack([trk[0], 0.9 * trk[0] + 0.1 * trk[1]]).astype(np.float32)
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    q = np.zeros(2, np.int32)
    thr = np.full(2, 0.05, np.float32)
    a, _ = ref.associate_tracks_ref(emb, trk, q, np.zeros(3, np.int32), thr)
    assert a[0] == 0 and a[1] == 1


@pytest.mark.slow
def test_associate_bucket_padding_invisible_property():
    hypothesis = pytest.importorskip(
        "hypothesis",
        reason="property tests need hypothesis (pip install -r "
               "requirements-dev.txt)")
    del hypothesis
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=30, deadline=None)
    @given(m=st.integers(1, 21), k=st.integers(1, 19),
           d=st.integers(2, 24), seed=st.integers(0, 2**16))
    def check(m, k, d, seed):
        rng = np.random.default_rng(seed)
        emb, trk, cq, tq, thr = _rand_problem(rng, m, k, d)
        # wrapper (bucket-pads M, K, D internally) vs the ref oracle on
        # the UNPADDED inputs: padding must be invisible in the outputs
        a, s = ops.associate_tracks(emb, trk, cq, tq, thr)
        ar, sr = ref.associate_tracks_ref(emb, trk, cq, tq, thr)
        np.testing.assert_array_equal(np.asarray(a), ar)
        matched = np.asarray(a) >= 0
        np.testing.assert_allclose(np.asarray(s)[matched], sr[matched],
                                   rtol=1e-5, atol=1e-5)

    check()


# --- the kinded QuerySpec surface ---------------------------------------------


def test_queryspec_kind_validation():
    QuerySpec(0, kind="classify")
    QuerySpec(0, kind="track")
    with pytest.raises(ValueError, match="unknown kind"):
        QuerySpec(0, kind="reid")


def test_track_kind_rejects_superstep():
    with pytest.raises(ValueError, match="superstep"):
        dataclasses.replace(
            vehicle_pursuit(), superstep=4).__post_init__()


def test_existing_presets_bit_identical_under_kinded_spec():
    # satellite regression: classify-only presets produce the same
    # summary as before the kind field / track plumbing landed, and emit
    # NO track columns
    for preset in (single_edge, homogeneous_multi_edge):
        sc = preset(duration_s=15.0)
        s = run_query(sc).summary()
        assert not any(k.startswith(("track", "id_switch", "prewarm"))
                       for k in s), s
        assert s == run_query(sc).summary()


def test_run_query_knobs_keyword_only():
    sc = single_edge(duration_s=5.0)
    with pytest.raises(TypeError):
        run_query(sc, None)          # noqa: too many positional args
    with pytest.raises(ValueError, match="unknown frontend"):
        run_query(sc, frontend="cnn")
    r = run_query(sc, frontend="confidence")
    assert r.n_items > 0


# --- end-to-end track runs ----------------------------------------------------


def _pursuit(duration_s=25.0, **kw):
    return vehicle_pursuit(duration_s=duration_s, **kw)


def test_vehicle_pursuit_tracks_end_to_end():
    r = run_query(_pursuit())
    s = r.summary()
    assert s["track_items"] > 0
    assert s["tracks_born"] > 0
    assert s["track_matches"] > 0
    assert 0.0 <= s["track_continuity"] <= 1.0
    assert s["track_launches_per_tick"] <= 1.0 + 1e-9


def test_track_association_one_fused_launch_per_tick(monkeypatch):
    calls = {"n": 0}
    orig = ops.associate_tracks

    def counting(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    import repro.system.tracks as TK
    monkeypatch.setattr(TK.ops, "associate_tracks", counting)
    r = run_query(_pursuit())
    assert calls["n"] == r.track_launches
    assert r.track_launches <= r.ticks


def test_handoff_beats_no_handoff_ablation():
    sc = vehicle_pursuit()
    on = run_query(sc)
    off = run_query(dataclasses.replace(sc, predictive_handoff=False))
    assert on.prewarms_shipped > 0 and on.track_handoffs > 0
    assert on.prewarm_hits > 0
    assert off.prewarms_shipped == 0
    # the acceptance criterion: predictive hand-off strictly reduces
    # identity switches on the pursuit scenario
    assert on.id_switches < off.id_switches
    assert on.track_continuity > off.track_continuity


def test_handoff_decisions_deterministic_across_reruns_and_drivers():
    from repro.serving.engine import AsyncDriver, VirtualClock
    sc = _pursuit()
    a = run_query(sc)
    b = run_query(sc)
    c = run_query(sc, driver=AsyncDriver(VirtualClock()))
    for other in (b, c):
        assert a.summary() == other.summary()
        assert a.prewarms_shipped == other.prewarms_shipped
        assert a.id_switches == other.id_switches
        np.testing.assert_array_equal(a.latencies, other.latencies)


def test_crowd_flow_mixes_track_and_classify():
    r = run_query(crowd_flow(duration_s=20.0))
    s = r.summary()
    assert s["n_queries"] == 2
    assert s["track_items"] > 0
    # the classify query's items never enter the track registry
    assert s["track_items"] < r.n_items


def test_track_table_dies_with_query_retire():
    sc = crowd_flow(duration_s=20.0)
    specs = tuple(dataclasses.replace(sp, t_retire_s=8.0)
                  if sp.kind == "track" else sp for sp in sc.queries)
    r = run_query(dataclasses.replace(sc, queries=specs))
    # association stops at retire: far fewer track items than the full run
    assert 0 < r.track_items < run_query(sc).track_items


def test_edge_health_snapshot_on_report():
    r = run_query(straggler_edge(duration_s=20.0))
    assert set(r.edge_health) == set(straggler_edge().edge_ids)
    snap = r.edge_health[1]
    assert set(snap) == {"alerts", "recent", "total"}
    # straggler_edge kills edge 1 mid-run: its failover must be visible
    assert snap["alerts"].get("failover", 0) >= 1
    assert snap["total"] == sum(snap["alerts"].values())
    assert any(a["topic"].startswith("alerts/edge1/") for a in snap["recent"])


@pytest.mark.slow
def test_pixel_frontend_emits_embeddings_for_track_queries():
    from repro.system import PixelFrontend
    sc = vehicle_pursuit(num_cameras=4, num_edges=2, duration_s=3.0)
    items = PixelFrontend(seed=0).stream(sc)
    assert items, "pixel path produced no detections"
    assert all(it.emb is not None for it in items)
    for it in items[:5]:
        assert it.emb.shape == (sc.embedding_dim,)
        assert abs(float(np.linalg.norm(it.emb)) - 1.0) < 1e-5
    # no trajectory ground truth on the pixel path
    assert all(it.gt_track == -1 for it in items)


def test_confidence_stream_embeddings_and_gt():
    from repro.system import synthetic_confidence_stream
    sc = _pursuit(duration_s=10.0)
    items = synthetic_confidence_stream(sc)
    tracked = [it for it in items if it.emb is not None]
    assert tracked
    for it in tracked[:10]:
        assert it.gt_track >= 0
        assert abs(float(np.linalg.norm(it.emb)) - 1.0) < 1e-5


def test_item_defaults_inert():
    it = Item(0.0, 0, 1, 0.5, False)
    assert it.emb is None and it.gt_track == -1
