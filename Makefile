# Developer entry points.  The tier-1 verify command is `make test`.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Parallelize the suite across cores when pytest-xdist is installed (CI
# installs it via requirements-dev.txt; bare containers fall back to serial).
XDIST := $(shell $(PY) -c "import xdist" 2>/dev/null && echo "-n auto")

.PHONY: test bench-smoke bench dev-deps

test:            ## tier-1 test suite (the verify gate for every PR)
	$(PY) -m pytest -x -q $(XDIST)

bench-smoke:     ## fast end-to-end sanity: every scenario x scheme, no training
	$(PY) examples/run_scenarios.py --cameras 4 --duration 30
	$(PY) examples/run_scenarios.py --scenario city_scale --duration 20
	$(PY) examples/quickstart.py

bench:           ## full paper tables/figures (fine-tunes the workload; slow)
	$(PY) -m benchmarks.run

dev-deps:        ## install test/dev dependencies
	pip install -r requirements-dev.txt
