# Developer entry points.  The tier-1 verify command is `make test`.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

# Parallelize the suite across cores when pytest-xdist is installed (CI
# installs it via requirements-dev.txt; bare containers fall back to serial).
# The probe result is cached in .cache/xdist.mk — re-probed only when
# requirements-dev.txt or the active interpreter changes (the stamp records
# which interpreter it probed; `command -v` is a shell builtin, not another
# Python spawn per invocation), never on unrelated make targets.
XDIST :=
PYBIN := $(shell command -v $(PY))
-include .cache/xdist.mk
ifneq ($(XDIST_PY),$(PYBIN))
# stale cache from a different interpreter: drop the flag and re-probe
XDIST :=
.cache/xdist.mk: FORCE
endif
.cache/xdist.mk: requirements-dev.txt
	@mkdir -p .cache
	@echo 'XDIST_PY := $(PYBIN)' > $@
	@if $(PY) -c "import xdist" >/dev/null 2>&1; then \
	  echo 'XDIST := -n auto' >> $@; \
	else \
	  echo 'XDIST :=' >> $@; \
	fi
FORCE:

.PHONY: test test-slow test-sharded test-compiled lint bench-smoke bench \
	report-gate bench-gate dev-deps

test:            ## tier-1 test suite (the verify gate for every PR; excludes slow-marked tests)
	$(PY) -m pytest -x -q -m "not slow" $(XDIST)

# 8 faked host devices (the flag must be set before jax imports, hence a
# fresh interpreter): the superstep differential + sharded-vs-single-device
# equivalence tests actually exercise the shard_map path here, instead of
# skipping on the single-device default.
test-sharded:    ## superstep differential + sharding tests under 8 faked host devices
	XLA_FLAGS=--xla_force_host_platform_device_count=8 \
	$(PY) -m pytest -x -q -m "not slow" \
	  tests/test_superstep.py tests/test_metrics_stream.py

test-slow:       ## pixel-path + hypothesis-heavy tests (nightly-blocking, per-PR non-blocking CI job)
	$(PY) -m pytest -q -m slow

# Pixel-path tests with the interpret knob OFF: on a TPU runtime this
# exercises the real compiled Pallas lowering; on plain CPU (the GitHub
# runner) the launching tests skip cleanly via the compiled_available()
# probe and only the backend-free ones run — a green-but-skipped run here
# is expected, a FAILED one means the compiled path or the probe broke.
test-compiled:   ## pixel-cascade tests under REPRO_PALLAS_INTERPRET=0 (compiled Pallas where the backend lowers it)
	REPRO_PALLAS_INTERPRET=0 $(PY) -m pytest -x -q -rs tests/test_pixel_cascade.py

lint:            ## ruff check (CI blocks on this; skipped when ruff is absent)
	@if $(PY) -m ruff --version >/dev/null 2>&1; then \
	  $(PY) -m ruff check src tests benchmarks examples; \
	else \
	  echo "ruff not installed (run 'make dev-deps'); skipping lint"; \
	fi

# One process for every preset (`--scenario all` embeds the per-scenario
# smoke overrides incl. the pixel frontend for pixel_city) instead of five
# sequential interpreters each paying import + jit warmup.  Writes INTO
# reports/ — this is how the committed baselines are (re)blessed.
bench-smoke:     ## fast end-to-end sanity; regenerates per-scenario JSON baselines in reports/
	$(PY) examples/run_scenarios.py --scenario all --cameras 4 --duration 30 --json-out reports
	$(PY) examples/quickstart.py

# Inside GitHub Actions the gates also append a per-metric verdict table
# to the job summary page; local runs (no GITHUB_STEP_SUMMARY) skip it.
SUMMARY_FLAG = $(if $(GITHUB_STEP_SUMMARY),--summary-md "$(GITHUB_STEP_SUMMARY)")

REPORT_FRESH := .cache/reports-fresh
report-gate:     ## regenerate all scenario reports into a scratch dir and diff against committed reports/ baselines (tolerance bands; fails on breach)
	rm -rf $(REPORT_FRESH)
	$(PY) examples/run_scenarios.py --scenario all --cameras 4 --duration 30 --json-out $(REPORT_FRESH)
	$(PY) benchmarks/report_gate.py --fresh $(REPORT_FRESH) --baseline reports $(SUMMARY_FLAG)

# BENCH_GATE_FLAGS: extra report_gate.py flags — the PR-time CI job passes
# `--bench-substrate pallas_interpret` so only interpret rows gate on the
# CPU runner (compiled rows remain nightly/TPU business).
BENCH_FRESH := .cache/bench-fresh
bench-gate:      ## regenerate BENCH_pixel_cascade.json into a scratch dir and diff vs the committed baseline (one-sided >30% throughput regression fails)
	rm -rf $(BENCH_FRESH) && mkdir -p $(BENCH_FRESH)
	$(PY) -c "from benchmarks.kernel_bench import pixel_cascade_bench; \
	  pixel_cascade_bench(out_path='$(BENCH_FRESH)/BENCH_pixel_cascade.json')"
	$(PY) benchmarks/report_gate.py \
	  --bench-fresh $(BENCH_FRESH)/BENCH_pixel_cascade.json \
	  --bench-baseline benchmarks/BENCH_pixel_cascade.json \
	  $(BENCH_GATE_FLAGS) $(SUMMARY_FLAG)

bench:           ## full paper tables/figures (fine-tunes the workload; slow)
	$(PY) -m benchmarks.run

dev-deps:        ## install test/dev dependencies
	pip install -r requirements-dev.txt
