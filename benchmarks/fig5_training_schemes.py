"""Paper Fig. 5: training schemes — No-Fine-tune vs SurveilEdge vs
All-Fine-tune (accuracy + wall-clock training time, normalized)."""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import finetune as FT
from repro.data import synthetic_video as SV
from repro.models import meta as M
from repro.serving.workload import _binary_batches


def run(verbose: bool = True, steps: int = 60):
    full = get_config("surveiledge-cls")
    cfg = dataclasses.replace(full.edge_variant(), num_query_classes=2,
                              vocab_size=full.vocab_size)
    rng = np.random.default_rng(0)
    cams = SV.make_cameras(4, seed=0)
    profile = np.mean([c.class_mix for c in cams], axis=0)
    key = jax.random.PRNGKey(0)

    # 'pre-trained' backbone: generic multi-class pretraining (ImageNet analogue)
    def pretrain_iter():
        r = np.random.default_rng(1)
        while True:
            cls = r.integers(0, SV.NUM_CLASSES, size=64)
            tokens, labels = SV.labeled_crop_batch(cls, r, cfg.vocab_size)
            import jax.numpy as jnp
            yield jnp.asarray(tokens), jnp.asarray(
                (labels == SV.QUERY_CLASS).astype(np.int32))

    pre = M.init_params(cfg, key)
    pre = FT.finetune(cfg, pre, pretrain_iter(), steps=20, lr=1e-3).params

    ev = next(_binary_batches(np.random.default_rng(99), cfg, profile, None,
                              SV.QUERY_CLASS, batch=256))

    results = {}
    r_no = FT.run_scheme("no_finetune", cfg, pre, None, None, ev)
    results["no_finetune"] = {"accuracy": r_no[-1].accuracy, "train_s": 0.0}

    it_fn = lambda: _binary_batches(np.random.default_rng(2), cfg, profile,
                                    None, SV.QUERY_CLASS)
    r_se = FT.run_scheme("surveiledge", cfg, pre, it_fn, None, ev)
    results["surveiledge"] = {"accuracy": r_se[-1].accuracy,
                              "train_s": r_se[-1].train_seconds}

    cam_fns = {c.cam_id: (lambda cid=c.cam_id: _binary_batches(
        np.random.default_rng(10 + cid), cfg,
        cams[cid].class_mix, None, SV.QUERY_CLASS)) for c in cams}
    r_all = FT.run_scheme("all_finetune", cfg, pre, it_fn, cam_fns, ev)
    total_s = sum(r.train_seconds for r in r_all.values())
    acc = float(np.mean([r.accuracy for r in r_all.values()]))
    results["all_finetune"] = {"accuracy": acc, "train_s": total_s}

    if verbose:
        print("\n== Fig. 5 — training schemes ==")
        tmax = max(r["train_s"] for r in results.values()) or 1.0
        for k, v in results.items():
            print(f"{k:16s} accuracy={v['accuracy']:.3f} "
                  f"train_s={v['train_s']:.2f} (norm {v['train_s']/tmax:.2f})")
    derived = {
        "speedup_vs_all_finetune":
            results["all_finetune"]["train_s"] /
            max(results["surveiledge"]["train_s"], 1e-9),
        "acc_gap_to_all_finetune":
            results["all_finetune"]["accuracy"] -
            results["surveiledge"]["accuracy"],
        "acc_gain_vs_no_finetune":
            results["surveiledge"]["accuracy"] -
            results["no_finetune"]["accuracy"],
    }
    return results, derived


if __name__ == "__main__":
    print(run()[1])
