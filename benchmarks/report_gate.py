"""Regression gate over the scenario JSON reports: diff fresh runs
against the committed ``reports/`` baselines with tolerance bands.

CI's ``e2e-smoke`` used to only *upload* the per-scenario reports — a
silent accuracy or bandwidth regression sailed through as a green build
with a quietly different artifact.  This gate makes the reports load-
bearing: ``make report-gate`` regenerates every scenario into a scratch
dir (one process, ``run_scenarios.py --scenario all``) and fails the job
on any breach of:

  * ``accuracy_F2`` — within +/-0.05 ABSOLUTE of the baseline
  * bandwidth (``bandwidth_MB`` / ``lan_MB`` / ``downloaded_MB``) and
    latency (``avg_latency_s`` / ``p99_latency_s``) — within 25%
    relative (plus a small absolute floor so near-zero baselines don't
    flag on noise)
  * per-query rows (multi-query scenarios): each query's ``f2`` and
    ``avg_latency_s``, same bands
  * structure — a fresh report missing a baseline scenario/scheme/query
    (or vice versa) is a breach: new scenarios ship WITH their committed
    baselines, retired ones delete them

The simulation is seed-deterministic, so on an unchanged tree fresh ==
baseline exactly; the bands exist to absorb *intentional* small behavior
drift (a re-tuned threshold constant) without re-blessing every digit.
A genuine change beyond the bands is re-blessed by regenerating the
baselines in place (``make bench-smoke`` writes into ``reports/``) and
committing the diff — which the PR reviewer then sees as numbers, not as
a silently mutated artifact.

The same gate also covers the kernel-bench trajectory: ``--bench-fresh``
diffs a freshly generated ``BENCH_pixel_cascade.json`` against the
committed baseline and fails on a >30% ONE-SIDED throughput regression
(fused or staged rows getting slower; getting faster never breaches —
wall-clock microbenchmarks are noisy upward, regressions are the signal).
A fresh file whose substrate differs from the baseline's (e.g. compiled
Pallas became available) is reported as a structural breach so the
baseline gets re-blessed deliberately.

  PYTHONPATH=src python benchmarks/report_gate.py --fresh .cache/reports-fresh
  PYTHONPATH=src python benchmarks/report_gate.py --fresh DIR --baseline reports
  PYTHONPATH=src python benchmarks/report_gate.py \
      --bench-fresh .cache/BENCH_pixel_cascade.json \
      --bench-baseline benchmarks/BENCH_pixel_cascade.json
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys
from typing import Dict, List, Tuple

# metric -> (kind, band, absolute floor for relative bands)
TOLERANCES: Dict[str, Tuple[str, float, float]] = {
    "accuracy_F2": ("abs", 0.05, 0.0),
    "avg_latency_s": ("rel", 0.25, 0.05),
    "p99_latency_s": ("rel", 0.25, 0.10),
    "bandwidth_MB": ("rel", 0.25, 0.05),
    "lan_MB": ("rel", 0.25, 0.05),
    "downloaded_MB": ("rel", 0.25, 0.05),
}
PER_QUERY_TOLERANCES: Dict[str, Tuple[str, float, float]] = {
    "f2": ("abs", 0.05, 0.0),
    "avg_latency_s": ("rel", 0.25, 0.10),
}


def _check(metric: str, base: float, fresh: float,
           spec: Tuple[str, float, float]) -> str:
    """One metric against its band; returns a breach message or ''."""
    kind, band, floor = spec
    if kind == "abs":
        tol = band
    else:
        tol = max(band * abs(base), floor)
    if abs(fresh - base) > tol:
        return (f"{metric}: fresh={fresh} vs baseline={base} "
                f"(|delta|={abs(fresh - base):.4g} > tol={tol:.4g} "
                f"[{kind} {band}])")
    return ""


def compare_rows(base: dict, fresh: dict,
                 tolerances: Dict[str, Tuple[str, float, float]]
                 ) -> List[str]:
    """Diff one scheme (or per-query) row; missing metrics are breaches."""
    out = []
    for metric, spec in tolerances.items():
        if metric not in base:
            continue                  # older baseline without the column
        if metric not in fresh:
            out.append(f"{metric}: missing from fresh report")
            continue
        msg = _check(metric, float(base[metric]), float(fresh[metric]), spec)
        if msg:
            out.append(msg)
    return out


def compare_report(baseline: dict, fresh: dict) -> List[str]:
    """All breaches between one scenario's baseline and fresh report."""
    breaches: List[str] = []
    name = baseline.get("scenario", "?")
    b_schemes = baseline.get("schemes", {})
    f_schemes = fresh.get("schemes", {})
    for scheme in sorted(set(b_schemes) | set(f_schemes)):
        tag = f"{name}/{scheme}"
        if scheme not in f_schemes:
            breaches.append(f"{tag}: scheme missing from fresh report")
            continue
        if scheme not in b_schemes:
            breaches.append(f"{tag}: scheme has no committed baseline "
                            f"(regenerate reports/ and commit)")
            continue
        b_row, f_row = b_schemes[scheme], f_schemes[scheme]
        breaches.extend(f"{tag}: {m}"
                        for m in compare_rows(b_row, f_row, TOLERANCES))
        b_q = b_row.get("queries", {})
        f_q = f_row.get("queries", {})
        for q in sorted(set(b_q) | set(f_q)):
            qtag = f"{tag}/q{q}"
            if q not in f_q:
                breaches.append(f"{qtag}: query missing from fresh report")
            elif q not in b_q:
                breaches.append(f"{qtag}: query has no committed baseline")
            else:
                breaches.extend(
                    f"{qtag}: {m}" for m in
                    compare_rows(b_q[q], f_q[q], PER_QUERY_TOLERANCES))
    return breaches


def gate(fresh_dir: str, baseline_dir: str) -> List[str]:
    """Diff every ``*.json`` pairwise by filename; structural gaps breach."""
    base_files = {os.path.basename(p)
                  for p in glob.glob(os.path.join(baseline_dir, "*.json"))}
    fresh_files = {os.path.basename(p)
                   for p in glob.glob(os.path.join(fresh_dir, "*.json"))}
    breaches: List[str] = []
    for fn in sorted(base_files - fresh_files):
        breaches.append(f"{fn}: committed baseline has no fresh run "
                        f"(scenario dropped? delete the stale baseline)")
    for fn in sorted(fresh_files - base_files):
        breaches.append(f"{fn}: fresh report has no committed baseline "
                        f"(new scenario? run `make bench-smoke` and commit "
                        f"reports/{fn})")
    for fn in sorted(base_files & fresh_files):
        with open(os.path.join(baseline_dir, fn)) as fh:
            base = json.load(fh)
        with open(os.path.join(fresh_dir, fn)) as fh:
            fresh = json.load(fh)
        breaches.extend(compare_report(base, fresh))
    return breaches


#: one-sided relative throughput band for the kernel-bench gate: a fresh
#: Mpx_s more than this fraction BELOW baseline is a breach (faster is not)
BENCH_REGRESSION_BAND = 0.30


def bench_gate(fresh_path: str, baseline_path: str) -> List[str]:
    """Diff a fresh BENCH_pixel_cascade.json against the committed one.

    One-sided: only throughput (``Mpx_s``) drops beyond
    ``BENCH_REGRESSION_BAND`` breach.  Structure (shapes, rows) and the
    recorded substrate must match — a substrate flip (interpret baseline
    vs newly available compiled Pallas) is a deliberate re-bless, not
    noise to absorb.
    """
    with open(baseline_path) as fh:
        base = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    breaches: List[str] = []
    b_shapes = base.get("shapes", {})
    f_shapes = fresh.get("shapes", {})
    for key in sorted(set(b_shapes) | set(f_shapes)):
        if key not in f_shapes:
            breaches.append(f"{key}: shape missing from fresh bench")
            continue
        if key not in b_shapes:
            breaches.append(f"{key}: shape has no committed baseline "
                            f"(regenerate BENCH_pixel_cascade.json and "
                            f"commit)")
            continue
        b_rows = b_shapes[key].get("rows", {})
        f_rows = f_shapes[key].get("rows", {})
        for row in sorted(set(b_rows) | set(f_rows)):
            tag = f"{key}/{row}"
            if row not in f_rows:
                breaches.append(f"{tag}: row missing from fresh bench")
                continue
            if row not in b_rows:
                breaches.append(f"{tag}: row has no committed baseline")
                continue
            b_sub = b_rows[row].get("substrate")
            f_sub = f_rows[row].get("substrate")
            if b_sub != f_sub:
                breaches.append(
                    f"{tag}: substrate changed {b_sub} -> {f_sub} "
                    f"(re-bless the baseline deliberately)")
                continue
            b_tp = float(b_rows[row]["Mpx_s"])
            f_tp = float(f_rows[row]["Mpx_s"])
            if f_tp < b_tp * (1.0 - BENCH_REGRESSION_BAND):
                breaches.append(
                    f"{tag}: throughput {f_tp} Mpx/s is more than "
                    f"{BENCH_REGRESSION_BAND:.0%} below baseline "
                    f"{b_tp} Mpx/s")
    return breaches


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh",
                    help="directory of freshly generated scenario reports")
    ap.add_argument("--baseline", default="reports",
                    help="directory of committed baselines (default: "
                         "reports/)")
    ap.add_argument("--bench-fresh",
                    help="freshly generated BENCH_pixel_cascade.json to "
                         "gate against --bench-baseline")
    ap.add_argument("--bench-baseline",
                    default=os.path.join("benchmarks",
                                         "BENCH_pixel_cascade.json"),
                    help="committed bench baseline (default: "
                         "benchmarks/BENCH_pixel_cascade.json)")
    args = ap.parse_args()
    if not args.fresh and not args.bench_fresh:
        ap.error("need --fresh and/or --bench-fresh")
    breaches: List[str] = []
    n = 0
    if args.fresh:
        if not glob.glob(os.path.join(args.fresh, "*.json")):
            print(f"report-gate: no fresh reports in {args.fresh}",
                  file=sys.stderr)
            return 2
        breaches.extend(gate(args.fresh, args.baseline))
        n += len(glob.glob(os.path.join(args.fresh, "*.json")))
    if args.bench_fresh:
        if not os.path.exists(args.bench_fresh):
            print(f"report-gate: no fresh bench at {args.bench_fresh}",
                  file=sys.stderr)
            return 2
        breaches.extend(f"bench: {b}"
                        for b in bench_gate(args.bench_fresh,
                                            args.bench_baseline))
        n += 1
    if breaches:
        print(f"report-gate: {len(breaches)} breach(es):", file=sys.stderr)
        for b in breaches:
            print(f"  BREACH {b}", file=sys.stderr)
        return 1
    print(f"report-gate: {n} artifact(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
