"""Regression gate over the scenario JSON reports: diff fresh runs
against the committed ``reports/`` baselines with tolerance bands.

CI's ``e2e-smoke`` used to only *upload* the per-scenario reports — a
silent accuracy or bandwidth regression sailed through as a green build
with a quietly different artifact.  This gate makes the reports load-
bearing: ``make report-gate`` regenerates every scenario into a scratch
dir (one process, ``run_scenarios.py --scenario all``) and fails the job
on any breach of:

  * ``accuracy_F2`` — within +/-0.05 ABSOLUTE of the baseline
  * bandwidth (``bandwidth_MB`` / ``lan_MB`` / ``downloaded_MB``) and
    latency (``avg_latency_s`` / ``p99_latency_s``) — within 25%
    relative (plus a small absolute floor so near-zero baselines don't
    flag on noise)
  * per-query rows (multi-query scenarios): each query's ``f2`` and
    ``avg_latency_s``, same bands
  * control-plane columns (``rush_hour``): ``shed_rate`` (±0.10 abs),
    ``alerts_total`` (coarse 50% band), per-tier p99 latencies — and
    ``slo_breach_top_tier`` at ZERO tolerance (the preset exists to
    prove the platinum tier never breaches)
  * structure — a fresh report missing a baseline scenario/scheme/query
    (or vice versa) is a breach: new scenarios ship WITH their committed
    baselines, retired ones delete them

The simulation is seed-deterministic, so on an unchanged tree fresh ==
baseline exactly; the bands exist to absorb *intentional* small behavior
drift (a re-tuned threshold constant) without re-blessing every digit.
A genuine change beyond the bands is re-blessed by regenerating the
baselines in place (``make bench-smoke`` writes into ``reports/``) and
committing the diff — which the PR reviewer then sees as numbers, not as
a silently mutated artifact.

The same gate also covers the kernel-bench trajectory: ``--bench-fresh``
diffs a freshly generated ``BENCH_pixel_cascade.json`` against the
committed baseline and fails on a >30% ONE-SIDED throughput regression
(fused or staged rows getting slower; getting faster never breaches —
wall-clock microbenchmarks are noisy upward, regressions are the signal).
A fresh file whose substrate differs from the baseline's (e.g. compiled
Pallas became available) is reported as a structural breach so the
baseline gets re-blessed deliberately.  ``--bench-substrate SUB``
(repeatable) restricts the bench gate to rows whose baseline substrate
matches — the PR-time CPU job gates ``pallas_interpret`` rows and leaves
the compiled rows to nightly/TPU.

Fresh scheme rows additionally pass physical-consistency checks with no
baseline involved (``row_consistency``): fused recalibration launches
with zero downlink bytes, or quantized downlink bytes exceeding the
row's own fp-equivalent reference, fail the gate outright.

``--summary-md PATH`` appends a per-metric verdict table (value,
baseline, tolerance, pass/fail) to PATH; CI points it at
``$GITHUB_STEP_SUMMARY`` so deltas land on the job page.

  PYTHONPATH=src python benchmarks/report_gate.py --fresh .cache/reports-fresh
  PYTHONPATH=src python benchmarks/report_gate.py --fresh DIR --baseline reports
  PYTHONPATH=src python benchmarks/report_gate.py \
      --bench-fresh .cache/BENCH_pixel_cascade.json \
      --bench-baseline benchmarks/BENCH_pixel_cascade.json \
      --bench-substrate pallas_interpret \
      --summary-md "$GITHUB_STEP_SUMMARY"
"""
from __future__ import annotations

import argparse
import dataclasses
import glob
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

# metric -> (kind, band, absolute floor for relative bands)
TOLERANCES: Dict[str, Tuple[str, float, float]] = {
    "accuracy_F2": ("abs", 0.05, 0.0),
    "avg_latency_s": ("rel", 0.25, 0.05),
    "p99_latency_s": ("rel", 0.25, 0.10),
    "bandwidth_MB": ("rel", 0.25, 0.05),
    "lan_MB": ("rel", 0.25, 0.05),
    "downloaded_MB": ("rel", 0.25, 0.05),
    # bandwidth-endgame columns: the fp-equivalent downlink reference,
    # upload spent per useful answer, and the speculative-escalation pair
    # (flip rate is an absolute band — its baseline is near zero, so a
    # relative band would either always pass or always fail)
    "downlink_fp_MB": ("rel", 0.25, 0.05),
    "uplink_bytes_per_TP": ("rel", 0.25, 256.0),
    "reconciliation_flip_rate": ("abs", 0.05, 0.0),
    "provisional_latency_s": ("rel", 0.25, 0.05),
    # control-plane columns (rush_hour): admission shed fraction, alert
    # volume (coarse band — queue-depth alerts ride load noise), and the
    # per-tier tail latencies.  slo_breach_top_tier is zero-band: the
    # preset's whole point is that the platinum tier NEVER breaches, so
    # any drift there is a regression, not noise.
    "shed_rate": ("abs", 0.10, 0.0),
    "alerts_total": ("rel", 0.50, 3.0),
    "slo_breach_top_tier": ("abs", 0.0, 0.0),
    "p99_latency_tier0": ("rel", 0.25, 0.10),
    "p99_latency_tier1": ("rel", 0.25, 0.10),
    "p99_latency_tier2": ("rel", 0.25, 0.10),
    # cross-camera track columns (vehicle_pursuit / crowd_flow): identity
    # continuity absolute (it is a 0..1 rate), count columns relative
    # with small floors (association is deterministic, but intentional
    # threshold re-tunes shift counts by a few), and the per-tick fused
    # associate launch budget near-exact
    "track_continuity": ("abs", 0.08, 0.0),
    "id_switches": ("rel", 0.30, 3.0),
    "tracks_born": ("rel", 0.30, 3.0),
    "track_handoffs": ("rel", 0.30, 3.0),
    "prewarms_shipped": ("rel", 0.50, 3.0),
    "prewarm_hits": ("rel", 0.50, 3.0),
    "track_launches_per_tick": ("abs", 0.05, 0.0),
}
PER_QUERY_TOLERANCES: Dict[str, Tuple[str, float, float]] = {
    "f2": ("abs", 0.05, 0.0),
    "avg_latency_s": ("rel", 0.25, 0.10),
}


@dataclasses.dataclass
class Check:
    """One verdict row for the ``--summary-md`` table: every compared
    metric (pass or fail) plus every structural/consistency breach."""
    tag: str                       # e.g. "drifting_city/surveiledge"
    metric: str
    fresh: object                  # value, or None when missing
    base: object
    tol: str                       # human-readable band, e.g. "±25% rel"
    ok: bool
    note: str = ""


def _tol_str(spec: Tuple[str, float, float]) -> str:
    kind, band, floor = spec
    if kind == "abs":
        return f"±{band} abs"
    return f"±{band:.0%} rel (floor {floor})"


def _note(checks: Optional[List[Check]], tag: str, metric: str,
          fresh, base, tol: str, ok: bool, note: str = "") -> None:
    if checks is not None:
        checks.append(Check(tag, metric, fresh, base, tol, ok, note))


def _check(metric: str, base: float, fresh: float,
           spec: Tuple[str, float, float]) -> str:
    """One metric against its band; returns a breach message or ''."""
    kind, band, floor = spec
    if kind == "abs":
        tol = band
    else:
        tol = max(band * abs(base), floor)
    if abs(fresh - base) > tol:
        return (f"{metric}: fresh={fresh} vs baseline={base} "
                f"(|delta|={abs(fresh - base):.4g} > tol={tol:.4g} "
                f"[{kind} {band}])")
    return ""


def compare_rows(base: dict, fresh: dict,
                 tolerances: Dict[str, Tuple[str, float, float]],
                 checks: Optional[List[Check]] = None,
                 tag: str = "") -> List[str]:
    """Diff one scheme (or per-query) row; missing metrics are breaches."""
    out = []
    for metric, spec in tolerances.items():
        if metric not in base:
            continue                  # older baseline without the column
        if metric not in fresh:
            out.append(f"{metric}: missing from fresh report")
            _note(checks, tag, metric, None, base[metric], _tol_str(spec),
                  False, "missing from fresh report")
            continue
        msg = _check(metric, float(base[metric]), float(fresh[metric]), spec)
        _note(checks, tag, metric, fresh[metric], base[metric],
              _tol_str(spec), not msg, msg)
        if msg:
            out.append(msg)
    return out


def row_consistency(tag: str, row: dict,
                    checks: Optional[List[Check]] = None) -> List[str]:
    """Physical-impossibility checks on ONE fresh scheme row.

    These hold regardless of any baseline: a run claiming fused
    recalibration launches must have shipped downlink bytes, and the
    charged (possibly quantized) downlink bytes can never exceed the
    row's own fp-equivalent reference — quantized shipping costing MORE
    than full-width fp is a wire-accounting bug, not drift to absorb."""
    out = []
    down = row.get("downloaded_bytes")
    if down is None:                  # older artifact: MB-only columns
        down = row.get("downloaded_MB", 0.0)
    if row.get("model_updates", 0) > 0 and down == 0:
        msg = (f"model_updates={row['model_updates']} but zero downlink "
               f"bytes — updates that never crossed the downlink")
        out.append(msg)
        _note(checks, tag, "downloaded_bytes", down,
              row.get("model_updates"), "> 0 when updates > 0", False, msg)
    fp_down = row.get("downlink_fp_bytes")
    if fp_down is not None and down > fp_down:
        msg = (f"downloaded_bytes={down} exceeds fp-equivalent reference "
               f"downlink_fp_bytes={fp_down} — quantized shipping cannot "
               f"cost more than full-width fp")
        out.append(msg)
        _note(checks, tag, "downloaded_bytes", down, fp_down,
              "<= downlink_fp_bytes", False, msg)
    # only the full adaptive scheme carries the zero-breach guarantee:
    # the ablation rows (fixed thresholds, edge_only, cloud_only) breach
    # tier 0 BY DESIGN — that contrast is the table's whole argument
    if tag.endswith("/surveiledge") \
            and row.get("slo_breach_top_tier", 0) > 0:
        msg = (f"slo_breach_top_tier={row['slo_breach_top_tier']} — the "
               f"top priority tier breached its SLO; admission control "
               f"failed to protect it")
        out.append(msg)
        _note(checks, tag, "slo_breach_top_tier",
              row["slo_breach_top_tier"], 0, "== 0", False, msg)
    if row.get("shed_queries", 0) > 0 and row.get("alerts_total", 0) == 0:
        msg = (f"shed_queries={row['shed_queries']} but alerts_total=0 — "
               f"admission shed queries without publishing alert events")
        out.append(msg)
        _note(checks, tag, "alerts_total", 0, row.get("shed_queries"),
              "> 0 when sheds > 0", False, msg)
    # the fused-association launch budget: at most ONE
    # ops.associate_tracks launch per scheduler tick, fleet-wide
    lpt = row.get("track_launches_per_tick", 0.0)
    if lpt > 1.0:
        msg = (f"track_launches_per_tick={lpt} > 1 — association must be "
               f"ONE fused launch per tick")
        out.append(msg)
        _note(checks, tag, "track_launches_per_tick", lpt, 1.0, "<= 1",
              False, msg)
    return out


def handoff_wins(name: str, schemes: dict,
                 checks: Optional[List[Check]] = None) -> List[str]:
    """The predictive hand-off must BEAT its own ablation within one
    fresh report.  The row pair is deterministic (same stream, same
    seed), so the comparison is exact: where pre-warms actually landed
    (``prewarm_hits > 0`` — vehicle_pursuit's sparse chase), ID switches
    must be STRICTLY below the no-handoff row; where the fleet stays
    naturally warm (crowd_flow's dense flow, zero hits), hand-off must
    at least do no harm."""
    on = schemes.get("surveiledge", {})
    off = schemes.get("surveiledge_no_handoff", {})
    if not (on.get("track_items") and off.get("track_items")):
        return []
    tag = f"{name}/surveiledge"
    sw_on, sw_off = on.get("id_switches", 0), off.get("id_switches", 0)
    strict = on.get("prewarm_hits", 0) > 0
    ok = sw_on < sw_off if strict else sw_on <= sw_off
    band = "< no_handoff (prewarms hit)" if strict else "<= no_handoff"
    _note(checks, tag, "id_switches(handoff vs ablation)", sw_on, sw_off,
          band, ok,
          "" if ok else "predictive hand-off no longer reduces ID switches")
    if not ok:
        return [f"{tag}: id_switches={sw_on} vs the no-handoff ablation's "
                f"{sw_off} (required {band}) — the predictive hand-off "
                f"stopped winning"]
    return []


def compare_report(baseline: dict, fresh: dict,
                   checks: Optional[List[Check]] = None) -> List[str]:
    """All breaches between one scenario's baseline and fresh report."""
    breaches: List[str] = []
    name = baseline.get("scenario", "?")
    b_schemes = baseline.get("schemes", {})
    f_schemes = fresh.get("schemes", {})
    breaches.extend(handoff_wins(name, f_schemes, checks))
    for scheme in sorted(set(b_schemes) | set(f_schemes)):
        tag = f"{name}/{scheme}"
        if scheme not in f_schemes:
            breaches.append(f"{tag}: scheme missing from fresh report")
            _note(checks, tag, "(scheme)", None, "present", "structure",
                  False, "scheme missing from fresh report")
            continue
        if scheme not in b_schemes:
            breaches.append(f"{tag}: scheme has no committed baseline "
                            f"(regenerate reports/ and commit)")
            _note(checks, tag, "(scheme)", "present", None, "structure",
                  False, "scheme has no committed baseline")
            continue
        b_row, f_row = b_schemes[scheme], f_schemes[scheme]
        breaches.extend(f"{tag}: {m}" for m in
                        compare_rows(b_row, f_row, TOLERANCES, checks, tag))
        breaches.extend(f"{tag}: {m}"
                        for m in row_consistency(tag, f_row, checks))
        b_q = b_row.get("queries", {})
        f_q = f_row.get("queries", {})
        for q in sorted(set(b_q) | set(f_q)):
            qtag = f"{tag}/q{q}"
            if q not in f_q:
                breaches.append(f"{qtag}: query missing from fresh report")
                _note(checks, qtag, "(query)", None, "present", "structure",
                      False, "query missing from fresh report")
            elif q not in b_q:
                breaches.append(f"{qtag}: query has no committed baseline")
                _note(checks, qtag, "(query)", "present", None, "structure",
                      False, "query has no committed baseline")
            else:
                breaches.extend(
                    f"{qtag}: {m}" for m in
                    compare_rows(b_q[q], f_q[q], PER_QUERY_TOLERANCES,
                                 checks, qtag))
    return breaches


def gate(fresh_dir: str, baseline_dir: str,
         checks: Optional[List[Check]] = None) -> List[str]:
    """Diff every ``*.json`` pairwise by filename; structural gaps breach."""
    base_files = {os.path.basename(p)
                  for p in glob.glob(os.path.join(baseline_dir, "*.json"))}
    fresh_files = {os.path.basename(p)
                   for p in glob.glob(os.path.join(fresh_dir, "*.json"))}
    breaches: List[str] = []
    for fn in sorted(base_files - fresh_files):
        breaches.append(f"{fn}: committed baseline has no fresh run "
                        f"(scenario dropped? delete the stale baseline)")
        _note(checks, fn, "(report)", None, "present", "structure", False,
              "committed baseline has no fresh run")
    for fn in sorted(fresh_files - base_files):
        breaches.append(f"{fn}: fresh report has no committed baseline "
                        f"(new scenario? run `make bench-smoke` and commit "
                        f"reports/{fn})")
        _note(checks, fn, "(report)", "present", None, "structure", False,
              "fresh report has no committed baseline")
    for fn in sorted(base_files & fresh_files):
        with open(os.path.join(baseline_dir, fn)) as fh:
            base = json.load(fh)
        with open(os.path.join(fresh_dir, fn)) as fh:
            fresh = json.load(fh)
        breaches.extend(compare_report(base, fresh, checks))
    return breaches


#: one-sided relative throughput band for the kernel-bench gate: a fresh
#: Mpx_s more than this fraction BELOW baseline is a breach (faster is not)
BENCH_REGRESSION_BAND = 0.30


def bench_gate(fresh_path: str, baseline_path: str,
               substrates: Optional[List[str]] = None,
               checks: Optional[List[Check]] = None) -> List[str]:
    """Diff a fresh BENCH_pixel_cascade.json against the committed one.

    One-sided: only throughput (``Mpx_s``) drops beyond
    ``BENCH_REGRESSION_BAND`` breach.  Structure (shapes, rows) and the
    recorded substrate must match — a substrate flip (interpret baseline
    vs newly available compiled Pallas) is a deliberate re-bless, not
    noise to absorb.

    ``substrates`` restricts the gate to rows whose BASELINE substrate is
    in the list (e.g. ``["pallas_interpret"]`` on a PR-time CPU runner:
    interpret rows gate, compiled/TPU rows stay nightly's business).
    """
    with open(baseline_path) as fh:
        base = json.load(fh)
    with open(fresh_path) as fh:
        fresh = json.load(fh)
    breaches: List[str] = []
    b_shapes = base.get("shapes", {})
    f_shapes = fresh.get("shapes", {})
    for key in sorted(set(b_shapes) | set(f_shapes)):
        if key not in f_shapes:
            breaches.append(f"{key}: shape missing from fresh bench")
            _note(checks, key, "(shape)", None, "present", "structure",
                  False, "shape missing from fresh bench")
            continue
        if key not in b_shapes:
            breaches.append(f"{key}: shape has no committed baseline "
                            f"(regenerate BENCH_pixel_cascade.json and "
                            f"commit)")
            _note(checks, key, "(shape)", "present", None, "structure",
                  False, "shape has no committed baseline")
            continue
        b_rows = b_shapes[key].get("rows", {})
        f_rows = f_shapes[key].get("rows", {})
        for row in sorted(set(b_rows) | set(f_rows)):
            tag = f"{key}/{row}"
            b_sub = b_rows[row].get("substrate") if row in b_rows else None
            if substrates is not None and row in b_rows \
                    and b_sub not in substrates:
                continue              # this substrate gates elsewhere
            if row not in f_rows:
                breaches.append(f"{tag}: row missing from fresh bench")
                _note(checks, tag, "(row)", None, "present", "structure",
                      False, "row missing from fresh bench")
                continue
            if row not in b_rows:
                if substrates is not None \
                        and f_rows[row].get("substrate") not in substrates:
                    continue
                breaches.append(f"{tag}: row has no committed baseline")
                _note(checks, tag, "(row)", "present", None, "structure",
                      False, "row has no committed baseline")
                continue
            f_sub = f_rows[row].get("substrate")
            if b_sub != f_sub:
                msg = (f"substrate changed {b_sub} -> {f_sub} "
                       f"(re-bless the baseline deliberately)")
                breaches.append(f"{tag}: {msg}")
                _note(checks, tag, "substrate", f_sub, b_sub, "exact",
                      False, msg)
                continue
            b_tp = float(b_rows[row]["Mpx_s"])
            f_tp = float(f_rows[row]["Mpx_s"])
            slow = f_tp < b_tp * (1.0 - BENCH_REGRESSION_BAND)
            _note(checks, tag, "Mpx_s", f_tp, b_tp,
                  f"-{BENCH_REGRESSION_BAND:.0%} one-sided", not slow)
            if slow:
                breaches.append(
                    f"{tag}: throughput {f_tp} Mpx/s is more than "
                    f"{BENCH_REGRESSION_BAND:.0%} below baseline "
                    f"{b_tp} Mpx/s")
    return breaches


def write_summary_md(path: str, checks: List[Check]) -> None:
    """Append a per-metric verdict table (GitHub-flavored markdown) to
    ``path`` — in CI that is ``$GITHUB_STEP_SUMMARY``, so the deltas land
    on the job page instead of inside an uploaded JSON artifact.
    Failures render first; passing rows fold into a ``<details>``."""
    def fmt(v) -> str:
        if v is None:
            return "—"
        if isinstance(v, float):
            return f"{v:.4g}"
        return str(v)

    def table(rows: List[Check]) -> List[str]:
        out = ["| artifact | metric | fresh | baseline | tolerance | "
               "verdict |", "|---|---|---|---|---|---|"]
        for c in rows:
            verdict = "✅ pass" if c.ok else f"❌ FAIL {c.note}".rstrip()
            out.append(f"| {c.tag} | {c.metric} | {fmt(c.fresh)} | "
                       f"{fmt(c.base)} | {c.tol} | {verdict} |")
        return out

    fails = [c for c in checks if not c.ok]
    passes = [c for c in checks if c.ok]
    lines = [f"### report-gate: {len(fails)} breach(es), "
             f"{len(passes)} metric(s) within tolerance", ""]
    if fails:
        lines += table(fails) + [""]
    if passes:
        lines += ["<details><summary>"
                  f"{len(passes)} passing metric(s)</summary>", ""]
        lines += table(passes)
        lines += ["", "</details>", ""]
    with open(path, "a") as fh:
        fh.write("\n".join(lines) + "\n")


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fresh",
                    help="directory of freshly generated scenario reports")
    ap.add_argument("--baseline", default="reports",
                    help="directory of committed baselines (default: "
                         "reports/)")
    ap.add_argument("--bench-fresh",
                    help="freshly generated BENCH_pixel_cascade.json to "
                         "gate against --bench-baseline")
    ap.add_argument("--bench-baseline",
                    default=os.path.join("benchmarks",
                                         "BENCH_pixel_cascade.json"),
                    help="committed bench baseline (default: "
                         "benchmarks/BENCH_pixel_cascade.json)")
    ap.add_argument("--bench-substrate", action="append", default=None,
                    metavar="SUB",
                    help="gate only bench rows whose baseline substrate "
                         "matches (repeatable; e.g. pallas_interpret for "
                         "PR-time CPU runners — compiled rows stay "
                         "nightly-only)")
    ap.add_argument("--summary-md", metavar="PATH", default=None,
                    help="append a per-metric verdict table (markdown) to "
                         "PATH — point this at $GITHUB_STEP_SUMMARY in CI")
    args = ap.parse_args()
    if not args.fresh and not args.bench_fresh:
        ap.error("need --fresh and/or --bench-fresh")
    breaches: List[str] = []
    checks: List[Check] = []
    n = 0
    if args.fresh:
        if not glob.glob(os.path.join(args.fresh, "*.json")):
            print(f"report-gate: no fresh reports in {args.fresh}",
                  file=sys.stderr)
            return 2
        breaches.extend(gate(args.fresh, args.baseline, checks))
        n += len(glob.glob(os.path.join(args.fresh, "*.json")))
    if args.bench_fresh:
        if not os.path.exists(args.bench_fresh):
            print(f"report-gate: no fresh bench at {args.bench_fresh}",
                  file=sys.stderr)
            return 2
        breaches.extend(f"bench: {b}"
                        for b in bench_gate(args.bench_fresh,
                                            args.bench_baseline,
                                            args.bench_substrate, checks))
        n += 1
    if args.summary_md:
        write_summary_md(args.summary_md, checks)
    if breaches:
        print(f"report-gate: {len(breaches)} breach(es):", file=sys.stderr)
        for b in breaches:
            print(f"  BREACH {b}", file=sys.stderr)
        return 1
    print(f"report-gate: {n} artifact(s) within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
