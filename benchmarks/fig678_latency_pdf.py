"""Paper Figs. 6-8: per-frame latency distributions (PDF + variance) under
the three cluster settings; shows the allocator's variance reduction."""
from __future__ import annotations

import numpy as np

from benchmarks import common


def _pdf_stats(latencies: np.ndarray, bins: int = 20):
    hist, edges = np.histogram(latencies, bins=bins, density=True)
    return {"mean": float(np.mean(latencies)),
            "var": float(np.var(latencies)),
            "p50": float(np.percentile(latencies, 50)),
            "p99": float(np.percentile(latencies, 99)),
            "mode_bin": float(edges[int(np.argmax(hist))])}


def run(verbose: bool = True):
    wl = common.shared_workload()
    settings = {
        "single (fig6)": [1.0],
        "homogeneous (fig7)": [1.0, 1.0, 1.0],
        "heterogeneous (fig8)": [1.0, 0.5, 0.25],
    }
    out = {}
    for name, speeds in settings.items():
        rows = common.run_schemes(wl, edge_service=speeds, seed=21,
                                  name=name.split(" ")[0])
        out[name] = {s: _pdf_stats(rows[s]["_result"].latencies)
                     for s in common.SCHEMES}
        if verbose:
            print(f"\n== latency PDFs — {name} ==")
            for s in common.SCHEMES:
                st = out[name][s]
                print(f"{s:20s} mean={st['mean']:7.3f} var={st['var']:9.3f} "
                      f"p50={st['p50']:7.3f} p99={st['p99']:8.3f}")
    derived = {
        f"var_reduction_vs_fixed[{k}]":
            v["surveiledge_fixed"]["var"] / max(v["surveiledge"]["var"], 1e-9)
        for k, v in out.items()
    }
    return out, derived


if __name__ == "__main__":
    print(run()[1])
