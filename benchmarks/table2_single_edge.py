"""Paper Table II: single edge + cloud, four query schemes.

Runs the ``repro.system`` end-to-end harness (one ``run_query`` per scheme)
on the single-edge scenario over the shared CQ-scored workload.
"""
from __future__ import annotations

from benchmarks import common


def run(verbose: bool = True):
    wl = common.shared_workload()
    rows = common.run_schemes(wl, edge_service=[1.0], seed=11,
                              name="single_edge")
    if verbose:
        common.print_table("Table II — single edge + cloud", rows)
    se, co, eo = rows["surveiledge"], rows["cloud_only"], rows["edge_only"]
    derived = {
        "bandwidth_reduction_vs_cloud": co["bandwidth_MB"] / max(se["bandwidth_MB"], 1e-9),
        "speedup_vs_cloud": co["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "speedup_vs_edge": eo["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "accuracy_gain_vs_edge": se["accuracy_F2"] - eo["accuracy_F2"],
    }
    return rows, derived


if __name__ == "__main__":
    _, derived = run()
    print(derived)
