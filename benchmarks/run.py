"""Benchmark driver: one function per paper table/figure + framework benches.

Prints ``name,us_per_call,derived`` CSV lines per the harness contract, and
human-readable tables above them.
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (ablation_thresholds, fig5_training_schemes,
                            fig678_latency_pdf, kernel_bench, roofline_report,
                            table2_single_edge, table3_homogeneous,
                            table4_heterogeneous)

    csv_lines = ["name,us_per_call,derived"]

    def bench(name, module):
        t0 = time.perf_counter()
        _, derived = module.run(verbose=True)
        us = (time.perf_counter() - t0) * 1e6
        d = ";".join(f"{k}={v:.3f}" if isinstance(v, float) else f"{k}={v}"
                     for k, v in derived.items())
        csv_lines.append(f"{name},{us:.0f},{d}")

    bench("table2_single_edge", table2_single_edge)
    bench("table3_homogeneous", table3_homogeneous)
    bench("table4_heterogeneous", table4_heterogeneous)
    bench("fig5_training_schemes", fig5_training_schemes)
    bench("fig678_latency_pdf", fig678_latency_pdf)
    bench("ablation_thresholds", ablation_thresholds)

    t0 = time.perf_counter()
    kernel_rows, _ = kernel_bench.run(verbose=True)
    for name, r in kernel_rows.items():
        csv_lines.append(f"kernel/{name},{r['us_per_call']},GB_s={r['GB_s']}")

    bench("roofline_report", roofline_report)

    print("\n" + "\n".join(csv_lines))


if __name__ == "__main__":
    main()
