"""Ablation (ours): the accuracy-latency-bandwidth tradeoff surface of the
confidence thresholds (paper §IV-E motivates the adaptive rule; this sweep
shows the static frontier the adaptive controller navigates)."""
from __future__ import annotations

import numpy as np

from benchmarks import common
from repro.serving.simulator import CloudEdgeSim, LinkSpec, NodeSpec


def run(verbose: bool = True):
    wl = common.shared_workload()
    import dataclasses as dc
    items = [dc.replace(it, edge_device=(it.edge_device - 1) % 3 + 1)
             for it in wl.items]
    edges = [NodeSpec(i, service_s=0.30) for i in (1, 2, 3)]
    cloud = NodeSpec(0, service_s=0.05)
    link = LinkSpec(uplink_MBps=0.5, rtt_s=0.1)

    grid = [(0.55, 0.30), (0.7, 0.2), (0.8, 0.1), (0.9, 0.05), (0.98, 0.01)]
    rows = {}
    if verbose:
        print("\n== ablation — static (alpha, beta) frontier ==")
        print(f"{'alpha':>6s} {'beta':>6s} {'F2':>8s} {'avg_lat':>9s} "
              f"{'band_MB':>9s} {'escal':>6s}")
    for a, b in grid:
        sim = CloudEdgeSim(edges, cloud, link, scheme="surveiledge_fixed",
                           seed=31, fixed_thresholds=(a, b))
        r = sim.run(items)
        rows[(a, b)] = r.summary()
        if verbose:
            print(f"{a:6.2f} {b:6.2f} {r.f_score():8.3f} {r.avg_latency:9.3f} "
                  f"{r.uploaded_bytes/1e6:9.2f} {r.escalated:6d}")
    # adaptive for reference
    sim = CloudEdgeSim(edges, cloud, link, scheme="surveiledge", seed=31)
    ra = sim.run(items)
    if verbose:
        print(f"{'adapt':>6s} {'':>6s} {ra.f_score():8.3f} "
              f"{ra.avg_latency:9.3f} {ra.uploaded_bytes/1e6:9.2f} "
              f"{ra.escalated:6d}")
    accs = [r["accuracy_F2"] for r in rows.values()]
    lats = [r["avg_latency_s"] for r in rows.values()]
    derived = {
        "static_acc_range": max(accs) - min(accs),
        "static_lat_range": max(lats) - min(lats),
        "adaptive_beats_static_latency": min(lats) / max(ra.avg_latency, 1e-9),
    }
    return rows, derived


if __name__ == "__main__":
    print(run()[1])
