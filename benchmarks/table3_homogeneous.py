"""Paper Table III: three homogeneous edges + cloud.

Runs the ``repro.system`` end-to-end harness (one ``run_query`` per scheme)
on the homogeneous multi-edge scenario over the shared CQ-scored workload.
"""
from __future__ import annotations

from benchmarks import common


def run(verbose: bool = True):
    wl = common.shared_workload()
    rows = common.run_schemes(wl, edge_service=[1.0, 1.0, 1.0], seed=12,
                              name="homogeneous_multi_edge")
    if verbose:
        common.print_table("Table III — homogeneous edges + cloud", rows)
    se, co, eo, fx = (rows[s] for s in
                      ("surveiledge", "cloud_only", "edge_only",
                       "surveiledge_fixed"))
    derived = {
        "bandwidth_reduction_vs_cloud": co["bandwidth_MB"] / max(se["bandwidth_MB"], 1e-9),
        "speedup_vs_cloud": co["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "speedup_vs_edge": eo["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "speedup_vs_fixed": fx["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
    }
    return rows, derived


if __name__ == "__main__":
    _, derived = run()
    print(derived)
