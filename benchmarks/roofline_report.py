"""Roofline table (deliverable g): all 40 (arch x shape) pairs, single-pod,
from the dry-run artifacts in experiments/dryrun + the analytic model."""
from __future__ import annotations

import json
import os

from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.launch import roofline as R

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments")


def run(verbose: bool = True, dryrun_dir: str = None):
    dryrun_dir = dryrun_dir or os.path.join(OUT_DIR, "dryrun")
    rows = []
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            rec = R.load_dryrun(dryrun_dir, arch, sname, "single")
            rl = R.analyze(cfg, shape, dryrun_record=rec)
            rows.append(rl)
    if verbose:
        hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
               f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s}")
        print("\n== Roofline (single-pod 16x16, v5e) ==")
        print(hdr)
        for r in rows:
            print(f"{r.arch:26s} {r.shape:12s} {r.compute_s:10.4f} "
                  f"{r.memory_s:10.4f} {r.collective_s:10.4f} "
                  f"{r.dominant:>10s} {r.useful_ratio:7.2f}")
    # multi-pod rows for the three hillclimbed pairs (512 chips; the pod
    # axis joins data-parallel batch sharding)
    multi_pairs = [("command-r-35b", "train_4k"),
                   ("granite-moe-1b-a400m", "prefill_32k"),
                   ("phi3.5-moe-42b-a6.6b", "decode_32k")]
    multi_rows = []
    for arch, sname in multi_pairs:
        rec = R.load_dryrun(dryrun_dir, arch, sname, "multi")
        rl = R.analyze(get_config(arch), INPUT_SHAPES[sname], chips=512,
                       mesh_name="multi", dryrun_record=rec)
        multi_rows.append(rl)
    if verbose:
        print("\n== Roofline (multi-pod 2x16x16, hillclimbed pairs) ==")
        for r in multi_rows:
            print(f"{r.arch:26s} {r.shape:12s} {r.compute_s:10.4f} "
                  f"{r.memory_s:10.4f} {r.collective_s:10.4f} "
                  f"{r.dominant:>10s} {r.useful_ratio:7.2f}")
    os.makedirs(OUT_DIR, exist_ok=True)
    with open(os.path.join(OUT_DIR, "roofline.json"), "w") as f:
        json.dump([r.__dict__ | {"dominant": r.dominant,
                                 "useful_ratio": r.useful_ratio}
                   for r in rows + multi_rows], f, indent=1)
    worst = max(rows, key=lambda r: max(r.compute_s, r.memory_s, r.collective_s))
    most_coll = max(rows, key=lambda r: r.collective_s)
    derived = {"worst_pair": f"{worst.arch}/{worst.shape}",
               "most_collective_bound": f"{most_coll.arch}/{most_coll.shape}"}
    return rows, derived


if __name__ == "__main__":
    print(run()[1])
