"""Kernel microbenchmarks: Pallas (interpret on CPU) vs pure-jnp oracle.

On this CPU host the interesting number is the *oracle* timing (the Pallas
path interprets the kernel body in Python and is not representative of TPU
throughput); both are reported, with bytes-based derived throughput.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.system import PixelFrontend, pixel_city, synthetic_confidence_stream


def _time(fn, *args, n=10, **kw):
    fn(*args, **kw)
    r = fn(*args, **kw)
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, r)
    t0 = time.perf_counter()
    for _ in range(n):
        r = fn(*args, **kw)
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, r)
    return (time.perf_counter() - t0) / n * 1e6     # us


def run(verbose: bool = True):
    B, H, W = 4, 96, 128
    f = [jax.random.randint(jax.random.PRNGKey(i), (B, H, W, 3), 0, 256)
         for i in range(3)]
    mask = ops.framediff(*f, threshold=40, use_pallas=False)
    conf = jax.random.uniform(jax.random.PRNGKey(9), (4096,))
    rows = []
    bytes_fd = 3 * B * H * W * 3 * 4
    rows.append(("framediff_ref", _time(ops.framediff, *f, threshold=40,
                                        use_pallas=False), bytes_fd))
    rows.append(("framediff_pallas_interp", _time(ops.framediff, *f,
                                                  threshold=40), bytes_fd))
    bytes_mo = B * H * W * 4 * 2
    rows.append(("dilate3x3_ref", _time(ops.dilate3x3, mask,
                                        use_pallas=False), bytes_mo))
    rows.append(("dilate3x3_pallas_interp", _time(ops.dilate3x3, mask), bytes_mo))
    rows.append(("erode3x3_ref", _time(ops.erode3x3, mask,
                                       use_pallas=False), bytes_mo))
    bytes_tr = 4096 * 4 * 3
    rows.append(("triage_ref", _time(ops.triage, conf, alpha=0.8, beta=0.1,
                                     capacity=512, use_pallas=False), bytes_tr))
    rows.append(("triage_pallas_interp", _time(ops.triage, conf, alpha=0.8,
                                               beta=0.1, capacity=512), bytes_tr))
    # fleet triage: the whole city_scale tick (64 edges x 512-wide bucket)
    # in ONE launch — vs 64 per-edge launches per tick before
    E, N = 64, 512
    fleet_conf = jax.random.uniform(jax.random.PRNGKey(10), (E, N))
    fleet_th = jnp.stack(
        [jnp.full((E,), 0.8), jnp.full((E,), 0.1)], axis=1)
    bytes_fleet = E * N * 4 * 3 + E * 2 * 4
    rows.append(("triage_fleet_ref",
                 _time(ops.triage_fleet, fleet_conf, fleet_th, capacity=64,
                       use_pallas=False), bytes_fleet))
    rows.append(("triage_fleet_pallas_interp",
                 _time(ops.triage_fleet, fleet_conf, fleet_th, capacity=64),
                 bytes_fleet))
    # multi-query fleet: 3 live CQs x 64 edges x 512-wide buckets, the
    # whole (Q, E, N) tick in ONE Q*E-row-folded launch — vs Q per-query
    # fleet launches (the loop a naive multi-query port would run)
    Qn = 3
    mq_conf = jax.random.uniform(jax.random.PRNGKey(13), (Qn, E, N))
    mq_th = jnp.tile(fleet_th[None], (Qn, 1, 1))
    bytes_mq = Qn * E * N * 4 * 3 + Qn * E * 2 * 4
    rows.append(("triage_fleet_qen_ref",
                 _time(ops.triage_fleet, mq_conf, mq_th, capacity=64,
                       use_pallas=False), bytes_mq))
    rows.append(("triage_fleet_qen_pallas_interp",
                 _time(ops.triage_fleet, mq_conf, mq_th, capacity=64),
                 bytes_mq))
    # fleet recalibration: one fused (E, N) Platt-fit launch per update
    # event — the feedback loop's whole fleet in ONE call (vs E per-edge
    # fits).  The NumPy ref is a per-row float64 Newton loop, so here the
    # fused jnp/Pallas path is also the *algorithmically* interesting one.
    Ec, Nc = 64, 256
    cal_s = jax.random.uniform(jax.random.PRNGKey(11), (Ec, Nc))
    cal_y = (jax.random.uniform(jax.random.PRNGKey(12), (Ec, Nc))
             < cal_s).astype(jnp.float32)
    bytes_cal = Ec * Nc * 4 * 2 + Ec * 2 * 4
    rows.append(("calibrate_fleet_ref",
                 _time(ops.calibrate_fleet, cal_s, cal_y,
                       use_pallas=False, n=3), bytes_cal))
    rows.append(("calibrate_fleet_pallas_interp",
                 _time(ops.calibrate_fleet, cal_s, cal_y, n=3), bytes_cal))
    # flash attention (small shape; interpret mode on CPU)
    qk = jax.random.normal(jax.random.PRNGKey(3), (1, 4, 128, 64))
    kk = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 64))
    vk = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 128, 64))
    bytes_fl = (qk.size + kk.size + vk.size) * 4
    rows.append(("flash_attn_ref", _time(ops.flash_attention, qk, kk, vk,
                                         use_pallas=False, n=5), bytes_fl))
    rows.append(("flash_attn_pallas_interp",
                 _time(ops.flash_attention, qk, kk, vk, n=5), bytes_fl))
    out = {}
    for name, us, nbytes in rows:
        gbps = nbytes / (us * 1e-6) / 1e9
        out[name] = {"us_per_call": round(us, 1), "GB_s": round(gbps, 3)}
        if verbose:
            print(f"{name:28s} {us:10.1f} us  {gbps:8.3f} GB/s")
    # the fleet kernel's headline is launch amortization: ONE (E, N) launch
    # replaces E per-edge launches every scheduler tick.  Time the actual
    # per-edge loop as the baseline.
    def _per_edge_tick(conf, th, use_pallas=True):
        return [ops.triage_batched(conf[e], alpha=float(th[e, 0]),
                                   beta=float(th[e, 1]), capacity=64,
                                   use_pallas=use_pallas)
                for e in range(conf.shape[0])]

    fleet_conf_np, fleet_th_np = (jax.device_get(fleet_conf),
                                  jax.device_get(fleet_th))
    us_loop = _time(_per_edge_tick, fleet_conf_np, fleet_th_np,
                    n=3, use_pallas=False)
    us_fleet = _time(ops.triage_fleet, fleet_conf, fleet_th, capacity=64,
                     n=3, use_pallas=False)
    # ... and the query axis: ONE fused (Q, E, N) launch vs Q (E, N)
    # fleet launches per tick (multi-query runtime's hot-path claim)
    def _per_query_tick(conf, th, use_pallas=True):
        return [ops.triage_fleet(conf[q], th[q], capacity=64,
                                 use_pallas=use_pallas)
                for q in range(conf.shape[0])]

    us_qloop = _time(_per_query_tick, mq_conf, mq_th, n=3, use_pallas=False)
    us_qfused = _time(ops.triage_fleet, mq_conf, mq_th, capacity=64,
                      n=3, use_pallas=False)
    derived = {
        "fleet_launches_per_tick": 1,
        "per_edge_launches_per_tick": E,
        "fleet_launch_reduction": E,
        "fleet_tick_speedup_vs_per_edge_loop": round(us_loop / us_fleet, 2),
        "multi_query_launches_per_tick": 1,
        "per_query_launches_per_tick": Qn,
        "multi_query_launch_reduction": Qn,
        "multi_query_tick_speedup_vs_per_query_loop": round(
            us_qloop / us_qfused, 2),
    }
    if verbose:
        print(f"fleet tick (E={E}, N={N}): 1 launch {us_fleet:.1f} us vs "
              f"{E}-launch loop {us_loop:.1f} us -> "
              f"{derived['fleet_tick_speedup_vs_per_edge_loop']}x, "
              f"{E}x fewer launches")
        print(f"multi-query tick (Q={Qn}, E={E}, N={N}): 1 fused launch "
              f"{us_qfused:.1f} us vs {Qn}-launch per-query loop "
              f"{us_qloop:.1f} us -> "
              f"{derived['multi_query_tick_speedup_vs_per_query_loop']}x, "
              f"{Qn}x fewer launches")
    # frontend throughput, fig5-style scheme comparison: the full pixel path
    # (render -> framediff -> crops -> CQ scores) vs the model-free
    # confidence stream on the same small scenario, in detections/s.  The
    # frontend cache is disabled so every timed call does the real work.
    sc = pixel_city(num_cameras=4, duration_s=3.0)
    pix = PixelFrontend(seed=0, cache=False)
    n_pix = len(pix.stream(sc))            # warm the jit caches
    # every cache-disabled stream() call does the full render/score work, so
    # time exactly ONE post-warmup call instead of _time's warmup pair
    t0 = time.perf_counter()
    pix.stream(sc)
    us_pix = (time.perf_counter() - t0) * 1e6
    n_conf = len(synthetic_confidence_stream(sc))
    us_conf = _time(synthetic_confidence_stream, sc, n=3)
    derived.update({
        "pixel_frontend_items_per_s": round(n_pix / (us_pix * 1e-6), 1),
        "confidence_frontend_items_per_s": round(
            n_conf / (us_conf * 1e-6), 1),
        "pixel_vs_confidence_throughput_ratio": round(
            (n_pix / us_pix) / (n_conf / us_conf), 6),
    })
    if verbose:
        print(f"frontend stream ({sc.num_cameras} cams, {sc.duration_s:.0f}s"
              f"): pixel {n_pix} items in {us_pix/1e6:.2f} s "
              f"({derived['pixel_frontend_items_per_s']}/s) vs confidence "
              f"{n_conf} items "
              f"({derived['confidence_frontend_items_per_s']}/s)")
    return out, derived


if __name__ == "__main__":
    run()
