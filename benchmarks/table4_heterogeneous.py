"""Paper Table IV: heterogeneous edges (2/4/8-core analogues) + cloud.

Runs the ``repro.system`` end-to-end harness (one ``run_query`` per scheme)
on the heterogeneous multi-edge scenario over the shared CQ-scored workload.
"""
from __future__ import annotations

from benchmarks import common


def run(verbose: bool = True):
    wl = common.shared_workload()
    # 2, 4, 8 logical cores -> 1.0 / 0.5 / 0.25 x per-item service time
    rows = common.run_schemes(wl, edge_service=[1.0, 0.5, 0.25], seed=13,
                              name="heterogeneous_multi_edge")
    if verbose:
        common.print_table("Table IV — heterogeneous edges + cloud", rows)
    se, co, eo, fx = (rows[s] for s in
                      ("surveiledge", "cloud_only", "edge_only",
                       "surveiledge_fixed"))
    derived = {
        "speedup_vs_cloud": co["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "speedup_vs_edge": eo["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "speedup_vs_fixed": fx["avg_latency_s"] / max(se["avg_latency_s"], 1e-9),
        "accuracy_gain_vs_edge": se["accuracy_F2"] - eo["accuracy_F2"],
        "accuracy_gain_vs_fixed": se["accuracy_F2"] - fx["accuracy_F2"],
    }
    return rows, derived


if __name__ == "__main__":
    _, derived = run()
    print(derived)
