"""Shared benchmark scaffolding: calibrated workload + scheme runner.

All table/figure scripts drive the ``repro.system`` end-to-end harness: one
``run_query(scenario)`` per scheme over the *same* CQ-model-scored detection
stream (built once by ``repro.serving.workload`` — offline clustering,
online fine-tuning, then model-scored arrivals).

Calibration: the 1.0x edge's per-item CQ service time is set so every edge
runs at EDGE_UTILIZATION (0.9) of its share of the stream's average arrival
rate — edges keep up off-peak and saturate at the cameras' periodic busy
peaks, which is exactly the regime the paper's allocator + adaptive
thresholds target.  The WAN uplink is a shared FIFO sized between average
and peak demand, so cloud-only saturates it (the Table II effect).
Absolute seconds differ from the paper's prototype; every claim checked
here is about ratios/orderings, which is what the paper's contribution is
about.
"""
from __future__ import annotations

import functools
from typing import Dict, Sequence

from repro.serving.workload import Workload, build_workload
from repro.system import SCHEMES, Scenario, run_query

EDGE_UTILIZATION = 0.9       # per-edge load factor the calibration targets


@functools.lru_cache(maxsize=2)
def shared_workload(duration_s: float = 240.0, num_cameras: int = 8,
                    num_edges: int = 3, seed: int = 0) -> Workload:
    return build_workload(num_cameras=num_cameras, num_edges=num_edges,
                          duration_s=duration_s, finetune_steps=80, seed=seed)


def calibrated_scenario(wl: Workload, name: str,
                        edge_speeds: Sequence[float], *,
                        cloud_speedup: float = 6.0,
                        uplink_MBps: float = 0.5,
                        seed: int = 1, **kw) -> Scenario:
    """Scenario over the shared workload's stream, service times anchored so
    a 1.0x edge runs at EDGE_UTILIZATION of *its own share* of the average
    arrival rate.  Per-edge load is thus held constant across the single-
    and multi-edge settings — as in the paper, where every edge serves its
    own cameras and the multi-edge win comes from busy-time diversity (the
    allocator shifting transient hotspots), not from spare capacity."""
    duration = max(it.t_arrival for it in wl.items)
    rate = len(wl.items) / max(duration, 1e-9)            # items/s, all cams
    return Scenario(name=name, edge_speeds=tuple(edge_speeds),
                    edge_service_s=EDGE_UTILIZATION * len(edge_speeds) / rate,
                    cloud_speedup=cloud_speedup, uplink_MBps=uplink_MBps,
                    duration_s=duration, seed=seed, **kw)


def run_schemes(wl: Workload, edge_service: Sequence[float], *,
                cloud_speedup: float = 6.0, uplink_MBps: float = 0.5,
                seed: int = 1, name: str = "benchmark",
                **scenario_kw) -> Dict[str, Dict[str, float]]:
    """One ``run_query`` per scheme through the system harness."""
    sc = calibrated_scenario(wl, name, edge_service,
                             cloud_speedup=cloud_speedup,
                             uplink_MBps=uplink_MBps, seed=seed,
                             **scenario_kw)
    out = {}
    for scheme in SCHEMES:
        res = run_query(sc.with_scheme(scheme), items=wl.items)
        out[scheme] = res.summary()
        out[scheme]["_result"] = res
    return out


def print_table(name: str, rows: Dict[str, Dict[str, float]]) -> None:
    cols = ["accuracy_F2", "avg_latency_s", "p99_latency_s", "latency_var",
            "bandwidth_MB", "escalated", "launches_per_tick"]
    print(f"\n== {name} ==")
    print(f"{'scheme':20s}" + "".join(f"{c:>18s}" for c in cols))
    for scheme, r in rows.items():
        print(f"{scheme:20s}" + "".join(f"{r[c]:>18}" for c in cols))
