"""Shared benchmark scaffolding: calibrated workload + scheme runner.

Service times are *calibrated from measured jitted inference on this host*
(edge model batch-1 latency), with the paper's relative speed ratios:
the cloud GPU classifies ~6x faster per item than an edge CPU; heterogeneous
edges are 2/4/8-core analogues (1.0 / 0.5 / 0.25 x).  The WAN uplink is the
shared FIFO resource whose saturation reproduces cloud-only's latency
(Table II).  Absolute seconds differ from the paper's prototype; every
claim checked in EXPERIMENTS.md is about ratios/orderings, which is what
the paper's contribution is about.
"""
from __future__ import annotations

import functools
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cascade import confidence_from_logits
from repro.models import transformer as T
from repro.serving.simulator import CloudEdgeSim, LinkSpec, NodeSpec
from repro.serving.workload import Workload, build_workload

SCHEMES = ("surveiledge", "surveiledge_fixed", "edge_only", "cloud_only")


@functools.lru_cache(maxsize=2)
def shared_workload(duration_s: float = 240.0, num_cameras: int = 8,
                    num_edges: int = 3, seed: int = 0) -> Workload:
    return build_workload(num_cameras=num_cameras, num_edges=num_edges,
                          duration_s=duration_s, finetune_steps=80, seed=seed)


def measure_edge_service_s(wl: Workload) -> float:
    """Measured batch-1 jitted inference latency of the CQ edge model."""
    cfg = wl.edge_cfg

    @jax.jit
    def conf_fn(params, tokens):
        h, _ = T.forward(cfg, params, tokens, remat=False)
        return confidence_from_logits(T.classify(cfg, params, h), 1)

    tokens = jnp.zeros((1, 16), jnp.int32)
    conf_fn(wl.edge_params, tokens).block_until_ready()      # compile
    t0 = time.perf_counter()
    n = 20
    for _ in range(n):
        conf_fn(wl.edge_params, tokens).block_until_ready()
    return (time.perf_counter() - t0) / n


def run_schemes(wl: Workload, edge_service: List[float], *,
                cloud_speedup: float = 6.0, uplink_MBps: float = 0.5,
                seed: int = 1) -> Dict[str, Dict[str, float]]:
    base = max(measure_edge_service_s(wl), 1e-3)
    scale = 0.30 / base          # anchor: paper-like ~0.3 s/item edge CPU
    edges = [NodeSpec(i + 1, service_s=base * scale * m)
             for i, m in enumerate(edge_service)]
    # remap camera->edge homes onto however many edges this setting has
    import dataclasses as _dc
    items = [_dc.replace(it, edge_device=(it.edge_device - 1) % len(edges) + 1)
             for it in wl.items]
    cloud = NodeSpec(0, service_s=base * scale / cloud_speedup)
    link = LinkSpec(uplink_MBps=uplink_MBps, rtt_s=0.1)
    out = {}
    for scheme in SCHEMES:
        sim = CloudEdgeSim(edges, cloud, link, scheme=scheme, seed=seed)
        res = sim.run(items)
        out[scheme] = res.summary()
        out[scheme]["_result"] = res
    return out


def print_table(name: str, rows: Dict[str, Dict[str, float]]) -> None:
    cols = ["accuracy_F2", "avg_latency_s", "p99_latency_s", "latency_var",
            "bandwidth_MB", "escalated"]
    print(f"\n== {name} ==")
    print(f"{'scheme':20s}" + "".join(f"{c:>16s}" for c in cols))
    for scheme, r in rows.items():
        print(f"{scheme:20s}" + "".join(f"{r[c]:>16}" for c in cols))
