"""Logical-axis -> mesh-axis sharding rules.

Parameters carry *logical* axis names (see ``repro.models.meta``).  This
module maps them onto the production mesh:

  mesh axes: ("pod", "data", "model")  (multi-pod)  or  ("data", "model")

Rules (MaxText-style):
  * tensor-parallel axes (heads / kv_heads / mlp / experts / ssm_inner /
    ssm_heads / vocab) -> "model"
  * FSDP: the "embed" logical axis -> "data" in *train* mode (params, grads
    and Adam moments all shard); replicated in serve mode.
  * every mapping is guarded by divisibility (a 25-head attention cannot
    shard over 16 chips -> replicate) and by one-mesh-axis-per-leaf.

Activation constraints use the same mesh: batch on ("pod","data"), heads /
mlp-hidden / vocab on "model", all divisibility-guarded.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import meta as M
from repro.models.config import ModelConfig

TP_AXES = ("vocab", "heads", "kv_heads", "mlp", "experts",
           "ssm_inner", "ssm_heads")


def _axis_size(mesh: Mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.shape else 1


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Batch-sharding axes: ('pod','data') on the multi-pod mesh."""
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def data_size(mesh: Mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= _axis_size(mesh, a)
    return n


def logical_to_mesh(cfg: ModelConfig, mesh: Mesh, mode: str,
                    force_1d_serve: bool = False) -> Dict[str, Any]:
    """Logical axis name -> mesh axis (or tuple) candidate."""
    rules: Dict[str, Any] = {a: "model" for a in TP_AXES}
    if cfg.is_moe:
        # experts take the model axis; per-expert mlp dim stays unsharded
        rules["mlp"] = None
    # FSDP ('embed' on the data axis): always in train; in serve only for
    # models whose 1-D TP shard would not fit per-chip HBM (2-D weight
    # sharding, vLLM-on-TPU style — costs per-layer weight all-gathers).
    # force_1d_serve keeps decode weights resident (EXPERIMENTS.md §Perf:
    # for one-token steps the 2-D gathers cost ~100 ms of ICI per step,
    # dwarfing the HBM win — prefer 1-D whenever the shard fits).
    two_d_serve = (cfg.param_count() * 2 / _axis_size(mesh, "model") > 2e9
                   and not force_1d_serve)
    rules["embed"] = "data" if (mode == "train" or two_d_serve) else None
    return rules


def spec_for_meta(cfg: ModelConfig, pm: M.ParamMeta, mesh: Mesh,
                  mode: str, force_1d_serve: bool = False) -> P:
    rules = logical_to_mesh(cfg, mesh, mode, force_1d_serve)
    used = set()
    out = []
    for dim, ax in zip(pm.shape, pm.axes):
        cand = rules.get(ax) if ax else None
        if cand is None or cand in used:
            out.append(None)
            continue
        if dim % _axis_size(mesh, cand) != 0:
            out.append(None)
            continue
        used.add(cand)
        out.append(cand)
    return P(*out)


def param_specs(cfg: ModelConfig, mesh: Mesh, mode: str,
                force_1d_serve: bool = False) -> Any:
    """PartitionSpec tree mirroring the param tree."""
    return jax.tree.map(
        lambda pm: spec_for_meta(cfg, pm, mesh, mode, force_1d_serve),
        M.model_meta(cfg), is_leaf=lambda x: isinstance(x, M.ParamMeta))


def param_shardings(cfg: ModelConfig, mesh: Mesh, mode: str,
                    force_1d_serve: bool = False) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, mesh, mode, force_1d_serve))


def _batch_spec(mesh: Mesh, batch: int) -> Any:
    """Largest prefix of ('pod','data') that divides the batch."""
    axes = []
    n = 1
    for a in data_axes(mesh):
        if batch % (n * _axis_size(mesh, a)) == 0:
            axes.append(a)
            n *= _axis_size(mesh, a)
    if not axes:
        return None
    return tuple(axes) if len(axes) > 1 else axes[0]


def batch_specs(cfg: ModelConfig, mesh: Mesh, batch: int,
                tree: Any) -> Any:
    """Shardings for an input-batch tree: dim0 = batch, rest replicated."""
    b = _batch_spec(mesh, batch)

    def spec(leaf):
        nd = len(leaf.shape)
        return NamedSharding(mesh, P(*((b,) + (None,) * (nd - 1))) if nd else P())

    return jax.tree.map(spec, tree)


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, cache: Any) -> Any:
    """Shardings for a decode cache (semantic, by leaf name).

    k/v/cross_k/cross_v: (L,B,S,KV,hd) — kv-heads on 'model' when divisible,
    else context-parallel (seq dim on 'model'; needed to fit 32k x 128 GQA
    caches where kv < tp).  ssd state (L,B,nh,hd,N): ssm heads on 'model'.
    conv caches (L,B,W-1,C): channels on 'model'.  Batch always on data axes.
    """
    b = _batch_spec(mesh, batch)
    tp = _axis_size(mesh, "model")

    def div(n: int) -> bool:
        return n % tp == 0 and n > 1

    def spec(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        shp = leaf.shape
        if name in ("pos", "kpos"):     # per-sequence bookkeeping: (B,)/(B,W)
            return NamedSharding(mesh, P(b, *((None,) * (len(shp) - 1))))
        if len(shp) <= 1:
            return NamedSharding(mesh, P(*((None,) * len(shp))))
        out = [None] * len(shp)
        out[1] = b                      # batch dim (after layer-stack dim)
        if name in ("k", "v", "cross_k", "cross_v", "k_scale", "v_scale"):
            # (L,B,S,KV,hd) values / (L,B,S,KV) scales: same layout rule
            if div(shp[3]):             # kv heads
                out[3] = "model"
            elif div(shp[2]):           # context-parallel fallback
                out[2] = "model"
        elif name == "ssd":
            if div(shp[2]):             # ssm heads
                out[2] = "model"
            elif div(shp[3]):
                out[3] = "model"
        elif len(shp) >= 4 and div(shp[3]):   # conv caches: channels
            out[3] = "model"
        return NamedSharding(mesh, P(*out))

    return jax.tree_util.tree_map_with_path(spec, cache)


# --- activation constraints -------------------------------------------------

class ActCtx:
    """Callable passed as ``ctx`` through the model: ctx(x, name) constrains x."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh, *,
                 seq_shard_resid: bool = True,
                 shard_moe_flat: bool = True):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = _axis_size(mesh, "model")
        self.seq_shard_resid = seq_shard_resid
        self.shard_moe_flat = shard_moe_flat

    def _maybe(self, dim: int, axis) -> Optional[str]:
        if axis is None:
            return None
        n = 1
        for a in (axis if isinstance(axis, tuple) else (axis,)):
            n *= _axis_size(self.mesh, a)
        return axis if dim % n == 0 and n > 1 else None

    def __call__(self, x: jax.Array, name: str) -> jax.Array:
        b = self._maybe(x.shape[0], _batch_spec(self.mesh, x.shape[0]))
        if name == "resid" and x.ndim == 3 and x.shape[1] > 1 \
                and self.seq_shard_resid:
            # sequence parallelism: residuals sharded on 'model' along seq so
            # the saved scan carries fit HBM in train mode
            spec = P(b, self._maybe(x.shape[1], "model"), None)
        elif name == "resid":                     # (B,S,D)
            spec = P(b, *([None] * (x.ndim - 1)))
        elif name == "act_q" and x.ndim == 4:     # (B,S,H,hd)
            spec = P(b, None, self._maybe(x.shape[2], "model"), None)
        elif name == "moe_buf" and x.ndim == 4:   # (B,E,cap,D)
            spec = P(b, self._maybe(x.shape[1], "model"), None, None)
        elif name == "moe_flat" and x.ndim == 3:  # (B,S*K,D) dispatch entries
            tk = self._maybe(x.shape[1], "model") if self.shard_moe_flat else None
            spec = P(b, tk, None)
        elif name == "logits":                    # (B,S,V) or (B,V)
            v = self._maybe(x.shape[-1], "model")
            if x.ndim == 3 and v is None:
                # vocab not divisible by tp (odd vocabs: granite, whisper,
                # internvl2, mamba2) -> shard the seq dim instead; the xent
                # reduction stays local per position.
                spec = P(b, self._maybe(x.shape[1], "model"), None)
            else:
                spec = P(b, *([None] * (x.ndim - 2)), v)
        else:
            spec = P(b, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))


# --- fleet-axis sharding (scan-superstep path) -------------------------------
#
# The surveillance fleet's folded (query, edge) row axis is embarrassingly
# parallel: the fused triage kernel compacts escalations per ROW, and the
# Eqs. 8-9 scan recurrence is elementwise over rows — no collectives, so a
# shard_map over a 1-D ("fleet",) mesh (launch.mesh.make_fleet_mesh) runs
# the kernel shard-local and bit-exactly reproduces the single-device
# result (asserted by tests/test_superstep.py under
# XLA_FLAGS=--xla_force_host_platform_device_count=8).

def fleet_axis_size(mesh: Mesh) -> int:
    return _axis_size(mesh, "fleet")


def can_shard_fleet(mesh: Mesh, padded_rows: int) -> bool:
    """Divisibility guard: the padded row bucket must split evenly across
    the fleet axis (power-of-two buckets make this true for any
    power-of-two device count <= the bucket)."""
    n = fleet_axis_size(mesh)
    return n > 1 and padded_rows % n == 0


def fleet_specs() -> Dict[str, P]:
    """PartitionSpecs of the superstep slab, keyed by operand role.

    conf (S, R, N) and the triage outputs shard on the row axis R; the
    (R, 2) threshold carry, the (S, R) update mask and the (R,) per-row
    drain signal shard the same way; scalar gains replicate."""
    return {
        "conf": P(None, "fleet", None),
        "thresholds": P("fleet", None),
        "mask": P(None, "fleet"),
        "drain": P("fleet"),
        "gains": P(None),
        "ths_out": P(None, "fleet", None),
        "routes": P(None, "fleet", None),
        "slots": P(None, "fleet", None),
    }
