"""int8 weight quantization for serving (beyond-paper; EXPERIMENTS.md §Perf).

Symmetric int8 with per-output-channel scales.  Quantization is *meta-aware*
(``repro.models.meta``): only weight leaves (init == normal/scaled, ndim>=2)
are quantized; norm scales, biases and SSM time constants stay in fp.
Layer-stacked leaves keep their leading ``stack`` dim in the scale tensor —
shape (L, out_dim) — so the quantized tree remains a valid ``lax.scan`` xs.

Dequantization happens *inside* the layer scan body (see
``transformer.maybe_dequant``): only one layer's weights are ever resident
in bf16, which is what lets a 42B MoE serve with 1-D tensor-parallel weights
on 16 GB chips.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import meta as M
from repro.models.config import ModelConfig


def _is_quant(leaf: Any) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


def quantize_leaf(x: jax.Array, stacked: bool):
    """x: (..., out).  Scale over every dim except the last (and, for
    stacked leaves, except the leading layer dim)."""
    axes = tuple(range(1 if stacked else 0, x.ndim - 1))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes) \
        if axes else jnp.abs(x.astype(jnp.float32))
    scale = jnp.maximum(amax, 1e-8) / 127.0      # (out,) or (L, out)
    bshape = ((x.shape[0],) if stacked else ()) + \
        (1,) * len(axes) + (x.shape[-1],)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale.reshape(bshape)),
                 -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequantize_leaf(leaf, dtype=jnp.bfloat16) -> jax.Array:
    q, s = leaf["q"], leaf["s"]
    if s.ndim == 2 and q.ndim >= 3 and s.shape[0] == q.shape[0]:
        s = s.reshape((q.shape[0],) + (1,) * (q.ndim - 2) + (q.shape[-1],))
    return (q.astype(jnp.float32) * s).astype(dtype)


def _quantizable(pm: M.ParamMeta) -> bool:
    return pm.init in ("normal", "scaled") and len(pm.shape) >= 2


def quantize_tree(params: Any, cfg: ModelConfig) -> Any:
    """Quantize weight leaves per the model's param metadata."""
    metas = M.model_meta(cfg)

    def f(pm, leaf):
        if _quantizable(pm):
            return quantize_leaf(leaf, stacked=pm.axes[0] == M.STACK)
        return leaf

    return jax.tree.map(f, metas, params,
                        is_leaf=lambda x: isinstance(x, M.ParamMeta))


def dequant_tree(params: Any, dtype=jnp.bfloat16) -> Any:
    """Inverse of quantize_tree (structure-preserving; no-op on fp leaves)."""
    return jax.tree.map(
        lambda l: dequantize_leaf(l, dtype) if _is_quant(l) else l,
        params, is_leaf=_is_quant)


def abstract_quantized(params_abs: Any, cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree of the quantized layout (for the dry-run)."""
    return jax.eval_shape(lambda p: quantize_tree(p, cfg), params_abs)


def quantized_shardings(pshard: Any, params_abs: Any, cfg: ModelConfig,
                        mesh) -> Any:
    """Sharding tree matching ``abstract_quantized``: int8 values keep the
    original leaf's sharding; the small scale tensors are replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    metas = M.model_meta(cfg)
    repl = NamedSharding(mesh, P())

    def f(pm, sh):
        if _quantizable(pm):
            return {"q": sh, "s": repl}
        return sh

    return jax.tree.map(f, metas, pshard,
                        is_leaf=lambda x: isinstance(x, M.ParamMeta))
