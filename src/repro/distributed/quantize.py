"""int8 weight quantization for serving (beyond-paper; EXPERIMENTS.md §Perf).

Symmetric int8 with per-output-channel scales.  Quantization is *meta-aware*
(``repro.models.meta``): only weight leaves (init == normal/scaled, ndim>=2)
are quantized; norm scales, biases and SSM time constants stay in fp.
Layer-stacked leaves keep their leading ``stack`` dim in the scale tensor —
shape (L, out_dim) — so the quantized tree remains a valid ``lax.scan`` xs.

Dequantization happens *inside* the layer scan body (see
``transformer.maybe_dequant``): only one layer's weights are ever resident
in bf16, which is what lets a 42B MoE serve with 1-D tensor-parallel weights
on 16 GB chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import meta as M
from repro.models.config import ModelConfig


def _is_quant(leaf: Any) -> bool:
    return isinstance(leaf, dict) and set(leaf) == {"q", "s"}


def quantize_leaf(x: jax.Array, stacked: bool):
    """x: (..., out).  Scale over every dim except the last (and, for
    stacked leaves, except the leading layer dim)."""
    axes = tuple(range(1 if stacked else 0, x.ndim - 1))
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes) \
        if axes else jnp.abs(x.astype(jnp.float32))
    scale = jnp.maximum(amax, 1e-8) / 127.0      # (out,) or (L, out)
    bshape = ((x.shape[0],) if stacked else ()) + \
        (1,) * len(axes) + (x.shape[-1],)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale.reshape(bshape)),
                 -127, 127).astype(jnp.int8)
    return {"q": q, "s": scale}


def dequantize_leaf(leaf, dtype=jnp.bfloat16) -> jax.Array:
    q, s = leaf["q"], leaf["s"]
    if s.ndim == 2 and q.ndim >= 3 and s.shape[0] == q.shape[0]:
        s = s.reshape((q.shape[0],) + (1,) * (q.ndim - 2) + (q.shape[-1],))
    return (q.astype(jnp.float32) * s).astype(dtype)


def _quantizable(pm: M.ParamMeta) -> bool:
    return pm.init in ("normal", "scaled") and len(pm.shape) >= 2


def quantize_tree(params: Any, cfg: ModelConfig) -> Any:
    """Quantize weight leaves per the model's param metadata."""
    metas = M.model_meta(cfg)

    def f(pm, leaf):
        if _quantizable(pm):
            return quantize_leaf(leaf, stacked=pm.axes[0] == M.STACK)
        return leaf

    return jax.tree.map(f, metas, params,
                        is_leaf=lambda x: isinstance(x, M.ParamMeta))


def dequant_tree(params: Any, dtype=jnp.bfloat16) -> Any:
    """Inverse of quantize_tree (structure-preserving; no-op on fp leaves)."""
    return jax.tree.map(
        lambda l: dequantize_leaf(l, dtype) if _is_quant(l) else l,
        params, is_leaf=_is_quant)


def abstract_quantized(params_abs: Any, cfg: ModelConfig) -> Any:
    """ShapeDtypeStruct tree of the quantized layout (for the dry-run)."""
    return jax.eval_shape(lambda p: quantize_tree(p, cfg), params_abs)


def quantized_shardings(pshard: Any, params_abs: Any, cfg: ModelConfig,
                        mesh) -> Any:
    """Sharding tree matching ``abstract_quantized``: int8 values keep the
    original leaf's sharding; the small scale tensors are replicated."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    metas = M.model_meta(cfg)
    repl = NamedSharding(mesh, P())

    def f(pm, sh):
        if _quantizable(pm):
            return {"q": sh, "s": repl}
        return sh

    return jax.tree.map(f, metas, pshard,
                        is_leaf=lambda x: isinstance(x, M.ParamMeta))


# --- WAN wire format (cloud -> edge model shipments) --------------------------
#
# The serving-side quantization above keeps a whole model resident in int8;
# this section is the *wire* analogue for the query pipeline's WAN downlink
# (``system/transport.py``): per-query CQ weights and recalibrated Platt
# heads ship int8-quantized instead of full-width fp32, which is where the
# paper's "up to 7x less bandwidth than cloud-only" headline has its last
# untapped factor.  The wire format is affine (scale + zero-point per
# channel), not the symmetric layout above: a Platt head's (a, b) ranges
# are nowhere near symmetric around zero, and wasting half the int8 range
# on a one-sided payload doubles the round-trip error for free.
#
# Byte accounting is explicit and exact so ``Transport`` can charge the
# *real* shipped size: 1 byte per value, 8 bytes (fp32 scale + fp32 zero)
# per ``WIRE_CHANNEL``-value channel, plus a fixed framing header.

#: framing per shipped tensor: dtype tag, ndim/shape, channel count
WIRE_HEADER_NBYTES = 16
#: values per quantization channel used for byte accounting of artifacts
#: the simulator never materializes (a CQ head shipped as ``cq_nbytes``)
WIRE_CHANNEL = 256


@dataclasses.dataclass(frozen=True)
class WireTensor:
    """One int8-quantized payload as it crosses the WAN.

    ``q`` keeps the original shape; ``scale``/``zero`` are per-channel
    (the leading dim for >=2-D payloads, one channel for vectors).
    Dequantization is ``q * scale + zero``; the round-trip error is
    bounded by ``scale / 2`` per element (no clipping error: the affine
    grid is fitted to the channel's exact [min, max])."""
    q: np.ndarray        # int8, original payload shape
    scale: np.ndarray    # (channels,) float32
    zero: np.ndarray     # (channels,) float32

    @property
    def nbytes(self) -> int:
        """Exact on-the-wire size: values + per-channel (scale, zero) +
        framing header."""
        return WIRE_HEADER_NBYTES + self.q.size + 8 * self.scale.size


def encode_wire(x: np.ndarray) -> WireTensor:
    """Affine int8 quantization of a float payload for WAN shipping.

    Channels are rows of the leading dim (>=2-D) or the whole vector
    (1-D).  ``scale = (max - min) / 254`` and ``zero = (max + min) / 2``
    put the channel's range exactly on the [-127, 127] grid, so nothing
    clips and a constant channel round-trips bit-exactly."""
    x = np.asarray(x, np.float32)
    rows = x.reshape(x.shape[0] if x.ndim >= 2 else 1, -1)
    lo = rows.min(axis=1)
    hi = rows.max(axis=1)
    zero = (hi + lo) / 2.0
    scale = np.maximum((hi - lo) / 254.0, 1e-12)
    q = np.clip(np.round((rows - zero[:, None]) / scale[:, None]),
                -127, 127).astype(np.int8)
    return WireTensor(q=q.reshape(x.shape), scale=scale.astype(np.float32),
                      zero=zero.astype(np.float32))


def decode_wire(p: WireTensor) -> np.ndarray:
    """Inverse of ``encode_wire`` (lossy: within scale/2 per element)."""
    rows = p.q.reshape(p.scale.size, -1).astype(np.float32)
    out = rows * p.scale[:, None] + p.zero[:, None]
    return out.reshape(p.q.shape).astype(np.float32)


def quantized_wire_nbytes(fp_nbytes: int) -> int:
    """Downlink byte cost of shipping an fp32 artifact of ``fp_nbytes``
    int8-quantized: one byte per value plus the per-``WIRE_CHANNEL``
    (scale, zero) overhead plus framing — the *real* charged size, so the
    bandwidth reduction a report shows is ~3.9x, never a free 4x."""
    if fp_nbytes < 0:
        raise ValueError(f"fp_nbytes={fp_nbytes} must be >= 0")
    n = max(1, fp_nbytes // 4)
    channels = -(-n // WIRE_CHANNEL)
    return WIRE_HEADER_NBYTES + n + 8 * channels
