"""Pure-jnp/NumPy oracles for every Pallas kernel (the allclose reference)."""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def framediff_ref(f0: jax.Array, f1: jax.Array, f2: jax.Array,
                  threshold: int, maxval: int = 255) -> jax.Array:
    """Paper Eqs. 1-4 on uint8-valued int32 frames (B,H,W,3) -> (B,H,W) mask.

    D1 = |f1-f0|, D2 = |f2-f1|, Da = D1 & D2 (bitwise), grayscale (BT.601
    integer weights), fixed-level threshold -> {0, maxval}.
    """
    d1 = jnp.abs(f1 - f0)
    d2 = jnp.abs(f2 - f1)
    da = jnp.bitwise_and(d1, d2)
    gray = (da[..., 0] * 299 + da[..., 1] * 587 + da[..., 2] * 114) // 1000
    return jnp.where(gray > threshold, maxval, 0).astype(f0.dtype)


def _shift2d(x: jax.Array, dy: int, dx: int, fill) -> jax.Array:
    """Shift (..., H, W) by (dy, dx), filling vacated cells."""
    H, W = x.shape[-2], x.shape[-1]
    y = jnp.roll(x, (dy, dx), axis=(-2, -1))
    if dy > 0:
        y = y.at[..., :dy, :].set(fill)
    elif dy < 0:
        y = y.at[..., dy:, :].set(fill)
    if dx > 0:
        y = y.at[..., :, :dx].set(fill)
    elif dx < 0:
        y = y.at[..., :, dx:].set(fill)
    return y


def dilate3x3_ref(x: jax.Array) -> jax.Array:
    """Paper Eq. 5: 3x3 max filter over (B,H,W) int32 (zero-padded)."""
    out = x
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            out = jnp.maximum(out, _shift2d(x, dy, dx, 0))
    return out


def erode3x3_ref(x: jax.Array, maxval: int = 255) -> jax.Array:
    """Paper Eq. 6: 3x3 min filter over (B,H,W) int32 (maxval-padded)."""
    out = x
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            out = jnp.minimum(out, _shift2d(x, dy, dx, maxval))
    return out


def pixel_cascade_ref(f0: jax.Array, f1: jax.Array, f2: jax.Array,
                      threshold: int, maxval: int = 255) -> jax.Array:
    """Jnp twin of the fused cascade: Eqs. 1-6 composed, (B,H,W) mask.

    The morphology runs as ``lax.reduce_window`` (bit-exact for integer
    max/min: window init 0 == dilate's fill since the mask is >= 0, init
    ``maxval`` == erode's fill since the mask is <= maxval) — this is the
    XLA-compiled fused twin the benchmarks time where compiled Pallas is
    unavailable, so it should be the *fast* honest composition, not the
    shift-and-mask teaching oracle above.
    """
    m = framediff_ref(f0, f1, f2, threshold, maxval)
    win, strides = (1, 3, 3), (1, 1, 1)
    pad = ((0, 0), (1, 1), (1, 1))
    m = jax.lax.reduce_window(m, jnp.asarray(0, m.dtype),
                              jax.lax.max, win, strides, pad)
    return jax.lax.reduce_window(m, jnp.asarray(maxval, m.dtype),
                                 jax.lax.min, win, strides, pad)


def pixel_cascade_np(f0: np.ndarray, f1: np.ndarray, f2: np.ndarray,
                     threshold: int, maxval: int = 255
                     ) -> Tuple[np.ndarray, np.ndarray]:
    """Independent NumPy oracle for the fused pixel cascade.

    Deliberately NOT a composition of the jnp twins: explicit np.pad
    halos and nine-slice loops, so the parity test checks the boundary
    semantics, not a shared implementation.  Returns (mask (B, H, W)
    int32, counts (B,) int32 foreground pixels per camera).
    """
    f0, f1, f2 = (np.asarray(f, np.int64) for f in (f0, f1, f2))
    d1 = np.abs(f1 - f0)
    d2 = np.abs(f2 - f1)
    da = np.bitwise_and(d1, d2)
    gray = (da[..., 0] * 299 + da[..., 1] * 587 + da[..., 2] * 114) // 1000
    m = np.where(gray > threshold, maxval, 0)
    B, H, W = m.shape

    def morph(x, red, fill):
        xp = np.pad(x, ((0, 0), (1, 1), (1, 1)), constant_values=fill)
        acc = None
        for dy in range(3):
            for dx in range(3):
                sl = xp[:, dy:dy + H, dx:dx + W]
                acc = sl if acc is None else red(acc, sl)
        return acc

    m = morph(m, np.maximum, 0)
    m = morph(m, np.minimum, maxval)
    mask = m.astype(np.int32)
    return mask, (mask > 0).sum(axis=(1, 2)).astype(np.int32)


def mha_ref(q: jax.Array, k: jax.Array, v: jax.Array,
            causal: bool = True) -> jax.Array:
    """Unfused GQA attention oracle.  q (B,H,Sq,hd), k/v (B,KV,Sk,hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qr = q.reshape(B, KV, G, Sq, hd).astype(jnp.float32)
    s = jnp.einsum("bkgqh,bksh->bkgqs", qr, k.astype(jnp.float32))
    s = s / jnp.sqrt(jnp.float32(hd))
    if causal:
        mask = jnp.arange(Sq)[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bksh->bkgqh", p, v.astype(jnp.float32))
    return o.reshape(B, H, Sq, hd).astype(q.dtype)


def triage_ref(conf: jax.Array, alpha: float, beta: float,
               capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Cascade triage + stable compaction of escalated indices.

    conf (N,) f32 -> routes (N,) int32 {0 accept,1 reject,2 escalate},
    slots (N,) int32 (slot in the escalation buffer, or -1),
    count () int32.
    """
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32)) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    return routes, slots, jnp.sum(esc.astype(jnp.int32))


def triage_fleet_ref(conf: jax.Array, thresholds: jax.Array,
                     capacity: int) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Per-row triage over the whole fleet's tick matrix.

    conf (..., N) f32 with thresholds (..., 2) f32 [alpha, beta] per row ->
    routes (..., N) int32, slots (..., N) int32 (per-row stable compaction,
    each row's escalation buffer capped at ``capacity``), counts (...,)
    int32.  The leading axes are arbitrary: (E, N) for the single-query
    fleet, (Q, E, N) for the multi-query fleet — every (query, edge) pair
    is an independent row with its own thresholds and its own buffer.
    """
    alpha = thresholds[..., 0:1]
    beta = thresholds[..., 1:2]
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32), axis=-1) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    return routes, slots, jnp.sum(esc.astype(jnp.int32), axis=-1)


def calibrate_fleet_ref(scores: np.ndarray, truths: np.ndarray,
                        iters: int, min_count: int
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """NumPy twin of ``calibrate.calibrate_fleet_pallas``: per-edge Platt fit.

    Deliberately an *independent* implementation (float64, explicit per-row
    Newton loop) so the parity test checks the numerics, not the layout:
    scores (E, N) with pad lanes -1.0, truths (E, N) {0, 1} ->
    (params (E, 2) [a, b], counts (E,) valid labels).  A (Q, E, N) input
    folds its leading axes to Q·E independent rows — same contract as the
    fused kernel's query axis — and returns (Q, E, 2)/(Q, E).  Constants
    (clip epsilon, ridge, clamps) mirror ``kernels/calibrate.py``.
    """
    from repro.kernels.calibrate import A_MAX, A_MIN, B_MAX, EPS, PRIOR
    scores = np.asarray(scores, np.float64)
    truths = np.asarray(truths, np.float64)
    if scores.ndim == 3:
        lead = scores.shape[:2]
        params, counts = calibrate_fleet_ref(
            scores.reshape(-1, scores.shape[-1]),
            truths.reshape(-1, truths.shape[-1]), iters, min_count)
        return params.reshape(*lead, 2), counts.reshape(lead)
    E = scores.shape[0]
    params = np.tile(np.asarray([1.0, 0.0]), (E, 1))
    counts = np.zeros(E, np.int32)
    for e in range(E):
        valid = scores[e] >= 0.0
        counts[e] = int(valid.sum())
        y01 = truths[e, valid]
        n_pos = y01.sum()
        if counts[e] < min_count or n_pos < 1 or n_pos > counts[e] - 1:
            continue
        n_neg = counts[e] - n_pos
        # Platt target smoothing, same constants as the kernel
        y = np.where(y01 > 0.5, (n_pos + 1.0) / (n_pos + 2.0),
                     1.0 / (n_neg + 2.0))
        c = np.clip(scores[e, valid], EPS, 1.0 - EPS)
        x = np.log(c / (1.0 - c))
        a, b = 1.0, 0.0
        for _ in range(iters):
            p = 1.0 / (1.0 + np.exp(-(a * x + b)))
            g0 = float(np.sum((p - y) * x)) + PRIOR * (a - 1.0)
            g1 = float(np.sum(p - y)) + PRIOR * b
            w = p * (1.0 - p)
            h00 = float(np.sum(w * x * x)) + PRIOR
            h01 = float(np.sum(w * x))
            h11 = float(np.sum(w)) + PRIOR
            det = h00 * h11 - h01 * h01
            a = float(np.clip(a - (h11 * g0 - h01 * g1) / det, A_MIN, A_MAX))
            b = float(np.clip(b - (h00 * g1 - h01 * g0) / det, -B_MAX, B_MAX))
        params[e] = (a, b)
    return params.astype(np.float32), counts


def associate_tracks_ref(emb: np.ndarray, trk: np.ndarray,
                         crop_q: np.ndarray, trk_q: np.ndarray,
                         thr: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """NumPy twin of ``similarity.associate_pallas``: greedy re-ID matching.

    Deliberately an *independent* implementation (explicit per-crop python
    loop and a mutable claimed set instead of the kernel's vectorized
    one-hot ``fori_loop``) so the parity test checks the matching
    semantics, not a shared implementation.  emb (M, D) and trk (K, D)
    L2-normalized float32, crop_q (M,) / trk_q (K,) int32 query ids, thr
    (M,) per-crop acceptance floors -> (assign (M,) int32 track row index
    or -1, sim (M,) float32 best available score, -1e30 when the crop's
    query has no unclaimed track).  Crops match greedily in row order,
    one-to-one, only within their own query id.
    """
    emb = np.asarray(emb, np.float32)
    trk = np.asarray(trk, np.float32)
    crop_q = np.asarray(crop_q, np.int32)
    trk_q = np.asarray(trk_q, np.int32)
    thr = np.asarray(thr, np.float32)
    M = emb.shape[0]
    K = trk.shape[0]
    assign = np.full(M, -1, np.int32)
    sim = np.full(M, np.float32(-1e30), np.float32)
    if K == 0:
        return assign, sim
    s = emb @ trk.T                                     # (M, K) float32
    s = np.where(crop_q[:, None] == trk_q[None, :], s, np.float32(-1e30))
    claimed = np.zeros(K, bool)
    for i in range(M):
        avail = np.where(claimed, np.float32(-1e30), s[i])
        j = int(np.argmax(avail))
        sim[i] = avail[j]
        if avail[j] >= thr[i]:
            assign[i] = j
            claimed[j] = True
    return assign, sim
