"""Pallas TPU kernel: fused fleet-wide confidence recalibration (Platt).

This is the compute heart of the cloud->edge learning loop: every cloud
re-classification is an exact label for the edge confidence that escalated,
and every ``update_period_s`` the feedback stage fits, for EVERY edge at
once, a two-parameter Platt map

    conf' = sigmoid(a * logit(conf) + b)

by masked Newton-Raphson on each edge's logistic negative log-likelihood —
ONE (E, N) launch per update event, the same bucket-padded layout as
``triage_fleet``.  Rows are independent: all reductions run along the
sample axis, the 2x2 Newton system is solved in closed form per row
(ridge-damped so fully-masked rows stay finite), and degenerate rows (too
few labels, or labels all one class) fall back to the identity (1, 0).

Pad lanes carry score -1.0 (same sentinel as ``triage_fleet``'s pad
convention) and are masked out of every sum, so padding can never move a
fit; pad edge rows are fully masked and therefore come back as identity.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

# Score clipping before the logit transform.  apply_calibration on the
# numpy side MUST use the same epsilon so train-time and serve-time
# features agree.
EPS = 1e-4
PRIOR = 0.5           # MAP pull of (a, b) toward the identity (1, 0): a
#                       dozen-label fit stays near identity, hundreds of
#                       labels override it — bad small-sample maps are the
#                       loop's main failure mode
A_MIN, A_MAX = 0.05, 6.0
B_MAX = 8.0


def _fit_rows(scores, truths, *, iters: int, min_count: int):
    """Shared fit body: (E, N) scores/0-1 truths -> ((E, 2) params, (E,) n).

    Written in plain jnp so the Pallas kernel body and the ``ref`` oracle
    are literally the same arithmetic (parity is then a layout test, not a
    numerics test)."""
    mask = (scores >= 0.0).astype(jnp.float32)
    c = jnp.clip(scores, EPS, 1.0 - EPS)
    x = jnp.log(c / (1.0 - c))                          # logit feature
    y01 = truths.astype(jnp.float32)
    n = jnp.sum(mask, axis=1)                           # (E,)
    pos = jnp.sum(mask * y01, axis=1)
    neg = n - pos
    # Platt target smoothing: regress on (N+ + 1)/(N+ + 2) and 1/(N- + 2)
    # instead of hard 0/1, so a by-chance-separable buffer cannot drive the
    # fit to a step function (the classic Platt 1999 regularizer)
    t_pos = ((pos + 1.0) / (pos + 2.0))[:, None]
    t_neg = (1.0 / (neg + 2.0))[:, None]
    y = jnp.where(y01 > 0.5, t_pos, t_neg)

    def newton(_, ab):
        a, b = ab[:, 0:1], ab[:, 1:2]                   # (E, 1)
        p = jax.nn.sigmoid(a * x + b)
        resid = mask * (p - y)
        g0 = jnp.sum(resid * x, axis=1) + PRIOR * (ab[:, 0] - 1.0)
        g1 = jnp.sum(resid, axis=1) + PRIOR * ab[:, 1]
        w = mask * p * (1.0 - p)
        h00 = jnp.sum(w * x * x, axis=1) + PRIOR
        h01 = jnp.sum(w * x, axis=1)
        h11 = jnp.sum(w, axis=1) + PRIOR
        det = h00 * h11 - h01 * h01
        da = (h11 * g0 - h01 * g1) / det
        db = (h00 * g1 - h01 * g0) / det
        a_new = jnp.clip(ab[:, 0] - da, A_MIN, A_MAX)
        b_new = jnp.clip(ab[:, 1] - db, -B_MAX, B_MAX)
        return jnp.stack([a_new, b_new], axis=1)

    E = scores.shape[0]
    # identity map (a=1, b=0) per row, built from scalar broadcasts only (a
    # materialized [[1, 0]] constant may not be captured by a Pallas body)
    init = jnp.concatenate([jnp.ones((E, 1), jnp.float32),
                            jnp.zeros((E, 1), jnp.float32)], axis=1)
    ab = jax.lax.fori_loop(0, iters, newton, init)
    # degenerate rows keep the identity map: too few cloud labels, or the
    # labels are single-class (a separable 1D logistic diverges)
    ok = (n >= min_count) & (pos >= 1.0) & (pos <= n - 1.0)
    params = jnp.where(ok[:, None], ab, init)
    return params.astype(jnp.float32), n.astype(jnp.int32)


def _calibrate_kernel(scores_ref, truths_ref, params_ref, count_ref, *,
                      iters: int, min_count: int):
    params, n = _fit_rows(scores_ref[...], truths_ref[...],
                          iters=iters, min_count=min_count)
    params_ref[...] = params
    count_ref[...] = n


def calibrate_fleet_pallas(scores: jax.Array, truths: jax.Array, *,
                           iters: int, min_count: int,
                           interpret: Optional[bool] = None):
    """scores (E, N) f32 (pad lanes -1.0), truths (E, N) f32 {0, 1} ->
    (params (E, 2) f32 [a, b], counts (E,) i32 valid labels per edge)."""
    interpret = resolve_interpret(interpret)
    E, N = scores.shape
    kernel = functools.partial(_calibrate_kernel, iters=iters,
                               min_count=min_count)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((E, N), lambda: (0, 0)),
                  pl.BlockSpec((E, N), lambda: (0, 0))],
        out_specs=(pl.BlockSpec((E, 2), lambda: (0, 0)),
                   pl.BlockSpec((E,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((E, 2), jnp.float32),
                   jax.ShapeDtypeStruct((E,), jnp.int32)),
        interpret=interpret,
    )(scores, truths)
