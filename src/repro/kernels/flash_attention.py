"""Pallas TPU kernel: fused flash attention (online softmax, GQA, causal).

The roofline (§Roofline) shows every train/prefill pair compute-bound with
the unfused attention path paying extra HBM round-trips for scores/probs.
This kernel keeps a (block_q, hd) f32 accumulator in VMEM/VREGs and streams
K/V blocks with the online-softmax recurrence — one HBM pass over Q/K/V.

Grid: (batch, q_heads, Sq/block_q).  GQA maps q-head h to kv-head
h // (H // KV) in the BlockSpec index map.  Causal masking skips fully
masked K blocks via the loop upper bound.

Target: TPU MXU (block shapes multiples of (8,128) after padding by ops.py);
validated on CPU in interpret mode against ``ref.mha_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *,
                  block_q: int, block_k: int, causal: bool, scale: float):
    # q_ref: (1,1,block_q,hd); k_ref/v_ref: (1,1,Sk,hd); o_ref like q_ref
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    Sk = k_ref.shape[2]
    hd = q.shape[-1]
    nk = Sk // block_k

    if causal:
        # last k block that intersects the triangle for this q block
        hi = jax.lax.div((qi + 1) * block_q + block_k - 1, block_k)
        hi = jnp.minimum(hi, nk)
    else:
        hi = nk

    def body(j, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(
            k_ref[0, 0], (j * block_k, 0), (block_k, hd)).astype(jnp.float32)
        v = jax.lax.dynamic_slice(
            v_ref[0, 0], (j * block_k, 0), (block_k, hd)).astype(jnp.float32)
        s = q @ k.T                                       # (bq, bk)
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            kpos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc0 = jnp.zeros((block_q, hd), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, hi, body, (acc0, m0, l0))
    out = acc / jnp.maximum(l, 1e-20)[:, None]
    o_ref[0, 0] = out.astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           block_q: int = 128, block_k: int = 128,
                           interpret: Optional[bool] = None) -> jax.Array:
    """q: (B,H,Sq,hd); k/v: (B,KV,Sk,hd) with H % KV == 0.  -> (B,H,Sq,hd).

    Sq % block_q == 0 and Sk % block_k == 0 (ops.py pads).
    """
    interpret = resolve_interpret(interpret)
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0 and Sq % block_q == 0 and Sk % block_k == 0
    G = H // KV
    grid = (B, H, Sq // block_q)
    kernel = functools.partial(_flash_kernel, block_q=block_q,
                               block_k=block_k, causal=causal,
                               scale=1.0 / math.sqrt(hd))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i, g=G: (b, h // g, 0, 0)),
            pl.BlockSpec((1, 1, Sk, hd), lambda b, h, i, g=G: (b, h // g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        interpret=interpret,
    )(q, k, v)
