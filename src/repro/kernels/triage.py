"""Pallas TPU kernels: cascade triage + escalation compaction (core C1).

One pass over a batch of edge confidences produces route codes, escalation
buffer slots (stable prefix-sum compaction) and the escalated count.  This
is the per-batch hot path of the SurveilEdge allocator: on TPU it runs as a
single VMEM-resident block (batch sizes are << VMEM), avoiding three
separate elementwise+scan launches.

Two granularities share one kernel body:

  * ``triage_dynamic_pallas`` — one edge's (N,) batch, thresholds as a (2,)
    runtime input (``triage_pallas`` delegates here with its static
    alpha/beta packed into that input).
  * ``triage_fleet_pallas`` — the whole fleet's (E, N) tick matrix with an
    (E, 2) per-edge threshold matrix: every edge's triage + compaction in
    ONE launch per scheduler tick, instead of one launch per edge per tick.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret


def _triage_dyn_kernel(conf_ref, ab_ref, routes_ref, slots_ref, count_ref, *,
                       capacity: int):
    """Fused triage + stable compaction, alpha/beta as a (2,) runtime input.

    Baking alpha/beta into the trace would force a retrace every time
    Eqs. 8-9 move the thresholds — i.e. every scheduler tick.  Reading them
    from VMEM keeps the per-tick hot path at a single cached compilation.
    """
    conf = conf_ref[...]
    alpha = ab_ref[0]
    beta = ab_ref[1]
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32)) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    routes_ref[...] = routes
    slots_ref[...] = slots
    count_ref[0] = jnp.sum(esc.astype(jnp.int32))


def triage_dynamic_pallas(conf: jax.Array, thresholds: jax.Array, *,
                          capacity: int, interpret: Optional[bool] = None):
    """conf (N,) f32, thresholds (2,) f32 [alpha, beta] ->
    (routes (N,) i32, slots (N,) i32, count (1,) i32)."""
    interpret = resolve_interpret(interpret)
    (N,) = conf.shape
    kernel = functools.partial(_triage_dyn_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((N,), lambda: (0,)),
                  pl.BlockSpec((2,), lambda: (0,))],
        out_specs=(pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(conf, thresholds)


def triage_pallas(conf: jax.Array, *, alpha: float, beta: float,
                  capacity: int, interpret: Optional[bool] = None):
    """conf (N,) f32 -> (routes (N,) i32, slots (N,) i32, count (1,) i32).

    Static-threshold convenience wrapper: packs alpha/beta into the dynamic
    kernel's (2,) threshold input (one kernel body to maintain; the static
    values still specialize the trace via the input array's contents only,
    so distinct thresholds share one compilation).
    """
    thresholds = jnp.asarray([alpha, beta], jnp.float32)
    return triage_dynamic_pallas(conf, thresholds, capacity=capacity,
                                 interpret=interpret)


def _triage_fleet_kernel(conf_ref, ab_ref, routes_ref, slots_ref, count_ref,
                         *, capacity: int):
    """(E, N) fleet tick matrix, per-edge (E, 2) runtime thresholds.

    Row e is edge e's padded per-tick batch; compaction (cumsum along the
    camera axis) and the escalation-capacity clamp are per row, so each
    edge keeps its own private escalation buffer exactly as in the
    one-edge kernel.  The whole fleet is one VMEM-resident block: for the
    city-scale operating point (64 edges x 512-wide tick buckets) the
    inputs are ~130 KB, far below VMEM, and the launch count per tick
    drops from E to 1.
    """
    conf = conf_ref[...]                       # (E, N)
    alpha = ab_ref[:, 0:1]                     # (E, 1) broadcast over cameras
    beta = ab_ref[:, 1:2]
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32), axis=1) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    routes_ref[...] = routes
    slots_ref[...] = slots
    count_ref[...] = jnp.sum(esc.astype(jnp.int32), axis=1)


def triage_fleet_pallas(conf: jax.Array, thresholds: jax.Array, *,
                        capacity: int, interpret: Optional[bool] = None):
    """conf (E, N) f32, thresholds (E, 2) f32 [alpha, beta] per edge ->
    (routes (E, N) i32, slots (E, N) i32, counts (E,) i32)."""
    interpret = resolve_interpret(interpret)
    E, N = conf.shape
    kernel = functools.partial(_triage_fleet_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((E, N), lambda: (0, 0)),
                  pl.BlockSpec((E, 2), lambda: (0, 0))],
        out_specs=(pl.BlockSpec((E, N), lambda: (0, 0)),
                   pl.BlockSpec((E, N), lambda: (0, 0)),
                   pl.BlockSpec((E,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((E, N), jnp.int32),
                   jax.ShapeDtypeStruct((E, N), jnp.int32),
                   jax.ShapeDtypeStruct((E,), jnp.int32)),
        interpret=interpret,
    )(conf, thresholds)
