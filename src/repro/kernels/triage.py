"""Pallas TPU kernel: cascade triage + escalation compaction (core C1).

One pass over a batch of edge confidences produces route codes, escalation
buffer slots (stable prefix-sum compaction) and the escalated count.  This
is the per-batch hot path of the SurveilEdge allocator: on TPU it runs as a
single VMEM-resident block (batch sizes are << VMEM), avoiding three
separate elementwise+scan launches.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _triage_kernel(conf_ref, routes_ref, slots_ref, count_ref, *,
                   alpha: float, beta: float, capacity: int):
    conf = conf_ref[...]
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32)) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    routes_ref[...] = routes
    slots_ref[...] = slots
    count_ref[0] = jnp.sum(esc.astype(jnp.int32))


def triage_pallas(conf: jax.Array, *, alpha: float, beta: float,
                  capacity: int, interpret: bool = True):
    """conf (N,) f32 -> (routes (N,) i32, slots (N,) i32, count (1,) i32)."""
    (N,) = conf.shape
    kernel = functools.partial(_triage_kernel, alpha=alpha, beta=beta,
                               capacity=capacity)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((N,), lambda: (0,))],
        out_specs=(pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(conf)


def _triage_dyn_kernel(conf_ref, ab_ref, routes_ref, slots_ref, count_ref, *,
                       capacity: int):
    """Same fused pass with alpha/beta read from a (2,) runtime input.

    The static-threshold kernel above bakes alpha/beta into the trace, which
    is fine for one-off calls but forces a retrace every time Eqs. 8-9 move
    the thresholds — i.e. every scheduler tick.  Reading them from VMEM keeps
    the per-tick hot path at a single cached compilation.
    """
    conf = conf_ref[...]
    alpha = ab_ref[0]
    beta = ab_ref[1]
    routes = jnp.where(conf > alpha, 0,
                       jnp.where(conf < beta, 1, 2)).astype(jnp.int32)
    esc = routes == 2
    pos = jnp.cumsum(esc.astype(jnp.int32)) - 1
    slots = jnp.where(esc & (pos < capacity), pos, -1).astype(jnp.int32)
    routes_ref[...] = routes
    slots_ref[...] = slots
    count_ref[0] = jnp.sum(esc.astype(jnp.int32))


def triage_dynamic_pallas(conf: jax.Array, thresholds: jax.Array, *,
                          capacity: int, interpret: bool = True):
    """conf (N,) f32, thresholds (2,) f32 [alpha, beta] ->
    (routes (N,) i32, slots (N,) i32, count (1,) i32)."""
    (N,) = conf.shape
    kernel = functools.partial(_triage_dyn_kernel, capacity=capacity)
    return pl.pallas_call(
        kernel,
        in_specs=[pl.BlockSpec((N,), lambda: (0,)),
                  pl.BlockSpec((2,), lambda: (0,))],
        out_specs=(pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((N,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((N,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.int32)),
        interpret=interpret,
    )(conf, thresholds)
