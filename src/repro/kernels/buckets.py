"""Padding-bucket table for the fleet kernels — importable WITHOUT jax.

Every fleet-shaped Pallas launch in the repo pads its axes up to
power-of-two buckets so a run's stream of varying tick matrices hits a
handful of cached compilations (see ``kernels/ops.py``).  The bucket
arithmetic lives here, jax-free, so config-layer code — notably
``Scenario.__post_init__`` — can validate fleet dimensions against the
same table the kernels will actually pad to and raise a clear
``ValueError`` *before* an oversized (Q·E, N) launch surfaces as an
opaque Pallas block-shape error deep inside a run.

Limits are sized for the CPU interpret-mode substrate this container
runs: the fused triage kernel is a single block, so every padded element
is materialized at once.  ``MAX_FLEET_ROWS`` bounds the per-tick folded
(Q·E) row space a scenario may declare; ``MAX_SUPERSTEP_ELEMS`` bounds
one scan superstep's folded (S·R, N) slab (the superstep planner clamps
its tick span to stay under it, never errors).
"""
from __future__ import annotations

#: minimum padded size of the edge / camera-lane axes (see ``bucket``)
BUCKET_MIN = 8

#: largest padded Q·E row count a scenario may fold into one fleet launch
MAX_FLEET_ROWS = 1 << 17

#: largest padded element count (S·R·N) of one scan-superstep triage slab
MAX_SUPERSTEP_ELEMS = 1 << 22

# --- pixel-cascade frame tiles ------------------------------------------------
# The fused pixel-cascade kernel (``kernels/pixel_cascade.py``) walks each
# camera's frame in (FRAME_BAND_H, W) row bands with the W axis padded to
# lane multiples; the staged morphology kernels use the same band height.
# These are the numbers ``validate_frame_hw`` checks a Scenario.frame_hw
# against, so a bad frame size raises here — with the padded tile spelled
# out — instead of as a Pallas block-shape error at first render.

#: output rows per pixel-cascade band (the stencil pipeline's block height)
FRAME_BAND_H = 32

#: lane-aligned width multiple every frame pads up to before a launch
FRAME_LANE_W = 128

#: smallest frame side the cascade's 3x3 stencil halos make sense for
MIN_FRAME_SIDE = 16

#: largest padded per-camera pixel count (H_pad * W_pad) of one frame —
#: bounds the interpret-mode slab like ``MAX_FLEET_ROWS`` bounds triage
MAX_FRAME_ELEMS = 1 << 22


def frame_pad(h: int, w: int):
    """Padded (H, W) the pixel kernels actually launch for a (h, w) frame."""
    hp = -(-h // FRAME_BAND_H) * FRAME_BAND_H
    wp = -(-w // FRAME_LANE_W) * FRAME_LANE_W
    return hp, wp


def validate_frame_hw(name: str, h: int, w: int) -> None:
    """Reject frame sizes the pixel-cascade tile table cannot host.

    Raises ``ValueError`` with the padded tile sizes spelled out — the
    same numbers that would otherwise appear (unexplained) in a Pallas
    block-shape error at the first rendered tick."""
    if h < MIN_FRAME_SIDE or w < MIN_FRAME_SIDE:
        raise ValueError(
            f"scenario {name!r}: frame_hw=({h}, {w}) is below the pixel "
            f"cascade's minimum frame side of {MIN_FRAME_SIDE} px — the "
            f"fused 3x3 stencil pipeline needs at least one "
            f"{MIN_FRAME_SIDE}x{MIN_FRAME_SIDE} sprite's worth of pixels "
            f"per frame")
    hp, wp = frame_pad(h, w)
    if hp * wp > MAX_FRAME_ELEMS:
        raise ValueError(
            f"scenario {name!r}: frame_hw=({h}, {w}) pads to "
            f"({hp}, {wp}) = {hp * wp} pixels per camera frame, over the "
            f"pixel-cascade tile table's limit of {MAX_FRAME_ELEMS} — "
            f"this would surface as an opaque Pallas shape error at the "
            f"first rendered tick; shrink the frame")


def bucket(n: int, minimum: int = BUCKET_MIN) -> int:
    """Next power-of-two size >= n (jit-cache-stable padding bucket)."""
    return max(minimum, 1 << (max(n - 1, 1)).bit_length())


def bucket_q(q: int) -> int:
    """Power-of-two bucket for the query axis, minimum 1.

    The query axis stays tiny (a handful of live CQs), so unlike the edge
    and camera axes it gets no minimum-8 floor: a single-query run pays
    zero padding and folds to exactly the (E, N) layout it had before the
    query axis existed."""
    return 1 if q <= 1 else 1 << (q - 1).bit_length()


def fleet_rows(num_queries: int, num_edges: int) -> int:
    """Padded row count of the folded (Q·E, N) fleet-triage launch."""
    return bucket_q(num_queries) * bucket(num_edges)


def validate_fleet_dims(name: str, num_queries: int, num_edges: int,
                        capacity: int) -> None:
    """Reject fleet dimensions the kernel bucket table cannot host.

    Raises ``ValueError`` with the padded sizes spelled out — the same
    numbers that would otherwise appear (unexplained) in a Pallas
    block-shape error at first launch."""
    if num_edges < 1:
        raise ValueError(
            f"scenario {name!r}: needs at least one edge "
            f"(edge_speeds is empty) — the fused (Q, E, N) triage launch "
            f"has no rows without an edge axis")
    if capacity < 1:
        raise ValueError(
            f"scenario {name!r}: escalation_capacity={capacity} must be "
            f">= 1 (it sizes the kernel's per-row escalation buffer)")
    rows = fleet_rows(num_queries, num_edges)
    if rows > MAX_FLEET_ROWS:
        raise ValueError(
            f"scenario {name!r}: {num_queries} queries x {num_edges} edges "
            f"pads to {bucket_q(num_queries)} x {bucket(num_edges)} = "
            f"{rows} fleet rows, over the kernel bucket table's limit of "
            f"{MAX_FLEET_ROWS} — this would surface as an opaque Pallas "
            f"block-shape error at the first fused triage launch; shrink "
            f"the fleet or split the query set")
