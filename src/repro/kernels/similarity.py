"""Pallas TPU kernel: fused re-ID similarity + greedy track association.

Cross-camera track queries match every detection crop's embedding against
the fleet-wide live track table once per scheduler tick.  This kernel
fuses the whole match into ONE launch — the same per-tick budget
discipline as ``triage.triage_fleet_pallas``:

  1. batched QK-style scores: ``s = emb @ trk.T`` over L2-normalized
     embeddings (cosine similarity), computed exactly like the
     ``flash_attention`` kernel's query-key score step, with the same
     ``NEG_INF`` masking discipline — here the mask is query identity
     (a crop may only match tracks of its OWN query), which is also what
     lets every live track query share one launch per tick;
  2. greedy one-to-one assignment folded into the same launch: crops
     claim tracks in arrival order (a ``fori_loop`` carrying the claimed
     set), each taking the best *unclaimed* track of its query, and
     matching only if that best score clears the crop's own threshold
     row (per-crop thresholds are how warm/cold edge state reaches the
     kernel as data, not trace constants).

Unlike attention's long sequences, a fleet's live track table is tiny
(hundreds of rows, not tens of thousands), so the whole problem is one
VMEM-resident block — whole-block ``BlockSpec``s like the fleet-triage
kernel rather than a ``flash_attention``-style K-block grid; the inputs
for the ``vehicle_pursuit`` operating point are a few KB.

Inputs are bucket-padded by the ``ops.associate_tracks`` wrapper
(``buckets.py`` discipline): pad crops carry query id -1, pad tracks
query id -2 — the ids can never be equal, so pad rows are masked
everywhere and can neither match nor be claimed.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

#: flash-attention's additive-mask value, reused as the "impossible match"
#: score (a masked pair can never clear a threshold in (0, 2])
NEG_INF = -1e30


def _associate_kernel(emb_ref, trk_ref, cq_ref, tq_ref, thr_ref,
                      assign_ref, sim_ref):
    """One fused score + greedy-assign pass.

    emb (M, D) crop embeddings, trk (K, D) track embeddings (both
    L2-normalized by the wrapper), cq (M,) / tq (K,) int32 query ids,
    thr (M,) per-crop acceptance floors -> assign (M,) int32 (track row
    index or -1) and sim (M,) f32 (the best *available* score each crop
    saw, ``NEG_INF`` when nothing of its query was unclaimed).

    The greedy loop is fully vectorized (one-hot row selects, no dynamic
    gathers), so the same body lowers compiled and interpreted.
    """
    emb = emb_ref[...]                         # (M, D)
    trk = trk_ref[...]                         # (K, D)
    cq = cq_ref[...]                           # (M,)
    tq = tq_ref[...]                           # (K,)
    thr = thr_ref[...]                         # (M,)
    M = emb.shape[0]
    K = trk.shape[0]
    s = jnp.dot(emb, trk.T,
                preferred_element_type=jnp.float32)          # (M, K)
    s = jnp.where(cq[:, None] == tq[None, :], s, NEG_INF)
    rows = jnp.arange(M, dtype=jnp.int32)
    cols = jnp.arange(K, dtype=jnp.int32)

    def body(i, carry):
        claimed, assign, sim = carry
        onei = rows == i
        row = jnp.sum(jnp.where(onei[:, None], s, 0.0), axis=0)  # s[i]
        thr_i = jnp.sum(jnp.where(onei, thr, 0.0))
        avail = jnp.where(claimed, NEG_INF, row)
        best = jnp.max(avail)
        j = jnp.argmax(avail).astype(jnp.int32)
        ok = best >= thr_i
        claimed = claimed | ((cols == j) & ok)
        assign = jnp.where(onei, jnp.where(ok, j, -1), assign)
        sim = jnp.where(onei, best, sim)
        return claimed, assign, sim

    _, assign, sim = jax.lax.fori_loop(
        0, M, body,
        (jnp.zeros((K,), jnp.bool_),
         jnp.full((M,), -1, jnp.int32),
         jnp.full((M,), NEG_INF, jnp.float32)))
    assign_ref[...] = assign
    sim_ref[...] = sim


def associate_pallas(emb: jax.Array, trk: jax.Array, crop_q: jax.Array,
                     trk_q: jax.Array, thr: jax.Array, *,
                     interpret: Optional[bool] = None):
    """emb (M, D) f32, trk (K, D) f32, crop_q (M,) i32, trk_q (K,) i32,
    thr (M,) f32 -> (assign (M,) i32, sim (M,) f32)."""
    interpret = resolve_interpret(interpret)
    M, D = emb.shape
    K = trk.shape[0]
    return pl.pallas_call(
        _associate_kernel,
        in_specs=[pl.BlockSpec((M, D), lambda: (0, 0)),
                  pl.BlockSpec((K, D), lambda: (0, 0)),
                  pl.BlockSpec((M,), lambda: (0,)),
                  pl.BlockSpec((K,), lambda: (0,)),
                  pl.BlockSpec((M,), lambda: (0,))],
        out_specs=(pl.BlockSpec((M,), lambda: (0,)),
                   pl.BlockSpec((M,), lambda: (0,))),
        out_shape=(jax.ShapeDtypeStruct((M,), jnp.int32),
                   jax.ShapeDtypeStruct((M,), jnp.float32)),
        interpret=interpret,
    )(emb, trk, crop_q, trk_q, thr)
