"""Pallas TPU kernels: 3x3 dilation / erosion (paper Eqs. 5-6).

A 3x3 stencil needs a 1-pixel halo.  Pallas blocks cannot overlap, so the
wrapper materializes overlapping row-bands (bh+2 rows each) with a strided
gather and the kernel reduces nine in-register shifted slices per band —
VREG shifts, no re-loads, exactly how a TPU stencil wants to run.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

BAND_H = 32        # output rows per band


def _morph_kernel(xb_ref, out_ref, *, op: str):
    """xb_ref: (1,1,bh+2,W+2) padded band -> out_ref (1,1,bh,W)."""
    x = xb_ref[0, 0]
    bh = out_ref.shape[2]
    W = out_ref.shape[3]
    red = jnp.maximum if op == "max" else jnp.minimum
    acc = None
    for dy in range(3):
        for dx in range(3):
            sl = x[dy:dy + bh, dx:dx + W]
            acc = sl if acc is None else red(acc, sl)
    out_ref[0, 0] = acc.astype(out_ref.dtype)


def _morph_pallas(x: jax.Array, *, op: str, fill: int,
                  interpret: Optional[bool] = None) -> jax.Array:
    """(B, H, W) int32 -> (B, H, W); 3x3 max/min with `fill` padding."""
    interpret = resolve_interpret(interpret)
    B, H, W = x.shape
    assert H % BAND_H == 0, (H, BAND_H)
    nb = H // BAND_H
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1)), constant_values=fill)
    # overlapping bands: (B, nb, BAND_H+2, W+2)
    bands = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(xp, i * BAND_H, BAND_H + 2, axis=1)
         for i in range(nb)], axis=1)
    grid = (B, nb)
    kernel = functools.partial(_morph_kernel, op=op)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((1, 1, BAND_H + 2, W + 2),
                               lambda b, i: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, BAND_H, W), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nb, BAND_H, W), x.dtype),
        interpret=interpret,
    )(bands[:, :, None].reshape(B, nb, BAND_H + 2, W + 2))
    return out.reshape(B, H, W)


def dilate3x3_pallas(x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    return _morph_pallas(x, op="max", fill=0, interpret=interpret)


def erode3x3_pallas(x: jax.Array, maxval: int = 255,
                    interpret: Optional[bool] = None) -> jax.Array:
    return _morph_pallas(x, op="min", fill=maxval, interpret=interpret)
