"""Pallas TPU kernels: 3x3 dilation / erosion (paper Eqs. 5-6).

A 3x3 stencil needs a 1-pixel halo.  Pallas blocks cannot overlap, so the
staged launchers materialize overlapping row-bands (bh+2 rows each) with a
strided gather and the kernel reduces nine in-register shifted slices per
band — VREG shifts, no re-loads, exactly how a TPU stencil wants to run.

The halo/pad plumbing lives in exactly two shared helpers so the staged
kernels here and the fused pixel cascade (``kernels/pixel_cascade.py``)
run ONE implementation of the stencil math:

  * ``stencil3x3`` — the nine-shift in-register reduction over a row
    window, with the column halo filled in-kernel (no host-side W pad).
  * ``halo_bands`` — the host-side overlapping row-band gather, including
    the pad-H-to-band-multiple fill that every 3x3 launch needs.

``dilate3x3_pallas`` / ``erode3x3_pallas`` are thin op/fill bindings of
the one ``_morph_pallas`` launcher; they own their padding end to end
(callers pass raw (B, H, W) arrays — no pre-padding contract to re-derive
per call site).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.buckets import FRAME_BAND_H
from repro.kernels.runtime import resolve_interpret

#: output rows per band — shared with the fused cascade's tile table
BAND_H = FRAME_BAND_H

_OPS = {"max": jnp.maximum, "min": jnp.minimum}


def stencil3x3(win: jax.Array, *, op: str, fill: int,
               out_h: int, out_w: int) -> jax.Array:
    """Nine-shift 3x3 stencil reduce over a row window, in registers.

    ``win`` is an (out_h + 2, out_w) window that already carries the
    1-row halo above and below; the 1-column halo is filled here with
    ``fill`` (a concatenate, not a host pad), so callers never pad W.
    Returns the (out_h, out_w) reduced block.  Shared by the staged
    morphology kernels and the fused pixel cascade — one stencil
    implementation for every 3x3 in the repo.
    """
    red = _OPS[op]
    fc = jnp.full((win.shape[0], 1), fill, win.dtype)
    xp = jnp.concatenate([fc, win, fc], axis=1)       # (out_h+2, out_w+2)
    acc = None
    for dy in range(3):
        for dx in range(3):
            sl = xp[dy:dy + out_h, dx:dx + out_w]
            acc = sl if acc is None else red(acc, sl)
    return acc


def halo_bands(x: jax.Array, *, fill: int,
               band_h: int = BAND_H) -> Tuple[jax.Array, int]:
    """Overlapping (band_h + 2)-row bands of a (B, H, W) array.

    Pads H up to a band multiple and adds the 1-row stencil halo, both
    with ``fill`` (so out-of-image neighbours reduce to the identity of
    the stencil's op), then gathers the overlapping bands with strided
    dynamic slices.  Returns ((B, nb, band_h + 2, W), original H).
    """
    B, H, W = x.shape
    hp = -(-H // band_h) * band_h
    xp = jnp.pad(x, ((0, 0), (1, 1 + hp - H), (0, 0)), constant_values=fill)
    nb = hp // band_h
    bands = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(xp, i * band_h, band_h + 2, axis=1)
         for i in range(nb)], axis=1)
    return bands, H


def _morph_kernel(xb_ref, out_ref, *, op: str, fill: int):
    """xb_ref: (1, 1, bh+2, W) haloed band -> out_ref (1, 1, bh, W)."""
    bh, W = out_ref.shape[2], out_ref.shape[3]
    out_ref[0, 0] = stencil3x3(xb_ref[0, 0], op=op, fill=fill,
                               out_h=bh, out_w=W).astype(out_ref.dtype)


def _morph_pallas(x: jax.Array, *, op: str, fill: int,
                  interpret: Optional[bool] = None) -> jax.Array:
    """(B, H, W) int32 -> (B, H, W); 3x3 max/min with ``fill`` padding."""
    interpret = resolve_interpret(interpret)
    B, _, W = x.shape
    bands, H = halo_bands(x, fill=fill)
    nb = bands.shape[1]
    kernel = functools.partial(_morph_kernel, op=op, fill=fill)
    out = pl.pallas_call(
        kernel,
        grid=(B, nb),
        in_specs=[pl.BlockSpec((1, 1, BAND_H + 2, W),
                               lambda b, i: (b, i, 0, 0))],
        out_specs=pl.BlockSpec((1, 1, BAND_H, W), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nb, BAND_H, W), x.dtype),
        interpret=interpret,
    )(bands)
    return out.reshape(B, nb * BAND_H, W)[:, :H]


def dilate3x3_pallas(x: jax.Array, interpret: Optional[bool] = None) -> jax.Array:
    return _morph_pallas(x, op="max", fill=0, interpret=interpret)


def erode3x3_pallas(x: jax.Array, maxval: int = 255,
                    interpret: Optional[bool] = None) -> jax.Array:
    return _morph_pallas(x, op="min", fill=maxval, interpret=interpret)
