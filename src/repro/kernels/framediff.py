"""Pallas TPU kernel: 3-frame difference moving-object detection (Eqs. 1-4).

The paper's OpenCV per-pixel loop becomes a branch-free elementwise pipeline
on (bh, bw)-tiled VMEM blocks: abs-diff, bitwise conjunction, integer
grayscale, threshold.  Pure VPU work — lane-aligned tiles (last dim multiple
of 128, second-to-last multiple of 8).

Target: TPU (compiled); validated on CPU with interpret=True against
``ref.framediff_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.runtime import resolve_interpret

# (8, 128)-aligned VMEM tile; 3 channels live in the same block.
BLOCK_H = 32
BLOCK_W = 128


def _framediff_kernel(f0_ref, f1_ref, f2_ref, out_ref, *,
                      threshold: int, maxval: int):
    f0 = f0_ref[...]
    f1 = f1_ref[...]
    f2 = f2_ref[...]
    d1 = jnp.abs(f1 - f0)                    # Eq. 1
    d2 = jnp.abs(f2 - f1)                    # Eq. 2
    da = jnp.bitwise_and(d1, d2)             # Eq. 3 (uint8 semantics in i32)
    gray = (da[..., 0] * 299 + da[..., 1] * 587 + da[..., 2] * 114) // 1000
    out_ref[...] = jnp.where(gray > threshold, maxval, 0).astype(out_ref.dtype)


def framediff_pallas(f0: jax.Array, f1: jax.Array, f2: jax.Array, *,
                     threshold: int, maxval: int = 255,
                     interpret: Optional[bool] = None) -> jax.Array:
    """(B, H, W, 3) int32 frames -> (B, H, W) int32 binary mask.

    H must be a multiple of BLOCK_H and W of BLOCK_W (ops.py pads).
    """
    interpret = resolve_interpret(interpret)
    B, H, W, C = f0.shape
    assert C == 3 and H % BLOCK_H == 0 and W % BLOCK_W == 0, (f0.shape,)
    grid = (B, H // BLOCK_H, W // BLOCK_W)
    in_spec = pl.BlockSpec((1, BLOCK_H, BLOCK_W, 3),
                           lambda b, i, j: (b, i, j, 0))
    out_spec = pl.BlockSpec((1, BLOCK_H, BLOCK_W), lambda b, i, j: (b, i, j))
    kernel = functools.partial(_framediff_kernel, threshold=threshold,
                               maxval=maxval)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, W), f0.dtype),
        interpret=interpret,
    )(f0, f1, f2)
