"""Pallas TPU kernel: the fused pixel-cascade frontend (paper Eqs. 1-6).

One launch replaces the per-tick chain that used to cost three Pallas
programs plus two full-frame HBM round-trips:

    framediff (Eqs. 1-4) -> 3x3 dilate (Eq. 5) -> 3x3 erode (Eq. 6)
                         -> per-band foreground reduction

The kernel walks each camera's frame in (BAND_H, W) row bands with a
double-buffered software pipeline: grid step ``i`` frame-differences band
``i`` into a rolling three-slot VMEM scratch while the 3x3 stencil chain
and writeback run for band ``i - 1``, whose halo rows (the last two of
band ``i - 2``, the first two of band ``i``) are already resident.  The
framediff and dilated masks never leave VMEM/registers — only the input
frames stream in and the final eroded mask streams out, so a compiled
tick is bounded by frame bandwidth, not launch count or intermediate
traffic.  On TPU the grid's block DMAs double-buffer automatically on top
of the software pipeline; the one-band writeback delay is expressed with
revisited output blocks (steps ``i`` and ``i + 1`` map to the same output
band exactly once at the boundary, so copy-out happens after the real
write).

Band layout per grid step ``(b, i)`` of the ``(B, nb + 1)`` grid::

      fd scratch (3, BAND_H, W)            output band i-1
      ┌────────────┐                       ┌──────────────┐
      │ band i-2   │─ last 2 rows ─┐       │              │
      ├────────────┤               ▼       │   erode ∘    │
      │ band i-1   │──────────▶ (BAND_H+4, │   dilate     │
      ├────────────┤               ▲  W)   │   window     │
      │ band i     │─ first 2 rows ┘       │              │
      └────────────┘ ◀─ framediff(band i)  └──────────────┘

The second output is the per-band foreground count — the mask reduction
the host needs to skip connected-component labeling for motionless
cameras (and the whole CCL fixpoint for motionless ticks) without paying
another device pass over the mask.

Boundary semantics match the staged chain bit-exactly: framediff outside
the true (H, W) image is 0 (dilate's fill), dilated values outside it are
``maxval`` (erode's fill), and the final mask is zeroed outside the true
image so the pad region can never contribute to a count.  The stencil
math itself is ``morphology.stencil3x3`` — the same nine-shift reduction
the staged kernels run, one implementation for both paths.

Target: TPU (compiled); validated on CPU with interpret=True against the
staged kernels and the independent NumPy oracle ``ref.pixel_cascade_np``.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.buckets import FRAME_BAND_H, FRAME_LANE_W, frame_pad
from repro.kernels.morphology import stencil3x3
from repro.kernels.runtime import resolve_interpret

BAND_H = FRAME_BAND_H


def _framediff_band(f0, f1, f2, *, threshold: int, maxval: int) -> jax.Array:
    """Eqs. 1-4 on one (bh, W, 3) frame band -> (bh, W) binary mask."""
    d1 = jnp.abs(f1 - f0)                        # Eq. 1
    d2 = jnp.abs(f2 - f1)                        # Eq. 2
    da = jnp.bitwise_and(d1, d2)                 # Eq. 3 (uint8 bits in i32)
    gray = (da[..., 0] * 299 + da[..., 1] * 587 + da[..., 2] * 114) // 1000
    return jnp.where(gray > threshold, maxval, 0).astype(jnp.int32)


def _cascade_kernel(f0_ref, f1_ref, f2_ref, mask_ref, count_ref, fd, *,
                    nb: int, true_h: int, true_w: int,
                    threshold: int, maxval: int):
    """One grid step of the band pipeline (see module docstring)."""
    i = pl.program_id(1)
    bh, Wp = mask_ref.shape[1], mask_ref.shape[2]

    # stage 1 — framediff band i into its rolling scratch slot.  Skipped on
    # the flush step (i == nb), which only drains the pipeline.
    @pl.when(i < nb)
    def _():
        fd[jax.lax.rem(i, 3)] = _framediff_band(
            f0_ref[0], f1_ref[0], f2_ref[0],
            threshold=threshold, maxval=maxval)

    # stage 2 — dilate + erode + reduce band i-1, whose halo is resident:
    # rows above come from band i-2's slot, rows below from the slot stage 1
    # just wrote.  Out-of-image halos reduce to each stencil's fill.
    @pl.when(i >= 1)
    def _():
        cur = fd[jax.lax.rem(i + 2, 3)]                  # band i-1
        above = fd[jax.lax.rem(i + 1, 3)][bh - 2:, :]    # band i-2, last 2
        below = fd[jax.lax.rem(i, 3)][:2, :]             # band i,   first 2
        above = jnp.where(i >= 2, above, 0)              # no band above 0
        below = jnp.where(i <= nb - 1, below, 0)         # flush: none below
        win = jnp.concatenate([above, cur, below], axis=0)   # (bh+4, Wp)

        # Eq. 5: 3x3 max, fill 0 — framediff is already 0 outside (H, W)
        dil = stencil3x3(win, op="max", fill=0, out_h=bh + 2, out_w=Wp)

        # Eq. 6: 3x3 min, fill maxval — mask the pad region to maxval so
        # the erode boundary matches the staged chain's fill bit-exactly
        g0 = (i - 1) * bh - 1                    # global row of dil row 0
        rows = g0 + jax.lax.broadcasted_iota(jnp.int32, (bh + 2, Wp), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (bh + 2, Wp), 1)
        dil = jnp.where((rows >= 0) & (rows < true_h) & (cols < true_w),
                        dil, maxval)
        ero = stencil3x3(dil, op="min", fill=maxval, out_h=bh, out_w=Wp)

        # zero the pad region so counts see only true pixels, then reduce
        orows = (i - 1) * bh + jax.lax.broadcasted_iota(
            jnp.int32, (bh, Wp), 0)
        ocols = jax.lax.broadcasted_iota(jnp.int32, (bh, Wp), 1)
        out = jnp.where((orows < true_h) & (ocols < true_w), ero, 0)
        mask_ref[0] = out.astype(mask_ref.dtype)
        count_ref[0, 0] = jnp.sum((out > 0).astype(jnp.int32))


def pixel_cascade_pallas(f0: jax.Array, f1: jax.Array, f2: jax.Array, *,
                         threshold: int, maxval: int = 255,
                         interpret: Optional[bool] = None
                         ) -> Tuple[jax.Array, jax.Array]:
    """(B, H', W', 3) int32 frame triple -> ((B, H', W') mask, (B, nb) counts).

    H' must be a multiple of BAND_H and W' of FRAME_LANE_W (ops.py pads
    with zeros and passes the true (H, W) through ``true_hw``); the mask
    is zero outside the true image and the per-band counts cover true
    pixels only.
    """
    return _cascade_call(f0, f1, f2, threshold=threshold, maxval=maxval,
                         true_hw=(f0.shape[1], f0.shape[2]),
                         interpret=interpret)


def _cascade_call(f0, f1, f2, *, threshold, maxval, true_hw,
                  interpret=None):
    interpret = resolve_interpret(interpret)
    B, Hp, Wp, C = f0.shape
    true_h, true_w = true_hw
    assert C == 3 and Hp % BAND_H == 0 and Wp % FRAME_LANE_W == 0, (f0.shape,)
    nb = Hp // BAND_H
    kernel = functools.partial(_cascade_kernel, nb=nb, true_h=true_h,
                               true_w=true_w, threshold=threshold,
                               maxval=maxval)
    in_spec = pl.BlockSpec((1, BAND_H, Wp, 3),
                           lambda b, i: (b, jnp.minimum(i, nb - 1), 0, 0))
    mask, counts = pl.pallas_call(
        kernel,
        grid=(B, nb + 1),
        in_specs=[in_spec, in_spec, in_spec],
        out_specs=[
            pl.BlockSpec((1, BAND_H, Wp),
                         lambda b, i: (b, jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, jnp.maximum(i - 1, 0))),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, Hp, Wp), f0.dtype),
            jax.ShapeDtypeStruct((B, nb), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((3, BAND_H, Wp), jnp.int32)],
        interpret=interpret,
    )(f0, f1, f2)
    return mask, counts


def pad_frames(x: jax.Array) -> jax.Array:
    """Zero-pad (B, H, W, 3) frames to the cascade's (BAND_H, LANE_W) tile.

    Zero is the correct frame fill: framediff of identical zeros is 0,
    which is exactly dilate's out-of-image fill — the kernel handles the
    erode fill itself via the true (H, W) mask.
    """
    B, H, W, _ = x.shape
    hp, wp = frame_pad(H, W)
    if hp == H and wp == W:
        return x
    return jnp.pad(x, ((0, 0), (0, hp - H), (0, wp - W), (0, 0)))
