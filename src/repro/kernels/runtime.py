"""Single interpret-mode switch for every Pallas launch in the repo.

Every kernel module used to hardcode ``interpret: bool = True`` in its
launcher signature, which meant a TPU run had to touch each call site to
compile anything.  Instead, launchers now default to ``interpret=None``
and resolve the effective mode here: the ``REPRO_PALLAS_INTERPRET`` env
knob (default ON — this container is CPU-only and CI runs the kernels in
interpret mode) flips every launch in the repo to compiled in one place:

    REPRO_PALLAS_INTERPRET=0 python -m pytest ...      # TPU: compile all

Passing an explicit ``interpret=`` to any launcher still wins — tests that
pin a mode stay pinned.  The env var is read per resolution call, so it
must be set before the first trace of a given shape (jit caches bake the
mode into the compiled artifact; flipping mid-process only affects
not-yet-traced shapes).
"""
from __future__ import annotations

import functools
import os
from typing import Optional

ENV_KNOB = "REPRO_PALLAS_INTERPRET"


def interpret_default() -> bool:
    """The repo-wide interpret mode: ON unless ``REPRO_PALLAS_INTERPRET=0``."""
    return os.environ.get(ENV_KNOB, "1") != "0"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    """An explicit per-call ``interpret=`` wins; ``None`` means the knob."""
    return interpret_default() if interpret is None else bool(interpret)


@functools.lru_cache(maxsize=1)
def compiled_available() -> bool:
    """Whether this backend can lower a Pallas kernel with interpret=False.

    Probed once per process with a tiny single-block copy kernel.  On the
    CPU backend of current jax this raises ``Only interpret mode is
    supported on CPU backend`` — the compiled-mode tests and BENCH rows
    use this probe to skip (tests) or record their actual substrate
    (benchmarks) instead of misrepresenting interpreted numbers as
    compiled ones.  On a TPU runtime it returns True and
    ``REPRO_PALLAS_INTERPRET=0`` exercises the real compiled path.
    """
    import jax
    import jax.numpy as jnp
    from jax.experimental import pallas as pl

    def _copy(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    try:
        x = jnp.zeros((8, 128), jnp.float32)
        pl.pallas_call(
            _copy, out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
            interpret=False)(x)
        return True
    except Exception:
        return False
