"""Jit'd public wrappers for the Pallas kernels (padding, dtype, dispatch).

Interpret mode is a single repo-wide switch (``repro.kernels.runtime``,
env knob ``REPRO_PALLAS_INTERPRET``): it defaults ON because this
container is CPU-only; on a real TPU runtime ``REPRO_PALLAS_INTERPRET=0``
flips every launch in the repo to compiled — no per-kernel defaults to
chase.  The wrappers here never pass ``interpret`` explicitly; each
launcher resolves the knob itself.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import buckets as _bk
from repro.kernels import calibrate as _ca
from repro.kernels import flash_attention as _fa
from repro.kernels import framediff as _fd
from repro.kernels import morphology as _mo
from repro.kernels import pixel_cascade as _pc
from repro.kernels import similarity as _sim
from repro.kernels import triage as _tr
from repro.kernels import ref as _ref
from repro.kernels.runtime import interpret_default  # noqa: F401  (re-export)


def _pad_hw(x: jax.Array, mh: int, mw: int, value=0) -> Tuple[jax.Array, int, int]:
    H, W = x.shape[1], x.shape[2]
    ph = (-H) % mh
    pw = (-W) % mw
    if ph or pw:
        pad = [(0, 0), (0, ph), (0, pw)] + [(0, 0)] * (x.ndim - 3)
        x = jnp.pad(x, pad, constant_values=value)
    return x, H, W


@functools.partial(jax.jit, static_argnames=("threshold", "maxval", "use_pallas"))
def framediff(f0: jax.Array, f1: jax.Array, f2: jax.Array, *,
              threshold: int = 40, maxval: int = 255,
              use_pallas: bool = True) -> jax.Array:
    """Binary motion mask from 3 consecutive frames (B,H,W,3) uint8/int."""
    f0, f1, f2 = (x.astype(jnp.int32) for x in (f0, f1, f2))
    if not use_pallas:
        return _ref.framediff_ref(f0, f1, f2, threshold, maxval)
    f0p, H, W = _pad_hw(f0, _fd.BLOCK_H, _fd.BLOCK_W)
    f1p, _, _ = _pad_hw(f1, _fd.BLOCK_H, _fd.BLOCK_W)
    f2p, _, _ = _pad_hw(f2, _fd.BLOCK_H, _fd.BLOCK_W)
    out = _fd.framediff_pallas(f0p, f1p, f2p, threshold=threshold,
                               maxval=maxval)
    return out[:, :H, :W]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def dilate3x3(x: jax.Array, use_pallas: bool = True) -> jax.Array:
    x = x.astype(jnp.int32)
    if not use_pallas:
        return _ref.dilate3x3_ref(x)
    return _mo.dilate3x3_pallas(x)


@functools.partial(jax.jit, static_argnames=("maxval", "use_pallas"))
def erode3x3(x: jax.Array, maxval: int = 255, use_pallas: bool = True) -> jax.Array:
    x = x.astype(jnp.int32)
    if not use_pallas:
        return _ref.erode3x3_ref(x, maxval)
    return _mo.erode3x3_pallas(x, maxval=maxval)


@functools.partial(jax.jit,
                   static_argnames=("threshold", "maxval", "use_pallas",
                                    "fused"))
def pixel_cascade(f0: jax.Array, f1: jax.Array, f2: jax.Array, *,
                  threshold: int = 40, maxval: int = 255,
                  use_pallas: bool = True, fused: bool = True):
    """Whole pixel frontend — framediff → dilate → erode → count — in ONE
    Pallas launch per tick.

    Frames are (B, H, W, 3) uint8/int; returns ``(mask (B, H, W) int32,
    counts (B,) int32)`` where ``counts[b]`` is camera b's foreground pixel
    count — the reduction ``detect`` uses to skip connected-component
    labeling for motionless cameras without a second pass over the mask.

    ``fused=False`` (or ``use_pallas=False``) runs the staged chain — the
    original three separate launches (or the jnp reference twin) plus a
    mask reduction — retained as the differential reference the fused
    kernel is tested bit-exact against.  Frames are zero-padded to the
    (FRAME_BAND_H, FRAME_LANE_W) tile from ``kernels/buckets.py`` before
    the fused launch; the pad is sliced back off and never reaches counts.
    """
    f0, f1, f2 = (x.astype(jnp.int32) for x in (f0, f1, f2))
    if use_pallas and fused:
        H, W = f0.shape[1], f0.shape[2]
        f0p, f1p, f2p = (_pc.pad_frames(x) for x in (f0, f1, f2))
        mask, band_counts = _pc._cascade_call(
            f0p, f1p, f2p, threshold=threshold, maxval=maxval,
            true_hw=(H, W))
        return mask[:, :H, :W], band_counts.sum(axis=1)
    if not use_pallas:
        mask = _ref.pixel_cascade_ref(f0, f1, f2, threshold, maxval)
    else:
        mask = erode3x3(dilate3x3(framediff(
            f0, f1, f2, threshold=threshold, maxval=maxval)), maxval=maxval)
    return mask, jnp.sum(mask > 0, axis=(1, 2)).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("causal", "block_q", "block_k",
                                    "use_pallas"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, use_pallas: bool = True) -> jax.Array:
    """Fused attention.  q (B,H,Sq,hd), k/v (B,KV,Sk,hd) -> (B,H,Sq,hd).

    Pads Sq/Sk up to block multiples; padded K positions are masked by the
    causal rule (padded keys sit after all queries) or, for non-causal
    inputs, by padding K with -inf-free zeros and masking via length.
    """
    if not use_pallas:
        return _ref.mha_ref(q, k, v, causal)
    B, H, Sq, hd = q.shape
    Sk = k.shape[2]
    pq = (-Sq) % block_q
    pk = (-Sk) % block_k
    if pk and (not causal or Sq > Sk):
        # padded keys would be visible to real queries; fall back
        return _ref.mha_ref(q, k, v, causal)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))) if pk else v
    out = _fa.flash_attention_pallas(qp, kp, vp, causal=causal,
                                     block_q=min(block_q, qp.shape[2]),
                                     block_k=min(block_k, kp.shape[2]))
    return out[:, :, :Sq]


@functools.partial(jax.jit,
                   static_argnames=("alpha", "beta", "capacity", "use_pallas"))
def triage(conf: jax.Array, *, alpha: float, beta: float, capacity: int,
           use_pallas: bool = True):
    """(N,) confidences -> (routes, slots, count)."""
    conf = conf.astype(jnp.float32)
    if not use_pallas:
        return _ref.triage_ref(conf, alpha, beta, capacity)
    routes, slots, count = _tr.triage_pallas(
        conf, alpha=alpha, beta=beta, capacity=capacity)
    return routes, slots, count[0]


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def _triage_dynamic(conf: jax.Array, thresholds: jax.Array, *, capacity: int,
                    use_pallas: bool = True):
    if not use_pallas:
        return _ref.triage_ref(conf, thresholds[0], thresholds[1], capacity)
    routes, slots, count = _tr.triage_dynamic_pallas(
        conf, thresholds, capacity=capacity)
    return routes, slots, count[0]


def triage_batched(conf: jax.Array, *, alpha: float, beta: float,
                   capacity: int, use_pallas: bool = True):
    """Per-tick batched triage with *runtime* thresholds.

    Pads N up to a power-of-two bucket (min 8) before the single kernel
    launch, then slices the pad back off, so a stream of tick batches of
    varying size hits a handful of cached compilations — and the adaptive
    alpha/beta (which change on every Eqs. 8-9 update) are data, not trace
    constants.  Pad lanes use conf=-1.0, which always routes to 'reject'
    (beta >= 0) and therefore can never claim an escalation slot or count.
    """
    conf = jnp.asarray(conf, jnp.float32)
    (n,) = conf.shape
    bucket = _bucket(n)
    if bucket != n:
        conf = jnp.pad(conf, (0, bucket - n), constant_values=-1.0)
    thresholds = jnp.asarray([alpha, beta], jnp.float32)
    routes, slots, count = _triage_dynamic(
        conf, thresholds, capacity=capacity, use_pallas=use_pallas)
    return routes[:n], slots[:n], count


# padding-bucket arithmetic lives in ``kernels/buckets.py`` (jax-free, so
# the scenario layer can validate fleet dims against the same table);
# these aliases keep the wrappers' call sites and the historical names
_bucket = _bk.bucket


def score_crops(score_fn, tokens: jax.Array, *, minimum: int = 8) -> jax.Array:
    """Bucket-padded per-tick crop scoring: ONE classifier launch per tick.

    ``tokens`` is the (N, T) patch-token matrix of every motion crop the
    whole camera fleet produced this scheduler tick and ``score_fn`` a jit'd
    ``(N, T) tokens -> (N,) confidences`` model apply.  N is padded up to a
    power-of-two bucket (min 8) before the single call — the same padding
    contract as ``triage_fleet``, so a run's stream of varying tick batches
    hits a handful of cached compilations — then the pad is sliced back
    off.  Pad rows carry token 0; their scores never leave this function.
    """
    tokens = jnp.asarray(tokens, jnp.int32)
    n = tokens.shape[0]
    bucket = _bucket(n, minimum)
    if bucket != n:
        tokens = jnp.pad(tokens, ((0, bucket - n), (0, 0)))
    return score_fn(tokens)[:n]


@functools.partial(jax.jit, static_argnames=("capacity", "use_pallas"))
def _triage_fleet(conf: jax.Array, thresholds: jax.Array, *, capacity: int,
                  use_pallas: bool = True):
    if not use_pallas:
        return _ref.triage_fleet_ref(conf, thresholds, capacity)
    return _tr.triage_fleet_pallas(conf, thresholds, capacity=capacity)


_bucket_q = _bk.bucket_q


def triage_fleet(conf: jax.Array, thresholds: jax.Array, *, capacity: int,
                 use_pallas: bool = True):
    """Whole-fleet per-tick triage: ONE kernel launch for every edge —
    and, with a query axis, for every live query on every edge.

    2D: ``conf`` is the (E, N) tick matrix — row e holds edge e's
    detections this scheduler tick, right-padded with -1.0 where edges saw
    fewer than N — and ``thresholds`` the (E, 2) per-edge runtime
    [alpha, beta] from each edge's own Eqs. 8-9 state.  Returns (routes
    (E, N), slots (E, N), counts (E,)); compaction and the ``capacity``
    clamp are per edge row.

    3D: ``conf`` (Q, E, N) with ``thresholds`` (Q, E, 2) — one row per
    (live query, edge) pair, each with its OWN Eqs. 8-9 threshold state
    and its own escalation buffer.  The query axis is bucket-padded to a
    power of two (pad rows: conf=-1.0, thresholds (1, 0) — inert exactly
    like pad edge rows), then Q·E-row-folded onto the 2D layout, so ALL
    live queries across ALL edges still cost ONE launch per scheduler
    tick; outputs come back (Q, E, N)/(Q, E).  Per-row compaction is
    unchanged by the fold — each (query, edge) keeps a private buffer.

    Both trailing axes are padded up to power-of-two buckets (min 8)
    before the launch so a run's stream of tick matrices hits a handful of
    cached compilations, then the pads are sliced back off.  Pad lanes use
    conf=-1.0, which always routes to 'reject' (beta >= 0) and therefore
    can never claim an escalation slot or count; pad edge rows get
    thresholds (1, 0) for the same reason.
    """
    conf = jnp.asarray(conf, jnp.float32)
    thresholds = jnp.asarray(thresholds, jnp.float32)
    if conf.ndim == 3:
        Q, E, n = conf.shape
        qb = _bucket_q(Q)
        if qb != Q:
            conf = jnp.pad(conf, ((0, qb - Q), (0, 0), (0, 0)),
                           constant_values=-1.0)
            thresholds = jnp.concatenate(
                [thresholds,
                 jnp.tile(jnp.asarray([[[1.0, 0.0]]], jnp.float32),
                          (qb - Q, E, 1))])
        routes, slots, counts = triage_fleet(
            conf.reshape(qb * E, n), thresholds.reshape(qb * E, 2),
            capacity=capacity, use_pallas=use_pallas)
        return (jnp.reshape(routes, (qb, E, n))[:Q],
                jnp.reshape(slots, (qb, E, n))[:Q],
                jnp.reshape(counts, (qb, E))[:Q])
    E, n = conf.shape
    eb, nb = _bucket(E), _bucket(n)
    if nb != n:
        conf = jnp.pad(conf, ((0, 0), (0, nb - n)), constant_values=-1.0)
    if eb != E:
        conf = jnp.pad(conf, ((0, eb - E), (0, 0)), constant_values=-1.0)
        thresholds = jnp.concatenate(
            [thresholds,
             jnp.tile(jnp.asarray([[1.0, 0.0]], jnp.float32), (eb - E, 1))])
    routes, slots, counts = _triage_fleet(
        conf, thresholds, capacity=capacity, use_pallas=use_pallas)
    return routes[:E, :n], slots[:E, :n], counts[:E]


@functools.partial(jax.jit, static_argnames=("iters", "min_count"))
def _calibrate_fleet_pallas(scores: jax.Array, truths: jax.Array, *,
                            iters: int, min_count: int):
    return _ca.calibrate_fleet_pallas(scores, truths, iters=iters,
                                      min_count=min_count)


def calibrate_fleet(scores, truths, *, iters: int = 8, min_count: int = 8,
                    use_pallas: bool = True):
    """Fleet-wide Platt recalibration: ONE fused launch per update event.

    ``scores`` is the (E, N) matrix of cloud-labeled edge confidences —
    row e holds edge e's buffered escalation scores, right-padded with
    -1.0 — and ``truths`` the matching (E, N) 0/1 cloud verdicts.  Returns
    (params (E, 2) [a, b] of ``conf' = sigmoid(a*logit(conf)+b)``, counts
    (E,) valid labels per edge).  Rows with fewer than ``min_count``
    labels, or labels all one class, come back as the identity (1, 0).

    3D: ``scores``/``truths`` (Q, E, N) — one row per (live query, edge)
    pair, query-axis bucket-padded then Q·E-row-folded onto the 2D layout
    (pad rows fully masked, fit to the identity), so a multi-query fleet's
    whole recalibration is still ONE launch per update event; ``params``
    comes back (Q, E, 2) and ``counts`` (Q, E).

    Both trailing axes are padded up to power-of-two buckets (min 8)
    before the launch — the same jit-cache contract as ``triage_fleet`` —
    then the pads are sliced back off.  Pad lanes use score=-1.0 and are
    masked out of every reduction; pad edge rows are fully masked and
    therefore fit to the identity.  The ``use_pallas=False`` path
    dispatches to the independent NumPy oracle
    (``ref.calibrate_fleet_ref``) outside jit.
    """
    scores = jnp.asarray(scores, jnp.float32)
    truths = jnp.asarray(truths, jnp.float32)
    if scores.ndim == 3:
        Q, E, n = scores.shape
        qb = _bucket_q(Q)
        if qb != Q:
            scores = jnp.pad(scores, ((0, qb - Q), (0, 0), (0, 0)),
                             constant_values=-1.0)
            truths = jnp.pad(truths, ((0, qb - Q), (0, 0), (0, 0)))
        params, counts = calibrate_fleet(
            scores.reshape(qb * E, n), truths.reshape(qb * E, n),
            iters=iters, min_count=min_count, use_pallas=use_pallas)
        return (jnp.reshape(jnp.asarray(params), (qb, E, 2))[:Q],
                jnp.reshape(jnp.asarray(counts), (qb, E))[:Q])
    E, n = scores.shape
    eb, nb = _bucket(E), _bucket(n)
    if nb != n:
        scores = jnp.pad(scores, ((0, 0), (0, nb - n)), constant_values=-1.0)
        truths = jnp.pad(truths, ((0, 0), (0, nb - n)))
    if eb != E:
        scores = jnp.pad(scores, ((0, eb - E), (0, 0)), constant_values=-1.0)
        truths = jnp.pad(truths, ((0, eb - E), (0, 0)))
    if not use_pallas:
        params, counts = _ref.calibrate_fleet_ref(
            np.asarray(scores), np.asarray(truths), iters, min_count)
    else:
        params, counts = _calibrate_fleet_pallas(
            scores, truths, iters=iters, min_count=min_count)
    return params[:E], counts[:E]


@jax.jit
def _associate_pallas(emb, trk, crop_q, trk_q, thr):
    return _sim.associate_pallas(emb, trk, crop_q, trk_q, thr)


def associate_tracks(emb, trk, crop_q, trk_q, thr, *,
                     use_pallas: bool = True):
    """Fleet-wide re-ID association: ONE fused launch per scheduler tick.

    ``emb`` is the (M, D) matrix of every detection-crop embedding the
    whole fleet produced this tick (L2-normalize upstream — scores are
    cosines) and ``trk`` the (K, D) live track table across ALL track
    queries; ``crop_q`` (M,) / ``trk_q`` (K,) carry each row's query id
    (crops only ever match tracks of their own query, which is what lets
    every live track query share the single launch) and ``thr`` (M,) the
    per-crop acceptance floor — warm/cold edge state reaches the kernel as
    data, not trace constants, same contract as ``triage_fleet``'s runtime
    thresholds.  Crops claim tracks greedily in row order, one-to-one.

    Returns (assign (M,) int32 — the matched row index into the UNPADDED
    ``trk``, or -1 — and sim (M,) float32, the best still-unclaimed score
    the crop saw, -1e30 when its query had none).

    M, K, and D are padded up to power-of-two buckets (min 8) before the
    launch — the ``triage_fleet`` jit-cache contract — then the pads are
    sliced back off.  Pad crops carry query id -1 and pad tracks -2, so a
    pad row can never match or be claimed (real ids are >= 0); pad crops
    are appended AFTER the real rows, so the greedy claim order of real
    crops is unchanged by padding.  ``use_pallas=False`` dispatches to the
    independent NumPy oracle (``ref.associate_tracks_ref``) outside jit.
    """
    emb = jnp.asarray(emb, jnp.float32)
    trk = jnp.asarray(trk, jnp.float32)
    crop_q = jnp.asarray(crop_q, jnp.int32)
    trk_q = jnp.asarray(trk_q, jnp.int32)
    thr = jnp.asarray(thr, jnp.float32)
    M, D = emb.shape
    K = trk.shape[0]
    mb, kb, db = _bucket(M), _bucket(K), _bucket(D)
    if db != D:
        emb = jnp.pad(emb, ((0, 0), (0, db - D)))
        trk = jnp.pad(trk, ((0, 0), (0, db - D)))
    if mb != M:
        emb = jnp.pad(emb, ((0, mb - M), (0, 0)))
        crop_q = jnp.pad(crop_q, (0, mb - M), constant_values=-1)
        thr = jnp.pad(thr, (0, mb - M), constant_values=2.0)
    if kb != K:
        trk = jnp.pad(trk, ((0, kb - K), (0, 0)))
        trk_q = jnp.pad(trk_q, (0, kb - K), constant_values=-2)
    if not use_pallas:
        assign, sim = _ref.associate_tracks_ref(
            np.asarray(emb), np.asarray(trk), np.asarray(crop_q),
            np.asarray(trk_q), np.asarray(thr))
        return jnp.asarray(assign)[:M], jnp.asarray(sim)[:M]
    assign, sim = _associate_pallas(emb, trk, crop_q, trk_q, thr)
    return assign[:M], sim[:M]
