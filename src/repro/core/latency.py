"""Latency estimation (paper §IV-D.3).

Two estimators, exactly as the paper uses them:

1. ``Lognormal3``: three-parameter lognormal MLE (Eqs. 10-16).  gamma (the
   physical minimum latency) is found by solving Eq. 16 iteratively
   (bisection on the monotone score function); mu/sigma^2 follow in closed
   form (Eqs. 14-15).  Long-period predictor; prediction is a weighted mean
   of E[X] = gamma + exp(mu + sigma^2/2) and Median[X] = gamma + exp(mu),
   which the paper uses to damp outlier-driven swings.

2. ``adaptive_mean``: the self-adaptive weighted mean of Eq. 17 — the
   real-time estimator whose weights automatically de-emphasize outliers:

     t = (t_old^2 + t_new^2)/(t_old+t_new)^2 * t_old
       + 2*t_old*t_new /(t_old+t_new)^2 * t_new
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import numpy as np


def adaptive_mean(t_old: float, t_new: float) -> float:
    """Eq. 17: outlier-damping weighted mean (weights sum to 1)."""
    s = t_old + t_new
    if s <= 0:
        return max(t_old, t_new, 0.0)
    w_old = (t_old * t_old + t_new * t_new) / (s * s)
    w_new = 2.0 * t_old * t_new / (s * s)
    return w_old * t_old + w_new * t_new


def _score_gamma(x: np.ndarray, g: float) -> float:
    """LHS of Eq. 16 (=0 at the MLE gamma)."""
    d = x - g
    ln = np.log(d)
    n = len(x)
    s1 = np.sum(1.0 / d)
    s2 = np.sum(ln)
    s3 = np.sum(ln * ln)
    s4 = np.sum(ln / d)
    return s1 * (s2 - s3 + s2 * s2 / n) - n * s4


def fit_lognormal3(x: Sequence[float],
                   iters: int = 80) -> Tuple[float, float, float]:
    """MLE (gamma, mu, sigma^2) of the 3-parameter lognormal (Eqs. 10-16).

    Solves Eq. 16 for gamma by bisection on (eps, min(x)), then Eqs. 14-15.
    Falls back to gamma=0 (plain lognormal) if no sign change is bracketed.
    """
    xa = np.asarray(list(x), dtype=np.float64)
    if len(xa) < 3 or np.any(xa <= 0):
        raise ValueError("need >=3 positive samples")
    xmin = float(np.min(xa))
    lo, hi = 1e-12, xmin * (1.0 - 1e-9)
    flo, fhi = _score_gamma(xa, lo), _score_gamma(xa, hi)
    if flo * fhi > 0:
        gamma = 0.0
    else:
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            fm = _score_gamma(xa, mid)
            if flo * fm <= 0:
                hi, fhi = mid, fm
            else:
                lo, flo = mid, fm
        gamma = 0.5 * (lo + hi)
    ln = np.log(xa - gamma)
    mu = float(np.mean(ln))                       # Eq. 14
    sigma2 = float(np.mean((ln - mu) ** 2))       # Eq. 15
    return gamma, mu, sigma2


@dataclasses.dataclass
class LatencyEstimator:
    """Combined estimator: Eq. 17 online + lognormal refits every ``refit_every``.

    ``predict()`` = blend of the real-time adaptive mean and the lognormal
    (mean(E[X], Median[X])) long-period prediction, as in the paper.
    """
    t: float = 0.1                     # current real-time estimate (seconds)
    history_max: int = 256
    refit_every: int = 64
    blend: float = 0.5                 # weight of lognormal long-period term
    _history: list = dataclasses.field(default_factory=list)
    _since_fit: int = 0
    _lognormal: Optional[Tuple[float, float, float]] = None

    def observe(self, t_new: float) -> float:
        self.t = adaptive_mean(self.t, t_new)
        self._history.append(float(t_new))
        if len(self._history) > self.history_max:
            self._history = self._history[-self.history_max:]
        self._since_fit += 1
        if self._since_fit >= self.refit_every and len(self._history) >= 8:
            try:
                self._lognormal = fit_lognormal3(self._history)
            except (ValueError, FloatingPointError):
                self._lognormal = None
            self._since_fit = 0
        return self.t

    def predict(self) -> float:
        if self._lognormal is None:
            return self.t
        g, mu, s2 = self._lognormal
        mean = g + np.exp(mu + s2 / 2.0)
        median = g + np.exp(mu)
        longterm = 0.5 * (mean + median)   # paper: damped long-period value
        return (1 - self.blend) * self.t + self.blend * float(longterm)
