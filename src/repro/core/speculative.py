"""Cascade speculative decoding (beyond-paper extension of C1).

SurveilEdge's cascade routes *images* by edge-model confidence.  The same
economics apply per *token* when serving an LLM: a cheap CQ-style draft
model proposes ``k`` tokens; the big model verifies them in ONE batched
forward (prefill over the draft) and accepts the longest agreeing prefix —
the token-level analogue of "escalate only the uncertain".

Greedy-match acceptance keeps the output *identical* to cloud-greedy
decoding (tested), so unlike the image cascade there is no accuracy trade —
only latency/bandwidth: per accepted draft token the big model runs 1/k of
a decode step, and only mismatching positions pay a cloud-only step.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class SpecStats:
    proposed: int = 0
    accepted: int = 0
    cloud_steps: int = 0

    @property
    def acceptance_rate(self) -> float:
        return self.accepted / max(self.proposed, 1)

    @property
    def tokens_per_cloud_step(self) -> float:
        return (self.accepted + self.cloud_steps) / max(self.cloud_steps, 1)


def greedy(logits: jax.Array) -> jax.Array:
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def draft_tokens(cfg: ModelConfig, params, cache, last_token: jax.Array,
                 k: int, window: Optional[int] = None
                 ) -> Tuple[jax.Array, Any]:
    """Draft k tokens greedily with the edge model.  Returns ((B,k), cache)."""
    toks = []
    tok = last_token
    for _ in range(k):
        logits, cache = T.decode_step(cfg, params, cache, tok, window=window)
        tok = greedy(logits)
        toks.append(tok)
    return jnp.stack(toks, axis=1), cache


def verify_prefix(cloud_logits: jax.Array, draft: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    """cloud_logits: (B, k, V) — the big model's logits at each draft
    position (position i conditioned on draft[:, :i]).  Returns
    (n_accepted (B,), next_token (B,)) where next_token is the big model's
    token at the first mismatch (or the k-th continuation if all match)."""
    cloud_tok = greedy(cloud_logits)                     # (B, k)
    # accepted = longest prefix where the big model's greedy token at each
    # draft position equals the draft token
    eq = (cloud_tok == draft).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(eq, axis=1), axis=1)     # (B,)
    idx = jnp.minimum(n_acc, draft.shape[1] - 1)
    next_tok = jnp.take_along_axis(cloud_tok, idx[:, None], axis=1)[:, 0]
    return n_acc, next_tok


def speculative_generate(edge_cfg: ModelConfig, edge_params,
                         cloud_cfg: ModelConfig, cloud_params,
                         prompt: jax.Array, *, steps: int, k: int = 4,
                         cache_len: Optional[int] = None
                         ) -> Tuple[jax.Array, SpecStats]:
    """Generate ``steps`` tokens for a (B, S) prompt batch.

    B must be 1 for the simple host-side control flow here (the serving
    engine batches at a higher level).  Output == cloud-greedy (verified by
    tests).
    """
    B, S = prompt.shape
    assert B == 1, "host-side speculative loop is per-sequence"
    total = S + steps + k + 2
    cache_len = max(cache_len or 0, total)
    stats = SpecStats()

    e_logits, e_cache = T.prefill(edge_cfg, edge_params, prompt,
                                  cache_len=cache_len)
    c_logits, c_cache = T.prefill(cloud_cfg, cloud_params, prompt,
                                  cache_len=cache_len)
    out = [greedy(c_logits)]                             # first cloud token
    # edge follows the accepted stream: feed it the first token too
    cur = out[0]

    while len(out) < steps + 1:
        kk = min(k, steps + 1 - len(out))
        draft, e_cache_draft = draft_tokens(edge_cfg, edge_params, e_cache,
                                            cur, kk)
        # verify: ONE cloud forward over [cur, draft[:-1]] positions
        seq = jnp.concatenate([cur[:, None], draft[:, :-1]], axis=1)
        c_logits_k = []
        c_cache_v = c_cache
        for i in range(kk):                 # cloud decodes the draft batch
            lg, c_cache_v = T.decode_step(cloud_cfg, cloud_params, c_cache_v,
                                          seq[:, i])
            c_logits_k.append(lg)
        cloud_logits = jnp.stack(c_logits_k, axis=1)     # (B, kk, V)
        n_acc, next_tok = verify_prefix(cloud_logits, draft)
        n = int(n_acc[0])
        stats.proposed += kk
        stats.accepted += n
        stats.cloud_steps += 1
        accepted = [draft[:, i] for i in range(n)]
        out.extend(accepted)
        if len(out) < steps + 1:
            out.append(next_tok)
        # rebuild caches to the accepted stream (host-side bookkeeping:
        # replay accepted tokens; cheap relative to cloud verify)
        replay = jnp.stack(out[1:], axis=1) if len(out) > 1 else None
        full = jnp.concatenate([prompt] + [t[:, None] for t in out], axis=1)
        e_logits, e_cache = T.prefill(edge_cfg, edge_params, full[:, :-1],
                                      cache_len=cache_len)
        c_logits, c_cache = T.prefill(cloud_cfg, cloud_params, full[:, :-1],
                                      cache_len=cache_len)
        cur = out[-1]

    return jnp.stack(out[:steps + 1], axis=1), stats


def cloud_greedy_generate(cfg: ModelConfig, params, prompt: jax.Array,
                          steps: int, cache_len: Optional[int] = None
                          ) -> jax.Array:
    """Reference: plain greedy decoding with the big model."""
    B, S = prompt.shape
    cache_len = max(cache_len or 0, S + steps + 2)
    logits, cache = T.prefill(cfg, params, prompt, cache_len=cache_len)
    out = [greedy(logits)]
    for _ in range(steps):
        logits, cache = T.decode_step(cfg, params, cache, out[-1])
        out.append(greedy(logits))
    return jnp.stack(out[:steps + 1], axis=1)
