"""Intelligent task allocator (paper Eq. 7 + §IV-D).

Every edge device runs this scheduler.  When a detection arrives it picks

    d_i = argmin_{0 <= j <= N}  Q_j * t_j                      (Eq. 7)

over all computing nodes (0 = the Cloud), using the replicated parameter
store (queue lengths Q_j, per-item latency estimates t_j, thresholds
alpha/beta).  Any parameter write triggers propagation to all nodes —
mirroring the paper's SQLite + MQTT design with an in-process bus.
"""
from __future__ import annotations

import dataclasses
from typing import Collection, Dict, List, Optional

from repro.core.latency import LatencyEstimator
from repro.core.thresholds import ThresholdState

CLOUD = 0      # node id 0 is the Cloud, as in the paper


@dataclasses.dataclass
class NodeInfo:
    node_id: int
    queue_len: int = 0
    up: bool = True            # False once the node is marked failed
    estimator: LatencyEstimator = dataclasses.field(
        default_factory=LatencyEstimator)

    @property
    def t(self) -> float:
        return self.estimator.predict()

    @property
    def drain_time(self) -> float:
        return self.queue_len * self.t


class Scheduler:
    """Per-edge-device scheduler over the shared parameter view."""

    def __init__(self, nodes: List[int], interval_s: float = 1.0,
                 thresholds: Optional[ThresholdState] = None):
        self.nodes: Dict[int, NodeInfo] = {n: NodeInfo(n) for n in nodes}
        self.thresholds = thresholds or ThresholdState()
        self.interval_s = interval_s

    # --- Eq. 7 ---------------------------------------------------------------
    def select_node(self, exclude_cloud: bool = False,
                    exclude: Collection[int] = (),
                    extra_cost: Optional[Dict[int, float]] = None) -> int:
        """argmin_j Q_j * t_j (+ extra_cost_j) over eligible nodes.

        The cloud participates unless ``exclude_cloud``; ``exclude`` drops
        further node ids (e.g. a detection's own edge, or a failed node —
        nodes marked down via :meth:`mark_down` are always skipped).
        ``extra_cost`` adds per-node seconds to the drain cost — the
        end-to-end harness charges the cloud its WAN-uplink backlog this
        way, since the paper folds transmission latency into t_0.  Ties
        break to the lowest node id, so with every queue empty the cloud
        (node 0) wins — matching the paper's idle-system behaviour where the
        fast cloud absorbs traffic until edge queues pay off.  Raises
        ``ValueError`` if the exclusions leave no eligible node.
        """
        best, best_cost = None, float("inf")
        for nid in sorted(self.nodes):
            n = self.nodes[nid]
            if exclude_cloud and nid == CLOUD:
                continue
            if nid in exclude or not n.up:
                continue
            cost = n.queue_len * n.t
            if extra_cost:
                cost += extra_cost.get(nid, 0.0)
            if cost < best_cost:
                best, best_cost = nid, cost
        if best is None:
            raise ValueError("no eligible node (all excluded or down)")
        return best

    # --- SLO-weighted Eq. 7 (priority tiers) ----------------------------------
    def slo_pressure(self, weight: float, slack_s: float,
                     base_extra: Optional[Dict[int, float]] = None
                     ) -> Dict[int, float]:
        """Per-node extra cost making Eq. 7 deadline-aware.

        For an item with ``slack_s`` seconds left on its tier's SLO, every
        node whose effective drain (its queue drain plus any
        ``base_extra`` — e.g. the cloud's WAN backlog) exceeds the slack
        pays ``weight * (drain - slack)`` on top of its Q_j * t_j cost: a
        node that would already miss the deadline is penalized in
        proportion to how badly, while nodes inside the slack keep the
        plain Eq. 7 argmin.  ``weight == 0`` (the tierless default)
        returns ``base_extra`` unchanged — bit-identical allocation."""
        base = base_extra or {}
        if weight <= 0.0:
            return base
        out = dict(base)
        for nid, n in self.nodes.items():
            if not n.up:
                continue
            over = n.drain_time + base.get(nid, 0.0) - slack_s
            if over > 0.0:
                out[nid] = out.get(nid, 0.0) + weight * over
        return out

    # --- node liveness --------------------------------------------------------
    def mark_down(self, node_id: int) -> None:
        """Take a node out of Eq. 7 rotation (failed-edge scenarios)."""
        self.nodes[node_id].up = False

    def mark_up(self, node_id: int) -> None:
        self.nodes[node_id].up = True

    # --- parameter-store updates (any write triggers threshold refresh) ------
    def on_enqueue(self, node_id: int) -> None:
        self.nodes[node_id].queue_len += 1
        self._refresh_thresholds(node_id)

    def on_complete(self, node_id: int, latency_s: float) -> None:
        n = self.nodes[node_id]
        n.queue_len = max(0, n.queue_len - 1)
        n.estimator.observe(latency_s)
        self._refresh_thresholds(node_id)

    def _refresh_thresholds(self, node_id: int) -> None:
        """Eqs. 8-9, driven by the updated node's drain time."""
        n = self.nodes[node_id]
        self.thresholds = self.thresholds.update(
            n.queue_len, n.t, self.interval_s)

    # --- cascade triage -------------------------------------------------------
    def triage(self, confidence: float) -> str:
        return self.thresholds.triage(confidence)

    def snapshot(self) -> Dict[str, float]:
        return {
            "alpha": self.thresholds.alpha,
            "beta": self.thresholds.beta,
            **{f"Q{n.node_id}": n.queue_len for n in self.nodes.values()},
            **{f"t{n.node_id}": n.t for n in self.nodes.values()},
        }
