"""CQ-specific fine-tuning (paper §IV-B, Fig. 5).

When a new query arrives, a lightweight edge model is fine-tuned from shared
pre-trained weights on the cluster's context-specific dataset, then shipped
to the edge.  Three schemes, matching the paper's Fig. 5 comparison:

  * ``surveiledge``  — fine-tune from pre-trained weights on *cluster* data
                       (small LR, few steps; the paper's scheme: ~8x faster
                       than All-Fine-tune at nearly equal accuracy)
  * ``all_finetune`` — train per *camera* from scratch-ish (high LR, many
                       steps x num cameras; the expensive upper bound)
  * ``no_finetune``  — pre-trained weights used as-is (zero training time,
                       low accuracy on the specific query)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import meta as M
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass
class FinetuneResult:
    params: Any
    steps: int
    train_seconds: float
    final_loss: float
    accuracy: float


def classifier_loss(cfg: ModelConfig, params, tokens: jax.Array,
                    labels: jax.Array) -> jax.Array:
    """Binary/k-way xent on the CQ classifier head."""
    h, _ = T.forward(cfg, params, tokens, remat=False)
    logits = T.classify(cfg, params, h)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - ll)


def accuracy_of(cfg: ModelConfig, params, tokens: jax.Array,
                labels: jax.Array) -> float:
    h, _ = T.forward(cfg, params, tokens, remat=False)
    pred = jnp.argmax(T.classify(cfg, params, h), axis=-1)
    return float(jnp.mean((pred == labels).astype(jnp.float32)))


def finetune(cfg: ModelConfig,
             params: Any,
             data_iter: Iterable[Tuple[jax.Array, jax.Array]],
             *,
             steps: int = 50,
             lr: float = 1e-3,
             head_only: bool = False,
             eval_set: Optional[Tuple[jax.Array, jax.Array]] = None
             ) -> FinetuneResult:
    """Fine-tune ``params`` on (tokens, labels) batches.

    ``head_only=True`` freezes the backbone (linear probe) — the fastest
    variant of the paper's scheme for tiny time budgets.
    """
    opt_cfg = adamw.AdamWConfig(lr=lr, weight_decay=0.01, clip_norm=1.0)
    opt = adamw.init(params)

    @jax.jit
    def step(params, opt, tokens, labels):
        loss, grads = jax.value_and_grad(
            lambda p: classifier_loss(cfg, p, tokens, labels))(params)
        new_params, new_opt, _ = adamw.apply(opt_cfg, grads, opt, params)
        if head_only:
            # linear probe: only the classifier head moves (note: a grad
            # mask alone would still leak weight decay into the backbone)
            new_params = jax.tree_util.tree_map_with_path(
                lambda path, old, new: new
                if "cls_head" in jax.tree_util.keystr(path) else old,
                params, new_params)
        return new_params, new_opt, loss

    t0 = time.time()
    loss = float("nan")
    n = 0
    for tokens, labels in data_iter:
        params, opt, loss_j = step(params, opt, tokens, labels)
        loss = float(loss_j)
        n += 1
        if n >= steps:
            break
    dt = time.time() - t0
    acc = accuracy_of(cfg, params, *eval_set) if eval_set is not None else float("nan")
    return FinetuneResult(params, n, dt, loss, acc)


def pretrain_backbone(cfg: ModelConfig, key: jax.Array,
                      data_iter: Iterable[Tuple[jax.Array, jax.Array]],
                      steps: int = 100, lr: float = 1e-3,
                      dtype=jnp.float32) -> Any:
    """'ImageNet pre-training' analogue: generic multi-class pretraining of
    the edge backbone on pooled (all-cluster) data."""
    params = M.init_params(cfg, key, dtype)
    res = finetune(cfg, params, data_iter, steps=steps, lr=lr)
    return res.params


# Fig. 5 training-step budget shared by the real trainer (`run_scheme`
# below) and the runtime cost model (`scheme_train_time`): both express the
# same scheme trade — SurveilEdge fits ONE cluster model in `FIG5_STEPS`
# steps, All-Fine-tune fits one model PER CAMERA (the ~num_cameras-x
# slower upper bound), No-Fine-tune trains nothing.
FIG5_STEPS = 40
FIG5_SCHEMES = ("surveiledge", "all_finetune", "no_finetune")


def scheme_train_time(scheme: str, num_cameras: int, *,
                      step_s: float = 0.05) -> float:
    """Simulated cloud seconds to fine-tune one CQ model under ``scheme``.

    This is the Fig. 5 trade as an analytic cost the runtime query
    lifecycle charges on arrival (``system/queries.py``): ``step_s`` is
    the cloud's per-optimizer-step wall clock, and the step counts mirror
    ``run_scheme`` exactly — measured training time for the real trainer
    lives in ``benchmarks/fig5_training_schemes.py``.
    """
    if scheme == "no_finetune":
        return 0.0
    if scheme == "surveiledge":
        return FIG5_STEPS * step_s
    if scheme == "all_finetune":
        return FIG5_STEPS * step_s * max(int(num_cameras), 1)
    raise ValueError(
        f"unknown Fig. 5 training scheme {scheme!r} "
        f"(expected one of {FIG5_SCHEMES})")


def run_scheme(scheme: str,
               cfg: ModelConfig,
               pretrained: Any,
               cluster_iter_fn: Callable[[], Iterable],
               camera_iter_fns: Dict[int, Callable[[], Iterable]],
               eval_set) -> Dict[str, FinetuneResult]:
    """Dispatch the Fig. 5 training schemes.  Returns per-target results."""
    if scheme == "no_finetune":
        acc = accuracy_of(cfg, pretrained, *eval_set)
        return {-1: FinetuneResult(pretrained, 0, 0.0, float("nan"), acc)}
    if scheme == "surveiledge":
        res = finetune(cfg, pretrained, cluster_iter_fn(),
                       steps=FIG5_STEPS, lr=5e-4, eval_set=eval_set)
        return {-1: res}
    if scheme == "all_finetune":
        out = {}
        for cam, it_fn in camera_iter_fns.items():
            out[cam] = finetune(cfg, pretrained, it_fn(),
                                steps=FIG5_STEPS, lr=5e-4, eval_set=eval_set)
        return out
    raise ValueError(scheme)
