"""Dynamic confidence-threshold adaptation (paper Eqs. 8-9).

The cascade uploads an image to the cloud when its edge confidence f falls in
[beta, alpha].  SurveilEdge adapts the interval width to system load:

  alpha_new = max(min(alpha_old - gamma1 * (l_d * t_d - s), 1), 0.5)     (8)
  beta_new  = gamma2 * (1 - alpha_new)                                   (9)

where l_d*t_d is the expected drain time of the chosen queue (queue length x
per-item latency) and s is the query sampling interval.  When the system is
overloaded (drain > s) the bracket shrinks -> fewer cloud uploads; when idle
it widens -> more reclassification -> higher accuracy.  alpha is clamped to
[0.5, 1] and beta < 0.5 by construction (gamma2 in (0,1)).

``ThresholdState`` is one edge's adaptation state.  The paper runs Eqs. 8-9
on every edge device, so the end-to-end engine keeps one instance per edge
(``repro.system.triage.TriageStage``) and feeds the resulting (E, 2) matrix
to the fused fleet-triage kernel as runtime data.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class ThresholdState:
    alpha: float = 0.8
    beta: float = 0.1
    gamma1: float = 0.2
    gamma2: float = 0.25
    # Optional asymmetric widening gain: when the system is idle (drain < s)
    # alpha rises with this gain instead of gamma1.  None keeps the paper's
    # symmetric Eq. 8.  The end-to-end harness sets a small value ("shed load
    # fast, spend idle capacity slowly") so a periodically-idle system does
    # not slam the bracket open and saturate the uplink with escalations.
    gamma1_up: Optional[float] = None

    def update(self, queue_len: float, item_latency: float,
               interval_s: float) -> "ThresholdState":
        """Eq. 8/9 update given the selected queue's drain time."""
        drain = queue_len * item_latency
        gain = self.gamma1 if (drain >= interval_s or self.gamma1_up is None) \
            else self.gamma1_up
        alpha = self.alpha - gain * (drain - interval_s)
        alpha = max(min(alpha, 1.0), 0.5)
        beta = self.gamma2 * (1.0 - alpha)
        return dataclasses.replace(self, alpha=alpha, beta=beta)

    def triage(self, confidence: float):
        """-> 'accept' | 'reject' | 'escalate' for one confidence value."""
        if confidence > self.alpha:
            return "accept"
        if confidence < self.beta:
            return "reject"
        return "escalate"
