"""Confidence-thresholded cloud-edge cascade (the paper's C1), in JAX.

The edge (CQ-specific) model emits a confidence f = P(query object | image).
Per item:
    f > alpha          -> accept at the edge
    f < beta           -> reject at the edge
    beta <= f <= alpha -> escalate: re-classify with the cloud model

``triage_and_compact`` is the batched, jit-able core: it routes a batch by
thresholds and compacts the escalated subset into a fixed-capacity buffer
(a requirement for fixed-shape XLA programs — and the hot-spot the Pallas
``triage`` kernel implements).  ``CascadePair`` wires two models together.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

ACCEPT, REJECT, ESCALATE = 0, 1, 2


def confidence_from_logits(logits: jax.Array,
                           query_class: int = 1) -> jax.Array:
    """(B, C) class logits -> (B,) P(query object)."""
    return jax.nn.softmax(logits.astype(jnp.float32), axis=-1)[:, query_class]


def triage(conf: jax.Array, alpha: jax.Array, beta: jax.Array) -> jax.Array:
    """(B,) confidences -> (B,) route codes {ACCEPT, REJECT, ESCALATE}."""
    return jnp.where(conf > alpha, ACCEPT,
                     jnp.where(conf < beta, REJECT, ESCALATE)).astype(jnp.int32)


def compact_escalated(routes: jax.Array, capacity: int
                      ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Stable-compact indices of escalated items into a fixed buffer.

    Returns (indices (capacity,) int32 — source index per slot, padded with
    the first index; valid (capacity,) bool; n_escalated ()).
    Overflowing items (beyond capacity) stay un-escalated — the adaptive
    thresholds exist precisely to keep this rare.
    """
    esc = routes == ESCALATE
    pos = jnp.cumsum(esc.astype(jnp.int32)) - 1          # slot per item
    n = jnp.sum(esc.astype(jnp.int32))
    slot = jnp.where(esc & (pos < capacity), pos, capacity)
    idx = jnp.zeros((capacity + 1,), jnp.int32).at[slot].set(
        jnp.arange(routes.shape[0], dtype=jnp.int32), mode="drop")[:capacity]
    valid = jnp.arange(capacity) < jnp.minimum(n, capacity)
    return idx, valid, n


def cascade_batch(edge_conf: jax.Array,
                  cloud_fn: Callable[[jax.Array], jax.Array],
                  items: jax.Array,
                  alpha: jax.Array, beta: jax.Array,
                  capacity: int) -> Dict[str, jax.Array]:
    """Pure-JAX cascade over one batch.

    edge_conf: (B,) edge confidences; items: (B, ...) payloads to send to
    ``cloud_fn`` (which maps (capacity, ...) -> (capacity,) confidences).
    Returns dict with final decisions (B,), routes, and stats.
    """
    B = edge_conf.shape[0]
    routes = triage(edge_conf, alpha, beta)
    idx, valid, n_esc = compact_escalated(routes, capacity)
    esc_items = jnp.take(items, idx, axis=0)
    cloud_conf = cloud_fn(esc_items)                     # (capacity,)
    # scatter cloud decisions back
    cloud_dec = (cloud_conf > 0.5)
    final = routes == ACCEPT                             # edge accepts
    upd = jnp.where(valid, cloud_dec, False)
    final = final.at[idx].set(jnp.where(valid, upd, final[idx]))
    return {
        "decision": final,                               # (B,) bool: query object?
        "routes": routes,
        "edge_conf": edge_conf,
        "n_escalated": n_esc,
        "escalated_frac": n_esc / B,
    }


@dataclasses.dataclass
class CascadePair:
    """An (edge CQ-specific model, cloud high-accuracy model) pair."""
    edge_cfg: Any
    cloud_cfg: Any
    edge_apply: Callable      # (params, batch) -> (B, C) logits
    cloud_apply: Callable
    query_class: int = 1

    def edge_confidence(self, edge_params, batch) -> jax.Array:
        return confidence_from_logits(
            self.edge_apply(edge_params, batch), self.query_class)

    def cloud_confidence(self, cloud_params, batch) -> jax.Array:
        return confidence_from_logits(
            self.cloud_apply(cloud_params, batch), self.query_class)
