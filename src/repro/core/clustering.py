"""Camera profiling + K-means clustering (paper §IV-A), in JAX.

A camera's *profile* is its proportion vector: occurrence frequencies of
object classes across its leisure-time frames (labeled by the high-accuracy
cloud models).  Cameras are clustered on profiles with K-means; each cluster
shares one context-specific training dataset.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def proportion_vector(labels: jax.Array, num_classes: int) -> jax.Array:
    """labels: (N,) int32 detected-object classes -> (C,) frequencies."""
    counts = jnp.zeros((num_classes,), jnp.float32).at[labels].add(1.0)
    return counts / jnp.maximum(jnp.sum(counts), 1.0)


def kmeans(profiles: jax.Array, k: int, *, iters: int = 50,
           key: jax.Array | None = None) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """K-means on (N, C) profiles.

    Returns (assignments (N,), centers (k, C), inertia ()).  Deterministic
    k-means++-style farthest-point init when ``key`` is None.
    """
    n, c = profiles.shape
    x = profiles.astype(jnp.float32)

    # farthest-point init (deterministic; k-means++ without randomness)
    def init_step(carry, _):
        centers, chosen = carry
        d = jnp.min(
            jnp.sum((x[:, None, :] - centers[None]) ** 2, -1)
            + jnp.where(jnp.arange(centers.shape[0])[None] < chosen,
                        0.0, jnp.inf), axis=1)
        nxt = jnp.argmax(jnp.where(jnp.isfinite(d), d, -jnp.inf))
        centers = centers.at[chosen].set(x[nxt])
        return (centers, chosen + 1), None

    centers0 = jnp.zeros((k, c), jnp.float32).at[0].set(x[0])
    (centers, _), _ = jax.lax.scan(init_step, (centers0, 1), None, length=k - 1)

    def em_step(centers, _):
        d = jnp.sum((x[:, None, :] - centers[None]) ** 2, -1)     # (N,k)
        assign = jnp.argmin(d, axis=1)
        onehot = jax.nn.one_hot(assign, k, dtype=jnp.float32)     # (N,k)
        tot = jnp.maximum(jnp.sum(onehot, axis=0), 1e-9)[:, None]
        new_centers = (onehot.T @ x) / tot
        # keep empty clusters where they were
        new_centers = jnp.where(jnp.sum(onehot, axis=0)[:, None] > 0,
                                new_centers, centers)
        return new_centers, None

    centers, _ = jax.lax.scan(em_step, centers, None, length=iters)
    d = jnp.sum((x[:, None, :] - centers[None]) ** 2, -1)
    assign = jnp.argmin(d, axis=1)
    inertia = jnp.sum(jnp.min(d, axis=1))
    return assign, centers, inertia
