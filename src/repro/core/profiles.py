"""Camera profiles + context-specific dataset establishment (paper §IV-A/B).

Offline stage: leisure-time frames from each camera are labeled by the
high-accuracy cloud pipeline (detector + classifier); per-camera proportion
vectors feed K-means; cameras in one cluster share a training dataset.

Online stage (new query): positive samples are labeled images of the query
class; negative samples are drawn from non-query classes *proportionally to
the cluster profile* — the paper's principle that commonly-seen objects
deserve more negative mass.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import clustering


def build_profiles(camera_labels: Dict[int, np.ndarray],
                   num_classes: int) -> Tuple[List[int], np.ndarray]:
    """{camera_id: (N_i,) labels} -> (camera_ids, (n_cams, C) profiles)."""
    cams = sorted(camera_labels)
    import jax.numpy as jnp
    profs = np.stack([
        np.asarray(clustering.proportion_vector(
            jnp.asarray(camera_labels[c], dtype=jnp.int32), num_classes))
        for c in cams])
    return cams, profs


def cluster_cameras(profiles: np.ndarray, k: int) -> Tuple[np.ndarray, np.ndarray]:
    """K-means wrapper -> (assignments, cluster profiles/centers)."""
    import jax.numpy as jnp
    assign, centers, _ = clustering.kmeans(jnp.asarray(profiles), k)
    return np.asarray(assign), np.asarray(centers)


def select_training_set(labels: np.ndarray,
                        cluster_profile: np.ndarray,
                        query_class: int,
                        n_positive: int,
                        n_negative: int,
                        rng: np.random.Generator) -> np.ndarray:
    """Indices of the CQ-specific fine-tuning set.

    Negative sampling mass per non-query class c is proportional to the
    cluster profile entry (common objects get more negatives).
    """
    pos_pool = np.flatnonzero(labels == query_class)
    neg_pool = np.flatnonzero(labels != query_class)
    if len(pos_pool) == 0 or len(neg_pool) == 0:
        raise ValueError("query class absent from the cluster dataset")
    pos = rng.choice(pos_pool, size=min(n_positive, len(pos_pool)),
                     replace=len(pos_pool) < n_positive)
    w = cluster_profile[labels[neg_pool]].astype(np.float64)
    w = np.maximum(w, 1e-9)
    w = w / w.sum()
    neg = rng.choice(neg_pool, size=n_negative, replace=True, p=w)
    idx = np.concatenate([pos, neg])
    rng.shuffle(idx)
    return idx
