"""Query-accuracy scoring shared by both evaluation substrates.

The paper reports F2 (recall-weighted F-measure) against the cloud model's
output treated as ground truth.  Kept in one place so the guard behaviour
(empty classes, zero denominators) cannot diverge between
``repro.serving.simulator.SimResult`` and ``repro.system.QueryReport``.
"""
from __future__ import annotations

import numpy as np


def f_score_counts(tp: int, fp: int, fn: int, lam: float = 2.0) -> float:
    """F_lambda from confusion counts — the one formula both the
    array path and the streaming aggregates (``metrics.StreamingWindows``)
    reduce to, so windowed and whole-run scores cannot diverge."""
    p = tp / max(tp + fp, 1)
    r = tp / max(tp + fn, 1)
    if p + r == 0:
        return 0.0
    return (1 + lam ** 2) * p * r / (lam ** 2 * p + r)


def f_score(decisions: np.ndarray, truths: np.ndarray,
            lam: float = 2.0) -> float:
    """F_lambda of boolean decisions vs boolean ground truth."""
    decisions = np.asarray(decisions, bool)
    truths = np.asarray(truths, bool)
    tp = int(np.sum(decisions & truths))
    fp = int(np.sum(decisions & ~truths))
    fn = int(np.sum(~decisions & truths))
    return f_score_counts(tp, fp, fn, lam)
