"""Alert/health stream over the control-plane bus.

Every operationally interesting condition the engine detects is published
on the ``Bus`` under ``alerts/<scope>/<kind>``:

  alerts/admission/quota       a tenant's token bucket ran dry
  alerts/admission/backlog     cloud fine-tune backlog shed a submission
  alerts/edge<e>/failover      edge died; its work re-dispatched
  alerts/edge<e>/shed_batch    overloaded edge shed a tick's raw batch
  alerts/edge<e>/queue_depth   sampled queue depth above the alert line
  alerts/edge<e>/threshold_drift  Eqs. 8-9 bracket drifted past the line

``AlertStream`` is the in-process consumer (the dashboard analogue): it
subscribes ``alerts/#`` and keeps (a) per-kind counts — the stable,
seed-robust aggregate ``QueryReport`` snapshots and the report gate
bands — and (b) a bounded ring of the most recent alerts with their full
topic and payload, for debugging and the demo CLI.  External consumers
subscribe the same topics on the same bus; nothing here is load-bearing
for the engine's decisions (alerts observe, never steer).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict


@dataclasses.dataclass(frozen=True)
class Alert:
    t: float
    topic: str
    payload: Any


class AlertStream:
    """Bus-subscribed alert aggregator (counts by kind + recent ring)."""

    def __init__(self, bus, keep: int = 256):
        self._bus = bus
        self.counts: Dict[str, int] = {}
        self.recent: Deque[Alert] = collections.deque(maxlen=keep)
        bus.subscribe("alerts/#", self._on_alert)

    def _on_alert(self, topic: str, payload: Any) -> None:
        # aggregate by the kind segment ("failover", "quota", ...): the
        # scope segment carries a node id, which varies with seed and
        # would make the report-gate baseline dict churn per run shape
        kind = topic.rsplit("/", 1)[-1]
        self.counts[kind] = self.counts.get(kind, 0) + 1
        t = payload.get("t", 0.0) if isinstance(payload, dict) else 0.0
        self.recent.append(Alert(float(t), topic, payload))

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def snapshot(self) -> Dict[str, int]:
        """Per-kind counts, sorted by kind (the ``QueryReport.alerts``
        payload)."""
        return dict(sorted(self.counts.items()))

    def close(self) -> None:
        """Detach from the bus (safe mid-delivery: publish iterates a
        snapshot of the subscription list)."""
        self._bus.unsubscribe("alerts/#", self._on_alert)
