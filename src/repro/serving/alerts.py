"""Alert/health stream over the control-plane bus.

Every operationally interesting condition the engine detects is published
on the ``Bus`` under ``alerts/<scope>/<kind>``:

  alerts/admission/quota       a tenant's token bucket ran dry
  alerts/admission/backlog     cloud fine-tune backlog shed a submission
  alerts/edge<e>/failover      edge died; its work re-dispatched
  alerts/edge<e>/shed_batch    overloaded edge shed a tick's raw batch
  alerts/edge<e>/queue_depth   sampled queue depth above the alert line
  alerts/edge<e>/threshold_drift  Eqs. 8-9 bracket drifted past the line

``AlertStream`` is the in-process consumer (the dashboard analogue): it
subscribes ``alerts/#`` and keeps (a) per-kind counts — the stable,
seed-robust aggregate ``QueryReport`` snapshots and the report gate
bands — and (b) a bounded ring of the most recent alerts with their full
topic and payload, for debugging and the demo CLI.  External consumers
subscribe the same topics on the same bus; nothing here is load-bearing
for the engine's decisions (alerts observe, never steer).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Any, Deque, Dict


@dataclasses.dataclass(frozen=True)
class Alert:
    t: float
    topic: str
    payload: Any


class AlertStream:
    """Bus-subscribed alert aggregator (counts by kind + recent ring)."""

    def __init__(self, bus, keep: int = 256, keep_per_scope: int = 8):
        self._bus = bus
        self.counts: Dict[str, int] = {}
        self.recent: Deque[Alert] = collections.deque(maxlen=keep)
        # per-scope view ("edge3", "admission", ...): kind counts + a
        # short recent ring each, feeding health_snapshot
        self._scope_counts: Dict[str, Dict[str, int]] = {}
        self._scope_recent: Dict[str, Deque[Alert]] = {}
        self._keep_per_scope = keep_per_scope
        bus.subscribe("alerts/#", self._on_alert)

    def _on_alert(self, topic: str, payload: Any) -> None:
        # aggregate by the kind segment ("failover", "quota", ...): the
        # scope segment carries a node id, which varies with seed and
        # would make the report-gate baseline dict churn per run shape
        kind = topic.rsplit("/", 1)[-1]
        self.counts[kind] = self.counts.get(kind, 0) + 1
        t = payload.get("t", 0.0) if isinstance(payload, dict) else 0.0
        alert = Alert(float(t), topic, payload)
        self.recent.append(alert)
        parts = topic.split("/")
        scope = parts[1] if len(parts) > 1 else ""
        sc = self._scope_counts.setdefault(scope, {})
        sc[kind] = sc.get(kind, 0) + 1
        ring = self._scope_recent.get(scope)
        if ring is None:
            ring = self._scope_recent[scope] = collections.deque(
                maxlen=self._keep_per_scope)
        ring.append(alert)

    @property
    def total(self) -> int:
        return sum(self.counts.values())

    def snapshot(self) -> Dict[str, int]:
        """Per-kind counts, sorted by kind (the ``QueryReport.alerts``
        payload)."""
        return dict(sorted(self.counts.items()))

    def health_snapshot(self, edge: int) -> Dict[str, Any]:
        """One edge's operator health view (``QueryReport.edge_health``):
        per-kind alert counts for scope ``edge<edge>``, the scope's most
        recent alerts (topic, t, payload), and its total.  An edge that
        never alerted reports a clean ``{"alerts": {}, "recent": [],
        "total": 0}`` — the healthy baseline, not an error."""
        scope = f"edge{edge}"
        counts = dict(sorted(self._scope_counts.get(scope, {}).items()))
        recent = [
            {"t": round(a.t, 3), "topic": a.topic, "payload": a.payload}
            for a in self._scope_recent.get(scope, ())]
        return {"alerts": counts, "recent": recent,
                "total": sum(counts.values())}

    def close(self) -> None:
        """Detach from the bus (safe mid-delivery: publish iterates a
        snapshot of the subscription list)."""
        self._bus.unsubscribe("alerts/#", self._on_alert)
