"""Slot-based continuous-batching decode engine with a cascade front-end.

The production serving path for the assigned architectures: a fixed-size
decode batch ("slots") runs one fused decode_step per tick; finished or
empty slots are refilled from the request queue (prefill on admission), so
the big model never idles while requests trickle in — the LLM-serving
analogue of the paper's "keep the cloud busy with exactly the work the edge
couldn't settle".

Requests enter through the SurveilEdge triage: the edge CQ model scores
each prompt, confident ones are answered at the edge (classification
serving), the rest are admitted to the cloud decode batch.

This module also hosts the **real-time driver** for the simulation
pipeline (``repro.system.pipeline``): ``AsyncDriver`` pumps the same
event heap the DES ``SimDriver`` drains, but from an asyncio loop
against a pluggable ``Clock`` — ``VirtualClock`` (deterministic: pops in
exactly the DES order, which the differential tests assert) or
``WallClock`` (real time, optionally scaled).  ``call_at`` schedules
host-side hooks (live query submission via ``repro.serving.api``) that
run strictly before same-instant simulation events.
"""
from __future__ import annotations

import asyncio
import collections
import dataclasses
import heapq
import time
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cascade as C
from repro.core.thresholds import ThresholdState
from repro.models import meta as M
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    tokens: np.ndarray                  # (S,) prompt
    max_new: int = 16
    # filled by the engine:
    output: Optional[np.ndarray] = None
    route: str = "pending"              # edge_accept | edge_reject | cloud
    ticks_waited: int = 0


@dataclasses.dataclass
class SlotState:
    rid: int = -1
    remaining: int = 0
    generated: Optional[List[int]] = None

    @property
    def free(self) -> bool:
        return self.rid < 0


class DecodeEngine:
    """Continuous batching over a fixed slot count for ONE model."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int,
                 cache_len: int, window: Optional[int] = None):
        self.cfg = cfg
        self.params = params
        self.slots = [SlotState() for _ in range(slots)]
        self.cache_len = cache_len
        self.window = window
        self.cache = T.make_cache(cfg, slots, cache_len, dtype=jnp.float32)
        self.tokens = jnp.zeros((slots,), jnp.int32)
        self.ticks = 0

        self._prefill_1 = jax.jit(
            lambda p, t: T.prefill(cfg, p, t, cache_len=cache_len,
                                   window=window))
        self._decode = jax.jit(
            lambda p, c, t: T.decode_step(cfg, p, c, t, window=window))

    # ---- slot management -----------------------------------------------------
    def admit(self, req: Request) -> bool:
        """Prefill the request into a free slot; False if the batch is full."""
        for i, slot in enumerate(self.slots):
            if slot.free:
                logits, cache1 = self._prefill_1(
                    self.params, jnp.asarray(req.tokens[None]))
                first = int(jnp.argmax(logits[0]))
                self._write_slot_cache(i, cache1)
                self.tokens = self.tokens.at[i].set(first)
                self.slots[i] = SlotState(rid=req.rid,
                                          remaining=req.max_new - 1,
                                          generated=[first])
                return True
        return False

    def _write_slot_cache(self, i: int, cache1) -> None:
        """Copy a batch-1 prefill cache into slot i of the engine cache.

        Positions are per-sequence ((B,)/(B,W)), so slots at different
        prefix lengths coexist — true mid-flight continuous batching."""
        def upd(dst, src):
            return dst.at[:, i:i + 1].set(src)
        self.cache["layers"] = jax.tree.map(upd, self.cache["layers"],
                                            cache1["layers"])
        self.cache["pos"] = self.cache["pos"].at[i].set(cache1["pos"][0])
        # pad the batch-1 kpos up to the engine cache length
        kp = cache1["kpos"][0]
        if kp.shape[0] < self.cache_len:
            kp = jnp.concatenate(
                [kp, jnp.full((self.cache_len - kp.shape[0],), -1, jnp.int32)])
        self.cache["kpos"] = self.cache["kpos"].at[i].set(kp)

    def _release_slot(self, i: int) -> None:
        """Reset a freed slot's bookkeeping so its lane stays benign."""
        self.cache["pos"] = self.cache["pos"].at[i].set(0)
        self.cache["kpos"] = self.cache["kpos"].at[i].set(-1)

    def step(self) -> List[Tuple[int, List[int]]]:
        """One decode tick for every active slot.  Returns finished
        (rid, generated_tokens) pairs."""
        self.ticks += 1
        logits, self.cache = self._decode(self.params, self.cache, self.tokens)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = nxt
        done = []
        for i, slot in enumerate(self.slots):
            if slot.free:
                continue
            slot.generated.append(int(nxt[i]))
            slot.remaining -= 1
            if slot.remaining <= 0:
                done.append((slot.rid, list(slot.generated)))
                self.slots[i] = SlotState()
                self._release_slot(i)
        return done

    @property
    def active(self) -> int:
        return sum(not s.free for s in self.slots)


class CascadeServer:
    """Edge triage + cloud continuous-batching decode."""

    def __init__(self, edge_cfg: ModelConfig, edge_params,
                 cloud_cfg: ModelConfig, cloud_params, *,
                 slots: int = 4, cache_len: int = 128,
                 thresholds: Optional[ThresholdState] = None):
        self.edge_cfg = edge_cfg
        self.edge_params = edge_params
        self.th = thresholds or ThresholdState(alpha=0.8, beta=0.1)
        self.engine = DecodeEngine(cloud_cfg, cloud_params, slots=slots,
                                   cache_len=cache_len)
        # deque: admission pops from the head every tick, and a long
        # backlog under a full batch made list.pop(0) O(n) per admit —
        # O(n^2) across a rush
        self.queue: Deque[Request] = collections.deque()
        self.results: Dict[int, Request] = {}

        @jax.jit
        def edge_conf(params, tokens):
            h, _ = T.forward(edge_cfg, params, tokens, remat=False)
            return C.confidence_from_logits(T.classify(edge_cfg, params, h))

        self._edge_conf = edge_conf

    def submit(self, req: Request) -> None:
        conf = float(self._edge_conf(self.edge_params,
                                     jnp.asarray(req.tokens[None]))[0])
        route = self.th.triage(conf)
        if route == "accept":
            req.route, req.output = "edge_accept", np.asarray([1])
            self.results[req.rid] = req
        elif route == "reject":
            req.route, req.output = "edge_reject", np.asarray([0])
            self.results[req.rid] = req
        else:
            req.route = "cloud"
            self.queue.append(req)

    def run(self, requests: List[Request], max_ticks: int = 1000
            ) -> Dict[int, Request]:
        pending: Dict[int, Request] = {}
        for r in requests:
            self.submit(r)
            if r.route == "cloud":
                pending[r.rid] = r
        # Mid-flight continuous batching: positions are per-sequence, so any
        # freed slot is refilled immediately, regardless of how far the other
        # slots have decoded or how long the new prompt is.
        ticks = 0
        while (self.queue or self.engine.active) and ticks < max_ticks:
            while self.queue and self.engine.admit(self.queue[0]):
                self.queue.popleft()
            for req in self.queue:
                req.ticks_waited += 1
            if self.engine.active:
                for rid, generated in self.engine.step():
                    req = pending.pop(rid)
                    req.output = np.asarray(generated, np.int32)
                    self.results[rid] = req
            ticks += 1
        return self.results


# --- real-time driver for the simulation pipeline -----------------------------
#
# ``QueryPipeline`` exposes a driver seam (setup / handle_event /
# finalize); ``SimDriver`` (system/pipeline.py) drains the event heap at
# zero wall-clock cost.  ``AsyncDriver`` pumps the SAME heap from asyncio
# against a Clock, which is what turns the simulator into a serving
# process: in wall time, events fire when their simulated instant
# actually arrives; in virtual time, the clock just jumps — bit-identical
# pops to SimDriver, so every control-plane feature can be tested
# deterministically and then served unchanged.


class VirtualClock:
    """Deterministic clock: ``sleep_until`` jumps straight to ``t``.

    The single ``asyncio.sleep(0)`` yield keeps the pump cooperative (a
    co-scheduled submitter coroutine gets a turn per event) without ever
    consulting real time."""

    def __init__(self) -> None:
        self._t = 0.0

    def now(self) -> float:
        return self._t

    async def sleep_until(self, t: float) -> None:
        if t > self._t:
            self._t = t
        await asyncio.sleep(0)


class WallClock:
    """Real time, scaled: ``speed`` simulated seconds pass per wall
    second (speed=60 replays a minute of fleet per wall second)."""

    def __init__(self, speed: float = 1.0) -> None:
        if speed <= 0:
            raise ValueError(f"speed={speed} must be > 0")
        self.speed = speed
        self._t0: Optional[float] = None

    def _origin(self) -> float:
        if self._t0 is None:
            self._t0 = time.monotonic()
        return self._t0

    def now(self) -> float:
        return (time.monotonic() - self._origin()) * self.speed

    async def sleep_until(self, t: float) -> None:
        delay = (t - self.now()) / self.speed
        if delay > 0:
            await asyncio.sleep(delay)


class AsyncDriver:
    """Pump a ``QueryPipeline``'s event heap from an asyncio loop.

    ``call_at(t, fn)`` schedules a host hook at simulated time ``t`` —
    the live-submission entry point (``QueryAPI.submit`` from a hook
    pushes ``QueryArrival`` into the same heap).  Hooks run strictly
    BEFORE simulation events at the same instant, so a submission at t
    is admitted by the arrival it just pushed, never raced by it.

    With no hooks, the pump peeks the heap, sleeps the clock to the
    event's instant, and pops — exactly ``SimDriver``'s order (same
    heap, same tie-breaking seq), which the differential tests assert
    bit-identical.
    """

    def __init__(self, clock: Optional[object] = None) -> None:
        self.clock = clock or VirtualClock()
        self._hooks: List[Tuple[float, int, Callable[[float], Any]]] = []
        self._hseq = 0
        self.events_pumped = 0
        self.hooks_run = 0

    def call_at(self, t: float, fn: Callable[[float], Any]) -> None:
        """Run ``fn(t)`` at simulated time ``t`` (FIFO among equal t)."""
        self._hseq += 1
        heapq.heappush(self._hooks, (t, self._hseq, fn))

    def drive(self, pipe) -> None:
        """Synchronous entry point for ``QueryPipeline.run``."""
        asyncio.run(self.pump(pipe))

    async def pump(self, pipe) -> None:
        """The async loop proper — await this directly (e.g. gathered
        with a live submitter coroutine) when the caller already owns an
        event loop."""
        while True:
            ev_t = pipe.events.peek_time()
            hook_t = self._hooks[0][0] if self._hooks else None
            if ev_t is None and hook_t is None:
                return
            nxt = min(x for x in (ev_t, hook_t) if x is not None)
            await self.clock.sleep_until(nxt)
            # re-peek: a wall-clock sleep (or the virtual clock's yield)
            # may have let a co-scheduled coroutine push earlier work
            ev_t = pipe.events.peek_time()
            hook_t = self._hooks[0][0] if self._hooks else None
            if hook_t is not None and (ev_t is None or hook_t <= ev_t):
                t, _, fn = heapq.heappop(self._hooks)
                self.hooks_run += 1
                fn(t)
            elif ev_t is not None:
                t, ev = pipe.events.pop()
                self.events_pumped += 1
                pipe.handle_event(t, ev)
