"""In-process publish/subscribe message bus (the MQTT/Mosquitto analogue).

Topic-based, synchronous delivery, MQTT-style trailing '#' wildcard —
enough to mirror the paper's control plane (parameter updates, task
dispatch, results, and the serving layer's alert/health stream) without a
broker dependency.

The '#' wildcard is segment-anchored, as in MQTT: ``edges/#`` matches
``edges`` and ``edges/3/queue`` but never ``edges9/queue`` — a trailing
``#`` only ever swallows whole ``/``-separated segments, so a pattern like
``edges#`` cannot prefix-match across the separator into a sibling
namespace.
"""
from __future__ import annotations

import fnmatch
from typing import Any, Callable, Dict, List, Tuple

Handler = Callable[[str, Any], None]


class Bus:
    def __init__(self) -> None:
        self._subs: List[Tuple[str, Handler]] = []
        self.delivered = 0
        self.published_bytes = 0

    def subscribe(self, pattern: str, handler: Handler) -> None:
        self._subs.append((pattern, handler))

    def unsubscribe(self, pattern: str, handler: Handler) -> bool:
        """Drop one (pattern, handler) subscription; True if it existed.

        Safe to call from inside a handler mid-delivery: ``publish``
        iterates a snapshot, so the in-flight delivery completes (the
        leaving handler may still see the current publication) and every
        later publish skips it."""
        try:
            self._subs.remove((pattern, handler))
            return True
        except ValueError:
            return False

    def publish(self, topic: str, payload: Any, nbytes: int = 0) -> int:
        """Deliver to all matching subscribers; returns delivery count."""
        self.published_bytes += nbytes
        n = 0
        for pattern, handler in list(self._subs):
            if _match(pattern, topic):
                handler(topic, payload)
                n += 1
        self.delivered += n
        return n


def _match(pattern: str, topic: str) -> bool:
    if pattern.endswith("#"):
        # MQTT semantics: '#' stands for "this segment and everything
        # below it", so it must sit on a topic-segment boundary.  The
        # prefix before it (sans its trailing '/') must equal the topic
        # or be a whole-segment prefix of it: "edges/#" matches "edges"
        # and "edges/3/q" but NOT "edges9/q".
        prefix = pattern[:-1].rstrip("/")
        return topic == prefix or topic.startswith(prefix + "/") \
            if prefix else True
    return fnmatch.fnmatch(topic, pattern)


class FifoLink:
    """Shared FIFO uplink: concurrent transfers serialize (the WAN model
    whose saturation reproduces cloud-only's latency, Table II)."""

    def __init__(self, MBps: float, rtt_s: float = 0.0) -> None:
        self.MBps = MBps
        self.rtt_s = rtt_s
        self.free_at = 0.0

    def send(self, t: float, nbytes: int) -> float:
        """Start a transfer at ``t``; returns its delivery time."""
        start = max(t, self.free_at)
        self.free_at = start + nbytes / (self.MBps * 1e6)
        return self.free_at + self.rtt_s

    def backlog(self, t: float) -> float:
        """Seconds of queued transfers ahead of a new send at ``t``."""
        return max(0.0, self.free_at - t)


class ParamDB:
    """Replicated parameter store (the SQLite analogue).

    Every write publishes on 'params/<key>'; every node holds the same view
    (synchronous replication — the paper's update-triggers-update semantics).
    """

    def __init__(self, bus: Bus) -> None:
        self._bus = bus
        self._store: Dict[str, Any] = {}
        self.writes = 0

    def put(self, key: str, value: Any) -> None:
        self._store[key] = value
        self.writes += 1
        self._bus.publish(f"params/{key}", value, nbytes=8)

    def get(self, key: str, default: Any = None) -> Any:
        return self._store.get(key, default)

    def snapshot(self) -> Dict[str, Any]:
        return dict(self._store)
