"""In-process publish/subscribe message bus (the MQTT/Mosquitto analogue).

Topic-based, synchronous delivery, wildcard '#' suffix supported — enough to
mirror the paper's control plane (parameter updates, task dispatch, results)
without a broker dependency.
"""
from __future__ import annotations

import collections
import fnmatch
from typing import Any, Callable, DefaultDict, Dict, List, Tuple

Handler = Callable[[str, Any], None]


class Bus:
    def __init__(self) -> None:
        self._subs: List[Tuple[str, Handler]] = []
        self.delivered = 0
        self.published_bytes = 0

    def subscribe(self, pattern: str, handler: Handler) -> None:
        self._subs.append((pattern, handler))

    def publish(self, topic: str, payload: Any, nbytes: int = 0) -> int:
        """Deliver to all matching subscribers; returns delivery count."""
        self.published_bytes += nbytes
        n = 0
        for pattern, handler in list(self._subs):
            if _match(pattern, topic):
                handler(topic, payload)
                n += 1
        self.delivered += n
        return n


def _match(pattern: str, topic: str) -> bool:
    if pattern.endswith("#"):
        return topic.startswith(pattern[:-1])
    return fnmatch.fnmatch(topic, pattern)


class FifoLink:
    """Shared FIFO uplink: concurrent transfers serialize (the WAN model
    whose saturation reproduces cloud-only's latency, Table II)."""

    def __init__(self, MBps: float, rtt_s: float = 0.0) -> None:
        self.MBps = MBps
        self.rtt_s = rtt_s
        self.free_at = 0.0

    def send(self, t: float, nbytes: int) -> float:
        """Start a transfer at ``t``; returns its delivery time."""
        start = max(t, self.free_at)
        self.free_at = start + nbytes / (self.MBps * 1e6)
        return self.free_at + self.rtt_s

    def backlog(self, t: float) -> float:
        """Seconds of queued transfers ahead of a new send at ``t``."""
        return max(0.0, self.free_at - t)


class ParamDB:
    """Replicated parameter store (the SQLite analogue).

    Every write publishes on 'params/<key>'; every node holds the same view
    (synchronous replication — the paper's update-triggers-update semantics).
    """

    def __init__(self, bus: Bus) -> None:
        self._bus = bus
        self._store: Dict[str, Any] = {}
        self.writes = 0

    def put(self, key: str, value: Any) -> None:
        self._store[key] = value
        self.writes += 1
        self._bus.publish(f"params/{key}", value, nbytes=8)

    def get(self, key: str, default: Any = None) -> Any:
        return self._store.get(key, default)

    def snapshot(self) -> Dict[str, Any]:
        return dict(self._store)
