"""Query-submission API + admission control (the serving front door).

The paper's prototype is a *serving system*: operators submit continuous
queries (CQs) against a live camera fleet, and the cloud fine-tunes /
ships a CQ model per query before the fleet can answer it.  This module
is the control-plane surface in front of that machinery:

  * ``TierSpec`` / ``TenantSpec`` — the scenario-level declarations of
    priority tiers (an SLO + an Eq. 7 pressure weight) and per-tenant
    submission quotas.
  * ``TokenBucket`` — the classic rate limiter the per-tenant quota runs
    on (simulated-clock driven: refill is computed from the event time,
    never from the wall clock, so admission verdicts are deterministic).
  * ``AdmissionController`` — the admit/shed decision at ``QueryArrival``:
    quota first, then shed on cloud fine-tune backlog in *reverse tier
    order* (tier 0 — the top tier — is backlog-exempt; each lower tier's
    backlog allowance halves, so under rush-hour load the low tiers shed
    first and the top tier keeps training headroom).
  * ``QueryAPI`` — submit/status/retire against a live pipeline, used by
    the asyncio driver (``serving.engine.AsyncDriver``) to inject queries
    mid-run; scenario-declared arrivals go through the same admission
    path, so simulated and live submissions are indistinguishable to the
    engine.

Nothing here imports the ``system/`` layer: the pipeline composes these
pieces, not the other way round.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class TierSpec:
    """One priority tier of the serving control plane.

    ``slo_s`` is the tier's end-to-end answer-latency objective;
    ``weight`` is the Eq. 7 / Eqs. 8-9 SLO-pressure gain: an item whose
    remaining slack is smaller than a node's drain time pays
    ``weight * (drain - slack)`` extra cost on that node, steering urgent
    work toward nodes that can still make the deadline (weight 0 keeps
    the allocator bit-identical to the tierless engine)."""
    tier: int
    name: str = ""
    slo_s: float = 5.0
    weight: float = 0.0

    def __post_init__(self):
        if self.tier < 0:
            raise ValueError(f"tier {self.tier} must be >= 0")
        if self.slo_s <= 0:
            raise ValueError(f"tier {self.tier}: slo_s={self.slo_s} "
                             f"must be positive")
        if self.weight < 0:
            raise ValueError(f"tier {self.tier}: weight={self.weight} "
                             f"must be >= 0")


@dataclasses.dataclass(frozen=True)
class TenantSpec:
    """Per-tenant submission quota: a token bucket of ``burst`` capacity
    refilling at ``rate`` queries/second of simulated time."""
    tenant: str
    rate: float
    burst: int = 1

    def __post_init__(self):
        if not self.tenant:
            raise ValueError("tenant name must be non-empty")
        if self.rate <= 0:
            raise ValueError(f"tenant {self.tenant!r}: rate={self.rate} "
                             f"must be positive")
        if self.burst < 1:
            raise ValueError(f"tenant {self.tenant!r}: burst={self.burst} "
                             f"must be >= 1")


class TokenBucket:
    """Simulated-clock token bucket (refill from event time deltas)."""

    def __init__(self, rate: float, burst: int):
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)
        self._last_t = 0.0

    def take(self, t: float) -> bool:
        """Consume one token at simulated time ``t``; False if empty."""
        if t > self._last_t:
            self.tokens = min(self.burst,
                              self.tokens + (t - self._last_t) * self.rate)
            self._last_t = t
        if self.tokens >= 1.0:
            self.tokens -= 1.0
            return True
        return False


#: tier ``k >= 1`` sheds once the cloud's fine-tune backlog exceeds
#: ``backlog_limit_s * BACKLOG_TIER_DECAY ** (k - 1)`` — each lower tier
#: tolerates half the backlog of the one above it, so overload sheds
#: bottom-up.  Tier 0 is backlog-exempt (quota still applies).
BACKLOG_TIER_DECAY = 0.5


class AdmissionController:
    """The admit/shed verdict at query submission time.

    Returns ``None`` to admit, or a shed reason (``"quota"`` /
    ``"backlog"``) — the caller publishes the matching
    ``alerts/admission/<reason>`` event and marks the query shed.  Order
    matters: quota is charged first (a tenant flooding the API burns its
    own bucket even when the cloud is idle), backlog second."""

    def __init__(self, tenants: Tuple[TenantSpec, ...] = (),
                 backlog_limit_s: Optional[float] = None):
        self.backlog_limit_s = backlog_limit_s
        self._buckets: Dict[str, TokenBucket] = {
            tn.tenant: TokenBucket(tn.rate, tn.burst) for tn in tenants}
        self.admitted = 0
        self.shed: Dict[str, int] = {}

    def backlog_limit(self, tier: int) -> float:
        """This tier's backlog allowance in seconds (inf for tier 0)."""
        if tier <= 0 or self.backlog_limit_s is None:
            return float("inf")
        return self.backlog_limit_s * BACKLOG_TIER_DECAY ** (tier - 1)

    def admit(self, t: float, tenant: str, tier: int,
              backlog_s: float) -> Optional[str]:
        bucket = self._buckets.get(tenant)
        if bucket is not None and not bucket.take(t):
            self.shed["quota"] = self.shed.get("quota", 0) + 1
            return "quota"
        if backlog_s > self.backlog_limit(tier):
            self.shed["backlog"] = self.shed.get("backlog", 0) + 1
            return "backlog"
        self.admitted += 1
        return None


@dataclasses.dataclass
class SubmitResult:
    """One submission's outcome: ``admitted`` or ``shed:<reason>``."""
    query: int
    verdict: str

    @property
    def admitted(self) -> bool:
        return self.verdict == "admitted"


class QueryAPI:
    """Submit/status/retire against a live pipeline.

    Built for the asyncio driver: a ``serve_demo``-style client schedules
    ``submit`` calls via ``AsyncDriver.call_at`` and the query enters the
    SAME ``QueryArrival`` -> admission -> fine-tune -> ship -> serve path
    the scenario-declared queries take.  The admission verdict is not
    known at submit time (it is decided when the arrival event pops);
    poll ``status`` or read ``log`` after the run."""

    def __init__(self, pipe):
        self._pipe = pipe
        self.log: List[SubmitResult] = []

    def submit(self, t: float, spec) -> SubmitResult:
        """Register ``spec`` and enqueue its arrival at ``max(t,
        spec.t_arrive_s)``.  Raises ``ValueError`` on a duplicate id."""
        from repro.system.events import QueryArrival
        self._pipe.register_query(spec)
        self._pipe.events.push(max(t, spec.t_arrive_s),
                               QueryArrival(spec.query, spec.kind))
        res = SubmitResult(spec.query, "submitted")
        self.log.append(res)
        return res

    def status(self, query: int) -> str:
        """``unknown | pending | shed | training | live | retired``."""
        qs = self._pipe.queries
        if query not in qs.specs:
            return "unknown"
        if qs.is_shed(query):
            return "shed"
        if qs.is_retired(query):
            return "retired"
        if qs.live_edges.get(query):
            return "live"
        if query in qs.train_s:
            return "training"
        return "pending"

    def retire(self, t: float, query: int) -> None:
        """Enqueue the query's retirement at ``t`` (idempotent: retiring
        a shed or already-retired query is a no-op at the handler)."""
        from repro.system.events import QueryRetire
        if query not in self._pipe.queries.specs:
            raise ValueError(f"unknown query {query}")
        self._pipe.events.push(t, QueryRetire(query))
