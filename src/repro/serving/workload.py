"""Workload construction: synthetic camera streams -> scored detection items.

Runs the *actual* offline/online SurveilEdge pipeline end to end:
  1. offline: leisure-time labels -> camera profiles -> K-means clusters
  2. online: CQ-specific fine-tuning of the edge model per cluster
  3. stream: per-camera Poisson arrivals (periodic busy profiles) scored by
     the trained edge model -> `Item` stream for the simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import finetune as FT
from repro.core import profiles as PR
from repro.core.cascade import confidence_from_logits
from repro.data import synthetic_video as SV
from repro.models import meta as M
from repro.models import transformer as T
from repro.serving.simulator import Item


@dataclasses.dataclass
class Workload:
    items: List[Item]
    edge_params: object
    edge_cfg: object
    clusters: np.ndarray
    edge_accuracy: float


def _binary_batches(rng, cfg, cluster_profile, labels_pool, query_class,
                    batch: int = 64):
    """Infinite iterator of CQ fine-tuning batches (tokens, binary labels)."""
    classes = np.arange(SV.NUM_CLASSES)
    neg_w = cluster_profile.copy()
    neg_w[query_class] = 0
    neg_w = np.maximum(neg_w, 1e-6)
    neg_w /= neg_w.sum()
    while True:
        is_pos = rng.random(batch) < 0.5
        cls = np.where(is_pos, query_class,
                       rng.choice(classes, size=batch, p=neg_w))
        tokens, _ = SV.labeled_crop_batch(cls, rng, cfg.vocab_size)
        yield jnp.asarray(tokens), jnp.asarray(is_pos.astype(np.int32))


def build_workload(*, num_cameras: int = 8, num_edges: int = 3,
                   duration_s: float = 240.0, interval_s: float = 1.0,
                   query_class: int = SV.QUERY_CLASS,
                   arch: str = "surveiledge-cls",
                   finetune_steps: int = 60,
                   seed: int = 0) -> Workload:
    rng = np.random.default_rng(seed)
    cams = SV.make_cameras(num_cameras, seed=seed)

    # --- offline stage: profiles + clustering ------------------------------
    leisure = {c.cam_id: rng.choice(SV.NUM_CLASSES, size=400, p=c.class_mix)
               for c in cams}
    cam_ids, profs = PR.build_profiles(leisure, SV.NUM_CLASSES)
    assign, centers = PR.cluster_cameras(profs, k=2)

    # --- online stage: CQ-specific fine-tune (cluster 0's model is used for
    # all cameras of that cluster; for the workload we fine-tune one model on
    # the majority cluster's profile, as the paper does per query) -----------
    full_cfg = get_config(arch)
    edge_cfg = dataclasses.replace(
        full_cfg.edge_variant(), num_query_classes=2,
        vocab_size=full_cfg.vocab_size)
    maj = int(np.argmax(np.bincount(assign)))
    profile = centers[maj]
    key = jax.random.PRNGKey(seed)
    pre = M.init_params(edge_cfg, key)
    ev_tokens, ev_labels = next(_binary_batches(
        np.random.default_rng(seed + 99), edge_cfg, profile, None, query_class,
        batch=256))
    res = FT.finetune(
        edge_cfg, pre,
        _binary_batches(rng, edge_cfg, profile, None, query_class),
        steps=finetune_steps, lr=1e-3, eval_set=(ev_tokens, ev_labels))

    # --- stream: arrivals + edge confidences --------------------------------
    @jax.jit
    def conf_fn(params, tokens):
        h, _ = T.forward(edge_cfg, params, tokens, remat=False)
        return confidence_from_logits(T.classify(edge_cfg, params, h), 1)

    items: List[Item] = []
    pending: List[Tuple[float, int, int, int]] = []   # (t, cam, edge, cls)
    for t in np.arange(0.0, duration_s, interval_s):
        for cam in cams:
            n = rng.poisson(cam.rate_at(t) * interval_s)
            for _ in range(int(n)):
                cls = int(rng.choice(SV.NUM_CLASSES, p=cam.class_mix))
                pending.append((float(t + rng.uniform(0, interval_s)),
                                cam.cam_id, cam.cam_id % num_edges + 1, cls))
    # batch-score all detections with the trained edge model
    all_cls = [p[3] for p in pending]
    BATCH = 256
    confs = np.zeros(len(pending))
    for i in range(0, len(pending), BATCH):
        cls_chunk = all_cls[i:i + BATCH]
        tokens, _ = SV.labeled_crop_batch(cls_chunk, rng, edge_cfg.vocab_size)
        confs[i:i + len(cls_chunk)] = np.asarray(
            conf_fn(res.params, jnp.asarray(tokens)))
    for (t, cam, edge, cls), cf in zip(pending, confs):
        items.append(Item(t_arrival=t, camera=cam, edge_device=edge,
                          conf=float(cf), is_query=(cls == query_class)))
    items.sort(key=lambda x: x.t_arrival)
    return Workload(items=items, edge_params=res.params, edge_cfg=edge_cfg,
                    clusters=assign, edge_accuracy=res.accuracy)
