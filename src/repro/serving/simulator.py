"""Event-driven cloud-edge serving simulator (evaluation substrate).

Reproduces the paper's experimental setups (Tables II-IV) with calibrated
per-node service-time distributions.  Four schemes:

  surveiledge        task scheduling (Eq. 7) + adaptive thresholds (Eqs. 8-9)
  surveiledge_fixed  local-edge-first, constant alpha=0.8 / beta=0.1
  edge_only          CQ-specific model only, no escalation
  cloud_only         every detection uploaded + classified by the cloud model

The workload is a stream of *detections* (from the synthetic video pipeline)
with a precomputed edge confidence and ground-truth label per item; the
cloud classifier is treated as ground truth exactly as the paper treats
ResNet-152.  Latency = queueing + service + (for uploads) transmission;
bandwidth = bytes shipped to the cloud.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.latency import LatencyEstimator
from repro.core.scheduler import CLOUD, Scheduler
from repro.core.scoring import f_score as _f_score
from repro.core.thresholds import ThresholdState
from repro.serving.bus import Bus, FifoLink, ParamDB


@dataclasses.dataclass
class Item:
    """One detected object entering the query system."""
    t_arrival: float
    camera: int
    edge_device: int          # home edge of the camera
    conf: float               # edge-model confidence (precomputed)
    is_query: bool            # ground truth
    nbytes: int = 3 * 128 * 128  # crop payload (~49 KB, 128x128 RGB)
    query: int = 0            # which continuous query (CQ) scored this crop
    # cross-camera track queries (QuerySpec.kind == "track") only; both
    # default inert so every classify-path construction is unchanged
    emb: Optional[np.ndarray] = None   # L2-normalizable re-ID embedding
    gt_track: int = -1        # ground-truth trajectory id (-1: untracked)


@dataclasses.dataclass
class NodeSpec:
    node_id: int
    service_s: float                    # mean per-item inference time
    jitter: float = 0.15                # lognormal sigma


@dataclasses.dataclass
class LinkSpec:
    uplink_MBps: float = 2.0            # edge -> cloud
    rtt_s: float = 0.05


@dataclasses.dataclass
class SimResult:
    scheme: str
    latencies: np.ndarray               # per item (seconds)
    decisions: np.ndarray               # bool
    truths: np.ndarray                  # bool
    uploaded_bytes: int
    escalated: int
    per_node_busy: Dict[int, float]
    trace: List[Tuple[float, int, float]]      # (t, node, latency)

    # --- metrics --------------------------------------------------------------
    def f_score(self, lam: float = 2.0) -> float:
        return _f_score(self.decisions, self.truths, lam)

    @property
    def avg_latency(self) -> float:
        return float(np.mean(self.latencies)) if len(self.latencies) else 0.0

    @property
    def p99_latency(self) -> float:
        return float(np.percentile(self.latencies, 99)) if len(self.latencies) else 0.0

    @property
    def latency_var(self) -> float:
        return float(np.var(self.latencies)) if len(self.latencies) else 0.0

    def summary(self) -> Dict[str, float]:
        return {
            "scheme": self.scheme,
            "accuracy_F2": round(self.f_score(2.0), 4),
            "avg_latency_s": round(self.avg_latency, 3),
            "p99_latency_s": round(self.p99_latency, 3),
            "latency_var": round(self.latency_var, 3),
            "bandwidth_MB": round(self.uploaded_bytes / 1e6, 2),
            "escalated": self.escalated,
        }


class CloudEdgeSim:
    """Discrete-event simulation of N edge nodes + 1 cloud node."""

    def __init__(self, edges: Sequence[NodeSpec], cloud: NodeSpec,
                 link: LinkSpec, *, scheme: str,
                 interval_s: float = 1.0, seed: int = 0,
                 fixed_thresholds: Optional[Tuple[float, float]] = None):
        assert cloud.node_id == CLOUD
        self.scheme = scheme
        self.link = link
        self.interval_s = interval_s
        self.rng = np.random.default_rng(seed)
        self.specs: Dict[int, NodeSpec] = {cloud.node_id: cloud}
        for e in edges:
            self.specs[e.node_id] = e
        self.bus = Bus()
        self.db = ParamDB(self.bus)
        self.sched = Scheduler(sorted(self.specs),
                               interval_s=interval_s,
                               thresholds=ThresholdState())
        if scheme == "surveiledge_fixed":
            # frozen at the paper's constants: alpha=0.8, beta=0.1 (or a
            # caller-supplied pair, for the threshold-ablation benchmark)
            a, b = fixed_thresholds or (0.8, 0.1)
            self.sched.thresholds = ThresholdState(
                alpha=a, beta=b, gamma1=0.0,
                gamma2=b / max(1.0 - a, 1e-6))
        # publish initial params (mirrors the SQLite bootstrap)
        for nid in self.specs:
            self.db.put(f"t{nid}", self.specs[nid].service_s)
            self.db.put(f"Q{nid}", 0)

    # --------------------------------------------------------------------------
    def _service_time(self, node: int) -> float:
        spec = self.specs[node]
        return float(spec.service_s *
                     self.rng.lognormal(0.0, spec.jitter))

    def _tx_done(self, t: float, nbytes: int) -> float:
        """Shared WAN uplink: a FIFO resource — uploads serialize.

        This is what makes cloud-only slow in the paper (Table II): the
        uplink saturates and upload queueing dominates end-to-end latency.
        """
        return self._uplink.send(t, nbytes)

    def run(self, items: Sequence[Item]) -> SimResult:
        """Discrete-event loop: arrivals are scheduled with the *current*
        queue/latency state (Eq. 7 semantics), service completions free
        their node and pull the next queued task (FIFO)."""
        scheme = self.scheme
        queues: Dict[int, List] = {nid: [] for nid in self.specs}
        node_busy: Dict[int, bool] = {nid: False for nid in self.specs}
        busy_time = {nid: 0.0 for nid in self.specs}
        lat: List[float] = []
        dec: List[bool] = []
        tru: List[bool] = []
        trace: List[Tuple[float, int, float]] = []
        self._uploaded = 0
        self._escalated = 0
        self._cloud_tx: Dict[int, float] = {}

        pq: List = []   # (time, seq, kind, payload)
        self._seq = 0
        self._uplink = FifoLink(self.link.uplink_MBps, self.link.rtt_s)

        def push(t, kind, payload):
            self._seq += 1
            heapq.heappush(pq, (t, self._seq, kind, payload))

        def start_service(t, node):
            it, phase = queues[node].pop(0)
            node_busy[node] = True
            svc = self._service_time(node)
            busy_time[node] += svc
            push(t + svc, "done", (it, node, phase, svc))

        def enqueue(t, node, it, phase):
            queues[node].append((it, phase))
            self.sched.on_enqueue(node)
            self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
            if not node_busy[node]:
                start_service(t, node)

        def finish(t, it, accept: bool, node: int):
            lat.append(t - it.t_arrival)
            dec.append(accept)
            tru.append(it.is_query)
            trace.append((it.t_arrival, node, t - it.t_arrival))

        for it in sorted(items, key=lambda x: x.t_arrival):
            push(it.t_arrival, "arrive", it)

        while pq:
            t, _, kind, payload = heapq.heappop(pq)
            if kind == "arrive":
                it = payload
                if scheme == "cloud_only":
                    self._uploaded += it.nbytes
                    push(self._tx_done(t, it.nbytes), "at_cloud", (it, t))
                elif scheme == "surveiledge":
                    node = self.sched.select_node()
                    if node == CLOUD:
                        self._uploaded += it.nbytes
                        push(self._tx_done(t, it.nbytes), "at_cloud", (it, t))
                    else:
                        enqueue(t, node, it, "edge")
                else:
                    enqueue(t, it.edge_device, it, "edge")
            elif kind == "at_cloud":
                it, t_submit = payload
                # cloud t_i estimate includes transmission (paper lumps the
                # upload into the cloud's per-item cost)
                self._cloud_tx[id(it)] = t - t_submit
                enqueue(t, CLOUD, it, "cloud")
            elif kind == "done":
                it, node, phase, svc = payload
                node_busy[node] = False
                obs = svc + self._cloud_tx.pop(id(it), 0.0) \
                    if phase == "cloud" else svc
                self.sched.on_complete(node, obs)
                self.db.put(f"t{node}", self.sched.nodes[node].estimator.t)
                self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
                if phase == "cloud":
                    # ground-truth classifier (paper: ResNet-152 == truth)
                    finish(t, it, it.is_query, node)
                elif scheme == "edge_only":
                    finish(t, it, it.conf > 0.5, node)
                else:
                    route = self.sched.thresholds.triage(it.conf)
                    if route == "escalate":
                        self._escalated += 1
                        self._uploaded += it.nbytes
                        push(self._tx_done(t, it.nbytes), "at_cloud", (it, t))
                    else:
                        finish(t, it, route == "accept", node)
                if queues[node]:
                    start_service(t, node)

        return SimResult(
            scheme=scheme,
            latencies=np.asarray(lat),
            decisions=np.asarray(dec, bool),
            truths=np.asarray(tru, bool),
            uploaded_bytes=self._uploaded,
            escalated=self._escalated,
            per_node_busy=busy_time,
            trace=trace,
        )
