"""Roofline analysis (deliverable g).

Three terms per (arch x shape x mesh), in seconds per step:

  compute    = FLOPs            / (chips x 197e12  bf16 FLOP/s)
  memory     = HBM bytes        / (chips x 819e9   B/s)
  collective = collective bytes / (chips x 50e9    B/s per ICI link)

Two sources, both reported:

  * analytic — exact matmul/state-update accounting from the config and the
    sharding design (formulas below).  This is the primary number: the
    XLA-CPU backend (the only one available here) undercounts `while`-loop
    bodies in cost_analysis (bodies are visited once, not trip-count times)
    and inflates memory via bf16->f32 legalization, so the compiled numbers
    are recorded as secondary evidence.
  * compiled — cost_analysis()/HLO-parse from the dry-run artifact
    (per-iteration loop bodies counted once; see EXPERIMENTS.md caveats).
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Dict, Optional

from repro.configs.shapes import (INPUT_SHAPES, InputShape, attn_cache_len,
                                  decode_window)
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.models.config import ModelConfig

BYTES = 2          # bf16


# --- analytic FLOPs -------------------------------------------------------------

def flops_per_token(cfg: ModelConfig, ctx_len: int,
                    window: Optional[int] = None) -> float:
    """Forward matmul FLOPs for ONE token with `ctx_len` visible context."""
    D, hd = cfg.d_model, cfg.head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    eff_ctx = min(ctx_len, window) if window else ctx_len
    per_layer = 0.0
    if cfg.has_attn:
        per_layer += 2 * D * (H + 2 * KV) * hd          # qkv proj
        per_layer += 2 * H * hd * D                     # out proj
        per_layer += 4 * eff_ctx * H * hd               # qk^T + pv
    if cfg.has_ssm:
        d_in, nh, G, N = (cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_ngroups,
                          cfg.ssm_state)
        per_layer += 2 * D * (2 * d_in + 2 * G * N + nh)   # in projs
        per_layer += 2 * cfg.ssm_conv * (d_in + 2 * G * N)  # conv
        per_layer += 6 * nh * hd_ssm(cfg) * N              # state upd + out
        per_layer += 2 * d_in * D                          # out proj
    if cfg.d_ff > 0:
        gate = 3 if cfg.mlp_act == "silu" else 2
        e = cfg.top_k if cfg.is_moe else 1
        per_layer += 2 * gate * D * cfg.d_ff * e
        if cfg.is_moe:
            per_layer += 2 * D * cfg.num_experts        # router
    total = cfg.num_layers * per_layer
    total += 2 * D * cfg.vocab_size                     # lm head
    if cfg.is_encdec:
        # cross attention per decoder layer
        total += cfg.num_layers * (4 * D * H * hd + 4 * cfg.enc_seq * H * hd)
    return total


def hd_ssm(cfg: ModelConfig) -> int:
    return cfg.ssm_headdim


def encoder_flops(cfg: ModelConfig, batch: int) -> float:
    if not cfg.is_encdec:
        return 0.0
    D, H, hd, S = cfg.d_model, cfg.num_heads, cfg.head_dim, cfg.enc_seq
    per_layer = 8 * D * H * hd + 4 * S * H * hd + 4 * D * cfg.d_ff
    return batch * S * cfg.num_enc_layers * per_layer


def analytic_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Global FLOPs for one step of this (cfg, shape)."""
    B, S = shape.global_batch, shape.seq_len
    w = decode_window(cfg, shape)
    if shape.kind == "decode":
        return B * flops_per_token(cfg, S, w) + encoder_flops(cfg, 0)
    # prefill/train: sum over positions of causal context ~ S/2 average
    avg_ctx = (S + 1) / 2
    fwd = B * S * flops_per_token(cfg, avg_ctx, w) + encoder_flops(cfg, B)
    return 3 * fwd if shape.kind == "train" else fwd


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """The 6·N·D convention (active params for MoE)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "decode":
        tokens = shape.global_batch
        return 2 * n * tokens            # fwd only
    tokens = shape.global_batch * shape.seq_len
    return (6 if shape.kind == "train" else 2) * n * tokens


# --- analytic HBM bytes -----------------------------------------------------------

def analytic_hbm_bytes(cfg: ModelConfig, shape: InputShape, chips: int,
                       two_d_serve: bool) -> float:
    """Per-chip HBM traffic per step x chips (global bytes)."""
    params_b = cfg.param_count() * BYTES
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.num_layers
    if shape.kind == "decode":
        cache_len = attn_cache_len(cfg, shape)
        cache_b = 0.0
        if cfg.has_attn:
            cache_b += 2 * L * B * cache_len * cfg.num_kv_heads * cfg.head_dim * BYTES
        if cfg.has_ssm:
            cache_b += L * B * cfg.ssm_heads * cfg.ssm_headdim * cfg.ssm_state * 4
        if cfg.is_encdec:
            cache_b += 2 * L * B * cfg.enc_seq * cfg.num_kv_heads * cfg.head_dim * BYTES
        # every decode step reads all (sharded) weights + reads cache + writes
        # the new slot (~read-dominated)
        return params_b + cache_b + B * D * L * BYTES * 8
    tokens = B * S
    act = tokens * D * L * BYTES * 12          # activations r/w along the stack
    weights = params_b * (3 if shape.kind == "train" else 1)
    if shape.kind == "train":
        weights += cfg.param_count() * 4 * 3   # f32 m, v read+write + grads
    return weights + act


# --- analytic collective bytes ------------------------------------------------------

def analytic_collective_bytes(cfg: ModelConfig, shape: InputShape,
                              data_shards: int, tp: int,
                              two_d_serve: bool, microbatches: int) -> float:
    """Global bytes crossing ICI per step, from the sharding design:

      train:  grad reduce-scatter + FSDP weight all-gathers (fwd+bwd)
              + TP/seq-parallel activation collectives per layer
      serve:  TP all-reduces per layer (+ 2-D weight gathers if enabled)
      moe:    all-to-all of dispatched tokens, both directions
    """
    params_b = cfg.param_count() * BYTES
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, cfg.num_layers
    tokens = B * S if shape.kind != "decode" else B
    coll = 0.0
    if shape.kind == "train":
        coll += 2 * params_b                      # grad RS + param AG (FSDP)
        coll += 2 * params_b * microbatches       # weight AG per microbatch fwd+bwd
        coll += 4 * tokens * D * BYTES * L        # seq-par <-> TP boundary per layer
    else:
        passes = 1
        coll += 2 * tokens * D * BYTES * L        # TP all-reduce fwd per layer
        if two_d_serve:
            coll += params_b / tp * passes        # 2-D weight all-gather per chip row
    if cfg.is_moe:
        coll += 2 * tokens * cfg.top_k * D * BYTES * (2 if shape.kind == "train" else 1)
    return coll


# --- assembly -------------------------------------------------------------------------

@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    analytic_flops: float
    compiled_flops: float
    compiled_coll_bytes: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / max(self.analytic_flops, 1.0)


def analyze(cfg: ModelConfig, shape: InputShape, *, chips: int = 256,
            tp: int = 16, mesh_name: str = "single",
            dryrun_record: Optional[Dict[str, Any]] = None) -> Roofline:
    data_shards = chips // tp
    two_d = cfg.param_count() * BYTES / tp > 2e9
    micro = 1
    if shape.kind == "train":
        from repro.train.steps import default_microbatches
        micro = default_microbatches(cfg, shape.global_batch, data_shards)
    fl = analytic_flops(cfg, shape)
    hbm = analytic_hbm_bytes(cfg, shape, chips, two_d)
    coll = analytic_collective_bytes(cfg, shape, data_shards, tp, two_d, micro)
    rec = dryrun_record or {}
    compiled_fl = float(rec.get("cost", {}).get("flops", 0.0)) * chips
    compiled_coll = float(rec.get("collectives", {}).get("total_bytes", 0.0))
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh_name, chips=chips,
        compute_s=fl / (chips * PEAK_FLOPS_BF16),
        memory_s=hbm / (chips * HBM_BW),
        collective_s=coll / (chips * ICI_BW),
        model_flops=model_flops(cfg, shape),
        analytic_flops=fl,
        compiled_flops=compiled_fl,
        compiled_coll_bytes=compiled_coll,
    )


# --- pixel-cascade kernels ------------------------------------------------------------

#: int32 element size of the pixel kernels' frames and masks
PIXEL_BYTES = 4

#: approximate integer ops per pixel for each pixel-cascade stage:
#: framediff = 3ch x (2 sub/abs + and) + 3 mul + 2 add + 1 div + 1 cmp/select;
#: each 3x3 morphology stage = 8 max/min reductions
PIXEL_FLOPS = {"framediff": 16.0, "dilate": 8.0, "erode": 8.0}


@dataclasses.dataclass
class PixelRoofline:
    """Analytic bytes/FLOPs roofline for one pixel-frontend variant.

    ``roofline_fraction`` is the fraction of peak compute the kernel's
    arithmetic intensity admits on the reference TPU roofline
    (min(1, AI / ridge), ridge = peak FLOP/s over HBM B/s) — below 1.0
    the kernel is bandwidth-bound and bytes, not launches, are the cost.
    """
    name: str
    hbm_bytes: float
    flops: float

    @property
    def ai(self) -> float:
        return self.flops / max(self.hbm_bytes, 1.0)

    @property
    def ridge(self) -> float:
        return PEAK_FLOPS_BF16 / HBM_BW

    @property
    def roofline_fraction(self) -> float:
        return min(1.0, self.ai / self.ridge)

    def to_row(self) -> Dict[str, float]:
        return {"hbm_bytes": self.hbm_bytes, "flops": self.flops,
                "ai_flops_per_byte": round(self.ai, 4),
                "roofline_fraction": round(self.roofline_fraction, 6)}


def pixel_cascade_roofline(batch: int, h: int, w: int, *, fused: bool
                           ) -> PixelRoofline:
    """Analytic HBM traffic + ops of one tick's pixel frontend.

    Both variants read the three (B, H, W, 3) int32 frames and write the
    final (B, H, W) mask.  The staged chain additionally round-trips the
    framediff and dilated masks through HBM — two extra full-frame writes
    and two extra reads — which is exactly the traffic the fused kernel's
    VMEM-resident band pipeline deletes.  FLOPs are identical by
    construction (same stencil math, one implementation).
    """
    px = batch * h * w
    frames = 3 * px * 3 * PIXEL_BYTES          # three RGB int32 frames in
    mask = px * PIXEL_BYTES                    # final mask out
    flops = px * sum(PIXEL_FLOPS.values())
    if fused:
        return PixelRoofline("pixel_cascade_fused", frames + mask, flops)
    # staged: framediff out + dilate in/out + erode in (4 extra passes)
    return PixelRoofline("pixel_cascade_staged",
                         frames + mask + 4 * mask, flops)


def load_dryrun(out_dir: str, arch: str, shape: str, mesh: str
                ) -> Optional[Dict[str, Any]]:
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh}.json")
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return None
