"""Training launcher.

On this CPU host it trains the reduced variant of any assigned architecture
on synthetic token streams (the ~100M-scale end-to-end driver); on a real
TPU mesh, drop --reduced and pass --mesh single|multi to train the full
config with the same code path the dry-run compiles.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \\
      --reduced --steps 200 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import checkpoint as CK
from repro.configs import get_config
from repro.configs.shapes import INPUT_SHAPES
from repro.distributed import sharding as SH
from repro.launch import mesh as MESH
from repro.models import meta as M
from repro.optim import adamw, schedules
from repro.train import steps as ST


from repro.data.loader import LoaderConfig, host_batches  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="train the smoke-scale variant (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--mesh", choices=["host", "single", "multi"],
                    default="host")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    print(f"[train] {cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"B={args.batch} S={args.seq} steps={args.steps}")

    if args.mesh == "host":
        mesh = MESH.make_host_mesh()
    else:
        mesh = MESH.make_production_mesh(multi_pod=(args.mesh == "multi"))

    opt_cfg = adamw.AdamWConfig(
        lr=args.lr, schedule=schedules.cosine_with_warmup(
            max(args.steps // 10, 1), args.steps))
    ctx = SH.ActCtx(cfg, mesh)
    step_fn = ST.make_train_step(cfg, opt_cfg, remat=True,
                                 microbatches=args.microbatches, ctx=ctx)

    key = jax.random.PRNGKey(0)
    with mesh:
        params = M.init_params(cfg, key)
        state = ST.TrainState(params, adamw.init(params),
                              jnp.zeros((), jnp.int32))
        jit_step = jax.jit(step_fn, donate_argnums=(0,))
        data = host_batches(
            cfg, LoaderConfig(global_batch=args.batch, seq_len=args.seq),
            host_id=jax.process_index(), num_hosts=jax.process_count())
        t0 = time.time()
        for step in range(args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(data).items()}
            state, metrics = jit_step(state, batch)
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])
                tps = args.batch * args.seq * (step + 1) / (time.time() - t0)
                print(f"  step {step:5d} loss={loss:8.4f} "
                      f"gnorm={float(metrics['grad_norm']):8.3f} "
                      f"tok/s={tps:9.0f}")
        if args.checkpoint:
            CK.save(args.checkpoint, state.params, step=args.steps)
            print(f"[train] checkpoint -> {args.checkpoint}")
    final = float(metrics["loss"])
    print(f"[train] done: final loss {final:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
