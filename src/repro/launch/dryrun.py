import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
# ^ MUST run before any jax import: jax locks the device count at first init.

DOC = """Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) combo.

For each combination this builds the exact jitted step the launcher would run
(train_step / prefill_step / decode_step), with explicit in/out shardings on
the production mesh, compiles it with ShapeDtypeStructs only (no allocation),
and records:

  * memory_analysis()      -> bytes per device (proves it fits)
  * cost_analysis()        -> per-device FLOPs / bytes for the roofline
  * collective inventory   -> parsed from the optimized HLO

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import json
import re
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.configs.shapes import (INPUT_SHAPES, InputShape, attn_cache_len,
                                  decode_window, input_specs)
from repro.distributed import sharding as SH
from repro.launch import mesh as MESH
from repro.models import meta as M
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw
from repro.train import steps as ST

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def parse_collectives(hlo: str) -> Dict[str, Any]:
    """Sum result-shape bytes of every collective op in optimized HLO.

    Returns {kind: {count, bytes}} plus 'total_bytes' (sum over kinds,
    all-reduce counted twice: reduce + broadcast phases of a ring).
    """
    out: Dict[str, Any] = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    shape_re = re.compile(r"(\w+)\[([0-9,]*)\]")
    for line in hlo.splitlines():
        s = line.strip()
        if s.startswith("%") or s.startswith("ROOT"):
            m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", s)
            if not m:
                continue
            rest = m.group(1)
            kind = next((k for k in COLLECTIVES
                         if re.search(rf"\b{k}(-start|-done)?\(", rest)), None)
            if kind is None or f"{kind}-done(" in rest:
                continue
            # result type(s): everything before the op name
            head = rest.split(f" {kind}", 1)[0] if f" {kind}" in rest else rest
            nbytes = 0
            for dt, dims in shape_re.findall(head):
                if dt not in DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * DTYPE_BYTES[dt]
            mult = 2 if kind == "all-reduce" else 1
            out[kind]["count"] += 1
            out[kind]["bytes"] += nbytes * mult
    out["total_bytes"] = sum(v["bytes"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


def _mem_analysis(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
        arg = float(getattr(ma, "argument_size_in_bytes", 0))
        out = float(getattr(ma, "output_size_in_bytes", 0))
        tmp = float(getattr(ma, "temp_size_in_bytes", 0))
        alias = float(getattr(ma, "alias_size_in_bytes", 0))
        return {
            "argument_bytes": arg, "output_bytes": out, "temp_bytes": tmp,
            "alias_bytes": alias,
            # donated outputs alias their inputs: don't double-count
            "peak_bytes": arg + tmp + out - alias,
        }
    except Exception as e:                         # pragma: no cover
        return {"error": str(e)}


def _cost_analysis(compiled) -> Dict[str, float]:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes" in k or k in ("transcendentals",))}
    except Exception as e:                         # pragma: no cover
        return {"error": str(e)}


def build_program(cfg: ModelConfig, shape: InputShape, mesh,
                  dtype=jnp.bfloat16, overrides=None):
    """Returns (fn, args_abstract, in_shardings, out_shardings).

    ``overrides`` (perf-iteration knobs, see EXPERIMENTS.md §Perf):
      micro: int            gradient-accumulation factor (train)
      kv_dtype: str         'int8' quantized KV cache (decode)
      remat_policy: str     'dots' | 'dots_no_batch' checkpoint policy
      no_seq_shard: bool    disable sequence-parallel residual sharding
    """
    import dataclasses as _dc
    ov = overrides or {}
    if ov.get("kv_dtype"):
        cfg = _dc.replace(cfg, kv_cache_dtype=ov["kv_dtype"])
    mode = "train" if shape.kind == "train" else "serve"
    ctx = SH.ActCtx(cfg, mesh,
                    seq_shard_resid=not ov.get("no_seq_shard", False),
                    shard_moe_flat=not ov.get("no_moe_flat_shard", False))
    pspecs = SH.param_shardings(cfg, mesh, mode,
                                force_1d_serve=ov.get("serve_1d", False))
    params_abs = M.abstract_params(cfg, dtype)
    if ov.get("quant_weights") and mode == "serve":
        from repro.distributed import quantize as QZ
        pspecs = QZ.quantized_shardings(pspecs, params_abs, cfg, mesh)
        params_abs = QZ.abstract_quantized(params_abs, cfg)
    batch_abs = input_specs(cfg, shape, dtype)
    batch_sh = SH.batch_specs(cfg, mesh, shape.global_batch, batch_abs)
    repl = NamedSharding(mesh, P())

    if shape.kind == "train":
        opt_cfg = adamw.AdamWConfig()
        micro = ov.get("micro") or ST.default_microbatches(
            cfg, shape.global_batch, SH.data_size(mesh))
        fn = ST.make_train_step(cfg, opt_cfg, remat=True,
                                microbatches=micro,
                                remat_policy=ov.get("remat_policy"), ctx=ctx)
        opt_abs = adamw.abstract_state(params_abs)
        opt_sh = adamw.AdamWState(
            count=repl,
            m=jax.tree.map(lambda _, s: s, params_abs, pspecs),
            v=jax.tree.map(lambda _, s: s, params_abs, pspecs))
        state_abs = ST.TrainState(params_abs, opt_abs,
                                  jax.ShapeDtypeStruct((), jnp.int32))
        state_sh = ST.TrainState(pspecs, opt_sh, repl)
        metrics_sh = {k: repl for k in
                      ("lm_loss", "moe_aux", "grad_norm", "lr", "loss")}
        return (fn, (state_abs, batch_abs), (state_sh, batch_sh),
                (state_sh, metrics_sh))

    if shape.kind == "prefill":
        window = decode_window(cfg, shape)
        cache_len = attn_cache_len(cfg, shape)
        fn = ST.make_prefill_step(cfg, cache_len=cache_len, window=window,
                                  ctx=ctx)
        cache_abs = T.make_cache(cfg, shape.global_batch, cache_len,
                                 dtype=dtype, abstract=True)
        cache_sh = SH.cache_specs(cfg, mesh, shape.global_batch, cache_abs)
        logits_sh = NamedSharding(
            mesh, P(SH._batch_spec(mesh, shape.global_batch), None))
        return (fn, (params_abs, batch_abs), (pspecs, batch_sh),
                (logits_sh, cache_sh))

    # decode
    window = decode_window(cfg, shape)
    cache_len = attn_cache_len(cfg, shape)
    fn = ST.make_decode_step(cfg, window=window, ctx=ctx)
    cache_abs = T.make_cache(cfg, shape.global_batch, cache_len,
                             dtype=dtype, abstract=True)
    cache_sh = SH.cache_specs(cfg, mesh, shape.global_batch, cache_abs)
    token_abs = batch_abs["token"]
    token_sh = SH.batch_specs(cfg, mesh, shape.global_batch,
                              {"token": token_abs})["token"]
    logits_sh = NamedSharding(
        mesh, P(SH._batch_spec(mesh, shape.global_batch), None))
    return (fn, (params_abs, cache_abs, token_abs),
            (pspecs, cache_sh, token_sh), (logits_sh, cache_sh))


def dryrun_one(arch: str, shape_name: str, mesh_kind: str,
               verbose: bool = True, overrides=None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = MESH.make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    fn, args, in_sh, out_sh = build_program(cfg, shape, mesh,
                                            overrides=overrides)
    # buffer donation: decode steps donate the KV cache (arg 1), train steps
    # donate the TrainState (arg 0) — standard serving/training practice and
    # required for the 32k x 128 caches to fit per-chip HBM.
    donate = (1,) if shape.kind == "decode" else (
        (0,) if shape.kind == "train" else ())
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = _mem_analysis(compiled)
    cost = _cost_analysis(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    coll = parse_collectives(hlo)
    n_chips = MESH.chips(mesh)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips,
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=True),
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem, "cost": cost, "collectives": coll,
        "window": decode_window(cfg, shape),
    }
    if verbose:
        flops = cost.get("flops", 0.0)
        peak = mem.get("peak_bytes", 0.0)
        print(f"[dryrun] {arch:26s} {shape_name:12s} {mesh_kind:6s} "
              f"chips={n_chips:3d} perdev_flops={flops:.3e} "
              f"peak_dev_bytes={peak/2**30:.2f}GiB "
              f"coll={coll['total_bytes']/2**20:.1f}MiB "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
        print("  memory_analysis:", {k: round(v / 2**20, 1) if isinstance(v, float) else v
                                     for k, v in mem.items()}, "(MiB)")
        print("  cost_analysis:", {k: f"{v:.3e}" for k, v in cost.items()
                                   if isinstance(v, float)})
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=DOC)
    ap.add_argument("--arch", default=None, help="architecture id (or --all)")
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape) combination")
    ap.add_argument("--out", default=None, help="directory for JSON records")
    ap.add_argument("--micro", type=int, default=None,
                    help="override gradient-accumulation factor")
    ap.add_argument("--kv-dtype", default=None, choices=["int8"],
                    help="quantized KV cache")
    ap.add_argument("--remat-policy", default=None,
                    choices=["dots", "dots_no_batch"])
    ap.add_argument("--no-seq-shard", action="store_true",
                    help="disable sequence-parallel residuals")
    ap.add_argument("--no-moe-flat-shard", action="store_true",
                    help="keep MoE dispatch tensors batch-sharded only")
    ap.add_argument("--serve-1d", action="store_true",
                    help="force 1-D TP weights in serve mode (no FSDP gathers)")
    ap.add_argument("--quant-weights", action="store_true",
                    help="serve with int8 weights (per-channel scales)")
    args = ap.parse_args(argv)
    overrides = {"micro": args.micro, "kv_dtype": args.kv_dtype,
                 "remat_policy": args.remat_policy,
                 "no_seq_shard": args.no_seq_shard,
                 "no_moe_flat_shard": args.no_moe_flat_shard,
                 "serve_1d": args.serve_1d,
                 "quant_weights": args.quant_weights}

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for arch in archs:
        for shape in shapes:
            for mk in meshes:
                try:
                    rec = dryrun_one(arch, shape, mk, overrides=overrides)
                    if args.out:
                        os.makedirs(args.out, exist_ok=True)
                        fname = f"{arch.replace('/', '_')}__{shape}__{mk}.json"
                        with open(os.path.join(args.out, fname), "w") as f:
                            json.dump(rec, f, indent=1)
                except Exception as e:
                    failures.append((arch, shape, mk, repr(e)))
                    print(f"[dryrun] FAIL {arch} {shape} {mk}: {e!r}",
                          file=sys.stderr)
    if failures:
        print(f"\n{len(failures)} FAILURES:", file=sys.stderr)
        for f in failures:
            print("  ", *f, file=sys.stderr)
        return 1
    print("\nAll dry-runs compiled successfully.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
