"""Serving launcher: batched prefill+decode with the cascade front-end.

Serves a (reduced, CPU-runnable) model behind the SurveilEdge triage: each
request batch is scored by the edge CQ model; confident requests are answered
at the edge, uncertain ones run the full ("cloud") model decode.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \\
      --requests 32 --decode-steps 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import cascade as C
from repro.core.thresholds import ThresholdState
from repro.models import meta as M
from repro.models import transformer as T
from repro.train import steps as ST


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--decode-steps", type=int, default=8)
    ap.add_argument("--alpha", type=float, default=0.8)
    ap.add_argument("--beta", type=float, default=0.1)
    args = ap.parse_args(argv)

    cloud_cfg = get_config(args.arch).reduced()
    edge_cfg = get_config(args.arch).edge_variant()
    key = jax.random.PRNGKey(0)
    cloud_params = M.init_params(cloud_cfg, key)
    edge_params = M.init_params(edge_cfg, jax.random.PRNGKey(1))
    print(f"[serve] cloud={cloud_cfg.name} ({cloud_cfg.param_count()/1e6:.1f}M) "
          f"edge={edge_cfg.name} ({edge_cfg.param_count()/1e6:.1f}M)")

    B, S = args.requests, args.prompt_len
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S),
                                0, min(edge_cfg.vocab_size,
                                       cloud_cfg.vocab_size))

    # --- edge triage ---------------------------------------------------------
    classify = jax.jit(ST.make_classify_fn(edge_cfg))
    conf = C.confidence_from_logits(classify(edge_params, {"tokens": tokens}))
    th = ThresholdState(alpha=args.alpha, beta=args.beta)
    routes = C.triage(conf, jnp.float32(th.alpha), jnp.float32(th.beta))
    idx, valid, n_esc = C.compact_escalated(routes, capacity=B)
    print(f"[serve] triage: accept={int((routes == 0).sum())} "
          f"reject={int((routes == 1).sum())} escalate={int(n_esc)}")

    # --- cloud decode for escalated requests ----------------------------------
    esc_tokens = jnp.take(tokens, idx, axis=0)
    prefill = jax.jit(lambda p, t: T.prefill(
        cloud_cfg, p, t, cache_len=S + args.decode_steps))
    decode = jax.jit(lambda p, c, t: T.decode_step(cloud_cfg, p, c, t))

    t0 = time.perf_counter()
    logits, cache = prefill(cloud_params, esc_tokens)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    for _ in range(args.decode_steps - 1):
        logits, cache = decode(cloud_params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(generated[-1])
    dt = time.perf_counter() - t0
    print(f"[serve] cloud decoded {int(n_esc)} reqs x {args.decode_steps} "
          f"tokens in {dt:.2f}s "
          f"({int(n_esc) * args.decode_steps / max(dt, 1e-9):.1f} tok/s)")
    gen = jnp.stack(generated, axis=1)
    print(f"[serve] sample continuation (req 0): {np.asarray(gen[0])[:8]}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
