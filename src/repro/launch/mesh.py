"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module does not touch jax device state.  The dry-run launcher
sets XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
import; everything else sees the real single CPU device.

Target hardware: TPU v5e, 256 chips/pod, 2 pods.
  single-pod mesh: (16, 16)      axes ("data", "model")
  multi-pod mesh:  (2, 16, 16)   axes ("pod", "data", "model")
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link (~unidirectional)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Single-device mesh for CPU smoke tests (data=1, model=1)."""
    return jax.make_mesh((1, 1), ("data", "model"),
                         devices=jax.devices()[:1])


def make_fleet_mesh(num_devices: int | None = None) -> Mesh:
    """1-D mesh over the fleet row axis of the scan-superstep launch.

    The surveillance-fleet workload shards along ONE axis — the folded
    (query, edge) row axis of the fused triage slab (rows are mutually
    independent, so the kernel runs shard-local with no collectives; see
    ``repro.distributed.sharding.fleet_specs``).  On CPU this is
    exercised with ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (set by the sharded CI leg); defaults to every visible device."""
    devices = jax.devices()
    n = len(devices) if num_devices is None else num_devices
    return jax.make_mesh((n,), ("fleet",), devices=devices[:n])


def chips(mesh: Mesh) -> int:
    n = 1
    for v in mesh.shape.values():
        n *= v
    return n
