"""Multi-host bring-up for real TPU pod slices.

On a v5e pod slice every host runs the same binary; this module initializes
jax.distributed from the standard TPU environment (or explicit flags),
builds the production mesh over the global device set, and exposes the
host-sharded data-feeding helpers.  The CPU container exercises the same
code paths via the dry-run (which fakes 512 devices); nothing here is
imported by the dry-run so device counts never conflict.
"""
from __future__ import annotations

import argparse
import os
from typing import Optional

import jax

from repro.launch.mesh import make_production_mesh


def initialize(coordinator: Optional[str] = None,
               num_processes: Optional[int] = None,
               process_id: Optional[int] = None) -> None:
    """jax.distributed.initialize with TPU-env autodetection fallback."""
    kwargs = {}
    if coordinator:
        kwargs = dict(coordinator_address=coordinator,
                      num_processes=num_processes, process_id=process_id)
    jax.distributed.initialize(**kwargs)


def describe() -> str:
    return (f"process {jax.process_index()}/{jax.process_count()} — "
            f"{jax.local_device_count()} local / "
            f"{jax.device_count()} global devices")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--coordinator", default=os.environ.get("COORDINATOR_ADDRESS"))
    ap.add_argument("--num-processes", type=int,
                    default=int(os.environ.get("NUM_PROCESSES", "0")) or None)
    ap.add_argument("--process-id", type=int,
                    default=int(os.environ.get("PROCESS_ID", "-1")))
    ap.add_argument("--multi-pod", action="store_true")
    args = ap.parse_args(argv)

    initialize(args.coordinator, args.num_processes,
               args.process_id if args.process_id >= 0 else None)
    print(describe())
    mesh = make_production_mesh(multi_pod=args.multi_pod)
    print(f"mesh: {dict(mesh.shape)}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
