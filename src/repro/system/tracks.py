"""Fleet-wide track registry for cross-camera track queries.

``TrackStage`` owns track birth / update / retire, keyed ``(query,
track_id)``.  Once per scheduler tick the orchestrator hands it every
live track query's embedded detections (grouped per (query, edge)); the
stage matches ALL of them against the fleet-wide live track table in ONE
fused ``ops.associate_tracks`` Pallas launch — the same per-tick launch
budget discipline as triage — then applies the associations:

* matched crop -> the track follows the crop (EMA embedding update,
  last-seen camera/edge advance).  A match whose edge differs from the
  track's previous edge is a *hand-off*: the association crossed edges,
  which is the thing a per-edge tracker cannot do.
* unmatched crop -> a new track is born.
* tracks unseen for ``Scenario.track_ttl_s`` retire; a ``QueryRetire``
  drops the query's whole table.

Warm vs cold edges drive the per-crop acceptance floor
(``Scenario.track_thresholds = (warm, cold)``): an edge is warm for a
query when one of the query's live tracks was last seen there, or when a
predictive pre-warm delivered and is inside ``prewarm_ttl_s``.  A cold
edge accepts only near-perfect (same-camera) continuations; a warm edge
accepts cross-camera appearance shifts.  That gap is the predictive
hand-off's value: when a track crosses into a new camera, the stage
extrapolates its direction one camera further and ships a pre-warm for
the *next* edge over the WAN downlink (``Transport.ship_update`` — the
same FIFO + stale-in-flight delivery semantics as every model artifact:
the pre-warm only helps if it DELIVERS before the target arrives), so by
the time the target crosses again the receiving edge is already warm.

ID-switch accounting rides the synthetic trajectory ground truth
(``Item.gt_track``): every re-observation of a ground-truth object is an
opportunity; landing on a different registry track than last time is an
ID switch.  ``track_continuity = 1 - switches / opportunities``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Tuple

import numpy as np

from repro.kernels import ops
from repro.system.events import ModelUpdate


@dataclasses.dataclass
class _Track:
    emb: np.ndarray               # L2-normalized running appearance
    last_seen: float
    last_camera: int
    last_edge: int
    prewarm_edge: int = -1        # last edge this track pre-warmed (dedupe)
    hits: int = 1


#: EMA weight of the incoming crop embedding on a match (re-normalized)
_EMA = 0.30


class TrackStage:
    """One per pipeline run (created only when track queries exist)."""

    def __init__(self, sc, transport):
        self.sc = sc
        self.transport = transport
        self.tracks: Dict[Tuple[int, int], _Track] = {}
        self._next_id: Dict[int, int] = {}
        self._warm_until: Dict[Tuple[int, int], float] = {}
        self._gt_last: Dict[Tuple[int, int], int] = {}
        self.launches = 0
        self.items = 0
        self.matches = 0
        self.tracks_born = 0
        self.id_switches = 0
        self.opportunities = 0
        self.handoffs = 0
        self.prewarms = 0
        self.prewarm_hits = 0
        self.elapsed_s = 0.0

    # --- warmth ---------------------------------------------------------------
    def _warm_parts(self, query: int, edge: int, t: float) -> Tuple[bool, bool]:
        """(naturally warm: a live track is here, pre-warmed: delivery live)."""
        nat = any(tr.last_edge == edge
                  for (q, _), tr in self.tracks.items() if q == query)
        pre = self._warm_until.get((query, edge), -np.inf) >= t
        return nat, pre

    def apply_prewarm(self, t: float, query: int, edge: int) -> None:
        """A ``ModelUpdate(kind="prewarm")`` delivered: the edge holds the
        query's thresholds/CQ weights hot for ``prewarm_ttl_s``."""
        key = (query, edge)
        until = t + self.sc.prewarm_ttl_s
        if until > self._warm_until.get(key, -np.inf):
            self._warm_until[key] = until

    def retire_query(self, query: int) -> None:
        for key in [k for k in self.tracks if k[0] == query]:
            del self.tracks[key]
        for key in [k for k in self._warm_until if k[0] == query]:
            del self._warm_until[key]

    # --- the per-tick association --------------------------------------------
    def tick(self, t: float, batches: Dict[Tuple[int, int], list]
             ) -> List[Tuple[float, ModelUpdate]]:
        """Associate one tick's embedded detections; returns the pre-warm
        shipments as ``(delivery_t, ModelUpdate)`` pairs for the caller to
        push onto the event queue.

        ``batches`` maps (query, edge) -> items; iteration is sorted by
        key (items keep stream order within a batch), so association —
        and therefore every hand-off decision — is deterministic across
        reruns and drivers."""
        t0 = time.perf_counter()
        # TTL retirement first: a track the fleet lost track_ttl_s ago must
        # not claim this tick's crops
        ttl = self.sc.track_ttl_s
        dead = [k for k, tr in self.tracks.items() if tr.last_seen < t - ttl]
        for k in dead:
            del self.tracks[k]
        crops = []
        for key in sorted(batches):
            q, e = key
            for it in batches[key]:
                if it.emb is not None:
                    crops.append((it, q, e))
        if not crops:
            self.elapsed_s += time.perf_counter() - t0
            return []
        self.items += len(crops)
        warm_t, cold_t = self.sc.track_thresholds
        # warmth is sampled BEFORE this tick's updates, per (query, edge)
        warm_nat: Dict[Tuple[int, int], bool] = {}
        warm_pre: Dict[Tuple[int, int], bool] = {}
        for _, q, e in crops:
            if (q, e) not in warm_nat:
                warm_nat[(q, e)], warm_pre[(q, e)] = self._warm_parts(q, e, t)
        keys = sorted(self.tracks)
        D = self.sc.embedding_dim
        emb = np.stack([c[0].emb for c in crops]).astype(np.float32)
        emb /= np.maximum(np.linalg.norm(emb, axis=1, keepdims=True), 1e-12)
        crop_q = np.asarray([q for _, q, _ in crops], np.int32)
        thr = np.asarray(
            [warm_t if (warm_nat[(q, e)] or warm_pre[(q, e)]) else cold_t
             for _, q, e in crops], np.float32)
        if keys:
            trk = np.stack([self.tracks[k].emb for k in keys])
            trk_q = np.asarray([k[0] for k in keys], np.int32)
            assign, sim = ops.associate_tracks(emb, trk, crop_q, trk_q, thr)
            assign = np.asarray(assign)
            sim = np.asarray(sim)
            self.launches += 1
        else:
            # empty table: nothing to launch against — every crop births
            assign = np.full(len(crops), -1, np.int32)
            sim = np.full(len(crops), -1e30, np.float32)
        out: List[Tuple[float, ModelUpdate]] = []
        C = self.sc.num_cameras
        E = self.sc.num_edges
        for i, (it, q, e) in enumerate(crops):
            j = int(assign[i])
            if j >= 0:
                key = keys[j]
                tr = self.tracks[key]
                self.matches += 1
                # a pre-warm "hit": the match needed the warm floor (cold
                # would have rejected it) and ONLY the pre-warm provided it
                if (warm_pre[(q, e)] and not warm_nat[(q, e)]
                        and float(sim[i]) < cold_t):
                    self.prewarm_hits += 1
                if e != tr.last_edge:
                    self.handoffs += 1
                prev_cam = tr.last_camera
                tr.emb = (1.0 - _EMA) * tr.emb + _EMA * emb[i]
                tr.emb /= max(float(np.linalg.norm(tr.emb)), 1e-12)
                tr.last_seen = t
                tr.last_edge = e
                tr.last_camera = it.camera
                tr.hits += 1
                if it.camera != prev_cam:
                    self._predict_handoff(t, q, tr, prev_cam, it.camera,
                                          e, C, E, out)
            else:
                tid = self._next_id.get(q, 0)
                self._next_id[q] = tid + 1
                key = (q, tid)
                self.tracks[key] = _Track(
                    emb=emb[i].copy(), last_seen=t,
                    last_camera=it.camera, last_edge=e)
                self.tracks_born += 1
            if it.gt_track >= 0:
                gk = (q, it.gt_track)
                prev_tid = self._gt_last.get(gk)
                if prev_tid is not None:
                    self.opportunities += 1
                    if prev_tid != key[1]:
                        self.id_switches += 1
                self._gt_last[gk] = key[1]
        self.elapsed_s += time.perf_counter() - t0
        return out

    def _predict_handoff(self, t: float, query: int, tr: _Track,
                         prev_cam: int, cam: int, edge: int,
                         C: int, E: int,
                         out: List[Tuple[float, ModelUpdate]]) -> None:
        """The track just crossed prev_cam -> cam: extrapolate one camera
        further along the chain (wrap-aware) and pre-warm its edge."""
        if not self.sc.predictive_handoff or prev_cam < 0:
            return
        d = cam - prev_cam
        if d > C / 2:
            d -= C
        elif d < -C / 2:
            d += C
        if d == 0:
            return
        next_cam = (cam + (1 if d > 0 else -1)) % C
        next_edge = next_cam % E + 1
        # skip same-edge predictions and duplicate ships for one crossing
        if next_edge == edge or tr.prewarm_edge == next_edge:
            return
        tr.prewarm_edge = next_edge
        done, _ = self.transport.ship_update(t, self.sc.prewarm_nbytes)
        out.append((done, ModelUpdate(next_edge, None, query=query,
                                      kind="prewarm")))
        self.prewarms += 1

    # --- report ---------------------------------------------------------------
    @property
    def continuity(self) -> float:
        if self.opportunities == 0:
            return 1.0
        return 1.0 - self.id_switches / self.opportunities
