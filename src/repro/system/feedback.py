"""Cloud->edge feedback stage: online CQ confidence recalibration.

This closes the loop the offline §IV-B training scheme leaves open at run
time: every cloud (or peer-edge) re-classification verdict is an exact
label for the edge confidence that escalated it, and throwing those labels
away freezes each edge's confidence quality for the whole run.  Instead:

  reclassify completes ──► per-(query, edge) (score, truth) ring buffer
                                      │  every update_period_s
                                      ▼
                    ONE fused ``ops.calibrate_fleet`` launch
                    (all ready (query, edge) rows' Platt fits, row-folded
                    and bucket-padded exactly like the triage kernel's
                    query axis)
                                      │  per-row (a, b)
                                      ▼
                    WAN downlink (``Transport.wan_recv``, FIFO)
                                      │  ModelUpdate at *delivery* time
                                      ▼
                    ``TriageStage.apply_update`` — later ticks triage on
                    ``sigmoid(a * logit(conf) + b)``; in-flight ticks
                    still ran on the stale calibration (the real race)

Buffers are bounded deques (``feedback_window``): recency-windowed labels
are what lets the fit *follow* concept drift instead of averaging it away.
Rows with too few labels, or labels all one class, are skipped rather
than shipped an identity that would overwrite a learned calibration; a
retired query's buffers are cleared and its rows never fit again.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, FrozenSet, List, Set, Tuple, Union

import numpy as np

from repro.kernels import ops
from repro.serving.simulator import Item
from repro.system.events import ModelUpdate
from repro.system.scenario import Scenario
from repro.system.transport import Transport

IDENTITY = (1.0, 0.0)
# must match kernels/calibrate.EPS: train-time and serve-time logit
# features have to agree or the fit systematically misses near 0/1
_EPS = 1e-4


def apply_calibration(conf: np.ndarray, a: float, b: float) -> np.ndarray:
    """``sigmoid(a * logit(conf) + b)`` — the Platt map the fused
    calibration kernel fits.  The identity (1, 0) returns ``conf``
    untouched (bit-exact, not just numerically close), so an uncalibrated
    run is indistinguishable from one with the loop disabled."""
    if (a, b) == IDENTITY:
        return conf
    c = np.clip(conf, _EPS, 1.0 - _EPS)
    z = a * np.log(c / (1.0 - c)) + b
    # numerically stable sigmoid: exp only ever sees non-positive z
    out = np.empty_like(z)
    pos = z >= 0
    out[pos] = 1.0 / (1.0 + np.exp(-z[pos]))
    ez = np.exp(z[~pos])
    out[~pos] = ez / (1.0 + ez)
    return out


def calibrate_row(row: np.ndarray, n: int,
                  params: Tuple[float, float]) -> None:
    """Apply one (query, edge) row's live Platt params to its first ``n``
    lanes in place (identity is a bit-exact no-op; pad lanes stay -1.0).

    Both fused-triage pack paths — the per-tick legacy pack
    (``triage.TriageStage.triage_tick``) and the scan-superstep slab pack
    (``system.superstep``) — MUST go through this one helper: the
    superstep's bit-exactness guarantee against the per-tick driver rests
    on the calibrated f32 lanes being computed by identical code."""
    if n and params != IDENTITY:
        row[:n] = apply_calibration(row[:n], params[0], params[1])


class FeedbackStage:
    """Accumulates cloud-labeled escalations; emits fleet model updates."""

    def __init__(self, sc: Scenario, transport: Transport):
        self.sc = sc
        self.transport = transport
        # the loop needs a cascade (something to recalibrate) and a period
        self.enabled = (sc.update_period_s is not None
                        and sc.scheme in ("surveiledge", "surveiledge_fixed"))
        self.buffers: Dict[Tuple[int, int],
                           Deque[Tuple[float, float, bool]]] = {
            (q, e): collections.deque(maxlen=sc.feedback_window)
            for q in sc.query_ids for e in sc.edge_ids}
        self.model_updates = 0        # fused calibrate launches (one/event)
        self.labels_seen = 0

    # --- label intake ---------------------------------------------------------
    def observe(self, t: float, item: Item) -> None:
        """One re-classification verdict at time ``t``: ground truth for
        ``item``'s raw edge confidence, banked against its query's row on
        its *home* edge (whose CQ model produced the score, wherever the
        re-classification actually ran)."""
        if not self.enabled:
            return
        self.buffers[(item.query, item.edge_device)].append(
            (t, item.conf, item.is_query))
        self.labels_seen += 1

    def add_query(self, query: int) -> None:
        """Open label buffers for a runtime-submitted query (live API) so
        its re-classification verdicts feed the fused fit like any
        declared query's."""
        for e in self.sc.edge_ids:
            self.buffers.setdefault(
                (query, e),
                collections.deque(maxlen=self.sc.feedback_window))

    def retire_query(self, query: int) -> None:
        """A retired query's labels describe a model nobody serves anymore:
        clear its buffers so its rows never re-enter the fused fit."""
        for key, buf in self.buffers.items():
            if key[0] == query:
                buf.clear()

    def _fresh(self, t: float, key: Tuple[int, int]
               ) -> List[Tuple[float, bool]]:
        """This (query, edge) row's labels young enough to describe the
        CURRENT score distribution.  Labels age out after
        ``feedback_max_age_periods`` update periods: the count-bounded
        deque alone turns over at the escalation rate, which under drift
        leaves the fit anchored to the dead regime for most of a run."""
        horizon = t - self.sc.feedback_max_age_periods * self.sc.update_period_s
        return [(s, truth) for (ts, s, truth) in self.buffers[key]
                if ts >= horizon]

    # --- one update event -----------------------------------------------------
    def tick(self, t: float, dead: set,
             retired: Union[Set[int], FrozenSet[int]] = frozenset()
             ) -> List[Tuple[float, ModelUpdate]]:
        """Fit every ready (query, edge) row in ONE fused launch and ship
        the results.

        Ready = live query on a live edge, with at least
        ``feedback_min_count`` fresh labels of both classes (a single-class
        or tiny fit would ship noise over a possibly learned calibration).
        Returns ``[(delivery_time, ModelUpdate), ...]`` — the caller pushes
        them onto the event queue so calibration lands only when the WAN
        downlink delivers it."""
        ready: List[Tuple[Tuple[int, int], List[Tuple[float, bool]]]] = []
        for key in sorted(self.buffers):
            q, e = key
            if e in dead or q in retired:
                continue
            labels = self._fresh(t, key)
            pos = sum(1 for _, truth in labels if truth)
            if len(labels) >= self.sc.feedback_min_count \
                    and 0 < pos < len(labels):
                ready.append((key, labels))
        if not ready:
            return []
        n = max(len(labels) for _, labels in ready)
        scores = np.full((len(ready), n), -1.0, np.float32)
        truths = np.zeros((len(ready), n), np.float32)
        for i, (_, labels) in enumerate(ready):
            scores[i, :len(labels)] = [s for s, _ in labels]
            truths[i, :len(labels)] = [float(truth) for _, truth in labels]
        # the ready rows are already (query, edge)-folded — the same Q·E
        # row-folding the kernel's 3D entry point performs itself
        params, _ = ops.calibrate_fleet(
            scores, truths, min_count=self.sc.feedback_min_count)
        params = np.asarray(params)
        self.model_updates += 1
        out = []
        for i, ((q, e), _) in enumerate(ready):
            # ship through the downlink wire path: under quantize_downlink
            # the (a, b) pair round-trips the int8 codec, so the edge
            # applies the calibration it actually received, and the link
            # is charged the real wire size instead of the fp width
            done, vals = self.transport.ship_update(
                t, self.sc.update_nbytes,
                values=np.asarray([params[i, 0], params[i, 1]], np.float32))
            out.append((done, ModelUpdate(
                e, (float(vals[0]), float(vals[1])),
                query=q, kind="calibration")))
        return out
