"""Runtime query lifecycle: the ``QuerySet`` registry + ``QuerySpec``.

SurveilEdge's headline workflow is *queries* arriving against a live
camera fleet, not one eternal query.  Each continuous query (CQ) moves
through

    arrival ──► cloud fine-tune (Fig. 5, ``core.finetune.scheme_train_time``)
            ──► per-edge CQ weight shipment (WAN downlink, FIFO)
            ──► live serving (per-(query, edge) Eqs. 8-9 thresholds,
                 fused into the same ONE triage launch per tick)
            ──► retire (threshold rows freed, feedback buffers cleared)

``QuerySet`` owns the lifecycle state machine; the orchestrator
(``system/pipeline.py``) drives it from ``QueryArrival`` / ``TrainDone``
/ ``ModelUpdate(kind="weights")`` / ``QueryRetire`` events.  Until a
query's weights *deliver* at an edge, that edge has no model to score the
query with: its detections wait in the pipeline's deferral buffer (the
query's escalations are thereby blocked while the cloud trains), and the
Fig. 5 training time surfaces as head-of-query latency — exactly the
trade the paper's Fig. 5 plots.

The lifecycle is modelled for the cascade schemes only (``surveiledge``,
``surveiledge_fixed`` — the schemes where the cloud actually fine-tunes
and ships CQ models).  ``cloud_only`` answers every query with the
cloud's accurate model (nothing to ship) and ``edge_only`` assumes
pre-provisioned edge models, so both serve every query from arrival.  A
scenario with no explicit ``queries`` runs one implicit query that is
born live everywhere — bit-identical to the pre-lifecycle engine.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Set, Tuple

from repro.core.finetune import FIG5_SCHEMES, scheme_train_time

#: the implicit query id used when a scenario declares no explicit queries
DEFAULT_QUERY = 0


@dataclasses.dataclass(frozen=True)
class QuerySpec:
    """One continuous query's lifecycle declaration.

    ``train_scheme`` picks the Fig. 5 fine-tuning scheme the cloud runs on
    arrival; it also shapes the synthetic stream's class-conditional
    confidence sharpness (``scenario._SCHEME_BETAS``) — No-Fine-tune ships
    instantly but scores blurrier, All-Fine-tune scores sharpest but
    trains ~num_cameras-x longer.  ``t_retire_s=None`` means the query
    lives to the end of the run.

    ``tenant`` / ``tier`` are the control-plane coordinates (admission
    quotas and priority, ``repro.serving.api``): both default to the
    tierless/quota-free engine, which keeps every pre-control-plane
    scenario bit-identical.

    ``kind`` selects what the query *asks*:

    * ``"classify"`` (the default — every pre-existing construction) —
      per-camera classification: is this crop the query class?
    * ``"track"`` — cross-camera re-ID: detections carry embeddings, the
      fleet-wide track registry (``system/tracks.py``) associates them
      against live tracks in ONE fused similarity launch per tick, and a
      predictive hand-off pre-warms the next-likely edge over the WAN
      downlink.  Track queries still ride the full classify lifecycle
      (fine-tune, weight shipment, triage, tiers, admission) — the track
      stage is additive."""
    query: int
    t_arrive_s: float = 0.0
    t_retire_s: Optional[float] = None
    train_scheme: str = "surveiledge"
    tenant: str = ""
    tier: int = 0
    kind: str = "classify"

    def __post_init__(self):
        if self.query < 0:
            raise ValueError(f"query id {self.query} must be >= 0")
        if self.kind not in ("classify", "track"):
            raise ValueError(
                f"query {self.query}: unknown kind {self.kind!r} "
                f"(expected 'classify' or 'track')")
        if self.tier < 0:
            raise ValueError(
                f"query {self.query}: tier={self.tier} must be >= 0")
        if self.t_arrive_s < 0:
            raise ValueError(
                f"query {self.query}: t_arrive_s={self.t_arrive_s} < 0")
        if self.t_retire_s is not None and self.t_retire_s <= self.t_arrive_s:
            raise ValueError(
                f"query {self.query}: t_retire_s={self.t_retire_s} must "
                f"exceed t_arrive_s={self.t_arrive_s}")
        if self.train_scheme not in FIG5_SCHEMES:
            raise ValueError(
                f"query {self.query}: unknown train_scheme "
                f"{self.train_scheme!r} (expected one of {FIG5_SCHEMES})")


class QuerySet:
    """Lifecycle state for every query in one run.

    State per query: pending -> training -> live on a growing set of edges
    (weights deliver edge by edge over the FIFO downlink, so a fleet goes
    live staggered) -> retired.  ``live_on`` is the single predicate the
    triage path asks; everything else is bookkeeping for the per-query
    report rows.
    """

    def __init__(self, sc):
        specs = sc.queries or (QuerySpec(DEFAULT_QUERY),)
        self.specs: Dict[int, QuerySpec] = {sp.query: sp for sp in specs}
        self.default = min(self.specs)
        # the lifecycle (train -> ship -> serve) is only modelled where the
        # cloud actually fine-tunes CQ models; see module docstring
        self.lifecycle = bool(sc.queries) and sc.scheme in (
            "surveiledge", "surveiledge_fixed")
        self._num_cameras = sc.num_cameras
        self._step_s = sc.train_step_s
        self._edge_ids = tuple(sc.edge_ids)
        self.live_edges: Dict[int, Set[int]] = {q: set() for q in self.specs}
        self.retired: Set[int] = set()
        self.shed: Set[int] = set()
        self.train_s: Dict[int, float] = {}
        self.train_window: Dict[int, Tuple[float, float]] = {}
        if not self.lifecycle:
            for q in self.specs:
                self.live_edges[q] = set(sc.edge_ids)

    def register(self, sp: QuerySpec) -> None:
        """Add a query at runtime (live API submission): it starts in the
        pending state and rides the same arrival -> train -> ship -> serve
        lifecycle as a scenario-declared query."""
        if sp.query in self.specs:
            raise ValueError(f"query {sp.query} already registered")
        self.specs[sp.query] = sp
        self.live_edges[sp.query] = set() if self.lifecycle \
            else set(self._edge_ids)

    # --- lifecycle transitions ------------------------------------------------
    def arrive(self, query: int, t: float) -> float:
        """The query enters: returns the Fig. 5 cloud training seconds its
        ``train_scheme`` costs (charged to the cloud by the caller)."""
        sp = self.specs[query]
        dt = scheme_train_time(sp.train_scheme, self._num_cameras,
                               step_s=self._step_s)
        self.train_s[query] = dt
        self.train_window[query] = (t, t + dt)
        return dt

    def activate(self, query: int, edge: int) -> None:
        """``query``'s CQ weights delivered at ``edge``: serving starts."""
        self.live_edges[query].add(edge)

    def retire(self, query: int) -> None:
        self.retired.add(query)

    def shed_query(self, query: int) -> None:
        """Admission refused the query: it never trains, never ships, and
        its stream items are dropped (counted) instead of answered."""
        self.shed.add(query)

    # --- predicates -----------------------------------------------------------
    def live_on(self, query: int, edge: int) -> bool:
        """Can ``edge`` triage this query's detections right now?"""
        return (query not in self.retired
                and edge in self.live_edges.get(query, ()))

    def is_retired(self, query: int) -> bool:
        return query in self.retired

    def is_shed(self, query: int) -> bool:
        return query in self.shed

    def training_at(self, query: int, t: float) -> bool:
        """Is the cloud inside this query's Fig. 5 fine-tune at ``t``?"""
        w = self.train_window.get(query)
        return w is not None and w[0] <= t < w[1]
