"""Slim orchestrator for the end-to-end cloud-edge query engine.

The engine is layered; this module only composes the layers and runs the
event loop:

  frontend   repro.system.frontend   detection stream (confidence-based
                                     today; the pixel/CNN path slots in
                                     behind the same ``Frontend`` seam)
  events     repro.system.events     typed events + time-ordered queue
  triage     repro.system.triage     per-edge Eqs. 8-9 thresholds + ONE
                                     fused fleet-triage Pallas launch per
                                     scheduler tick (``ops.triage_fleet``)
  allocator  repro.core.scheduler    Eq. 7: argmin_j Q_j * t_j (+ WAN
                                     backlog for the cloud), node liveness
  nodes      repro.system.nodes      per-node deque queues, service state,
                                     failure bookkeeping
  transport  repro.system.transport  shared-FIFO WAN uplink + downlink,
                                     dedicated LAN links, byte accounting
  feedback   repro.system.feedback   cloud->edge learning loop: cloud
                                     labels -> ONE fused calibrate launch
                                     per update_period_s -> per-edge Platt
                                     params over the WAN downlink
  metrics    repro.system.metrics    QueryReport

Beyond-paper stress is first-class: scenarios may declare traffic bursts
and mid-run edge failures (queued work is re-dispatched, the dead edge's
cameras re-home to survivors via Eq. 7).  Entry point unchanged:
``run_query(scenario) -> QueryReport``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import CLOUD, Scheduler
from repro.serving.bus import Bus, ParamDB
from repro.serving.simulator import Item
from repro.system import metrics as MX
from repro.system.events import (
    Arrive,
    EdgeFail,
    EventQueue,
    FeedbackTick,
    ModelUpdate,
    Sample,
    ServiceDone,
    Task,
    TickArrivals,
    Transfer,
)
from repro.system.feedback import FeedbackStage
from repro.system.frontend import ConfidenceStreamFrontend, Frontend
from repro.system.nodes import NodeBank
from repro.system.scenario import Scenario
from repro.system.transport import Transport
from repro.system.triage import ACCEPT, ESCALATE, TriageStage


def group_arrivals(items: Sequence[Item], interval_s: float
                   ) -> List[Tuple[int, Dict[int, List[Item]]]]:
    """Group a stream into per-tick, per-edge batches with numpy.

    Returns ``[(tick_index, {edge: [items]}), ...]`` in tick order; within
    each (tick, edge) group arrival order is preserved (stable lexsort over
    an already arrival-sorted stream).  The grouping work is O(n) numpy —
    no per-item Python dict churn, which matters at city scale."""
    if not items:
        return []
    n = len(items)
    arr = np.empty(n, object)
    arr[:] = list(items)
    t = np.fromiter((it.t_arrival for it in items), np.float64, n)
    e = np.fromiter((it.edge_device for it in items), np.int64, n)
    ticks = (t // interval_s).astype(np.int64)
    order = np.lexsort((e, ticks))
    arr, ticks, e = arr[order], ticks[order], e[order]
    out: List[Tuple[int, Dict[int, List[Item]]]] = []
    tick_cuts = np.flatnonzero(np.diff(ticks)) + 1
    for s0, s1 in zip(np.r_[0, tick_cuts], np.r_[tick_cuts, n]):
        seg_e = e[s0:s1]
        edge_cuts = np.flatnonzero(np.diff(seg_e)) + 1
        batches = {
            int(seg_e[b0]): list(arr[s0 + b0:s0 + b1])
            for b0, b1 in zip(np.r_[0, edge_cuts],
                              np.r_[edge_cuts, s1 - s0])}
        out.append((int(ticks[s0]), batches))
    return out


class QueryPipeline:
    """Event loop over one scenario.  Build once, ``run()`` once."""

    def __init__(self, sc: Scenario):
        self.sc = sc
        self.rng = np.random.default_rng(sc.seed + 1)
        # topology: cloud is node 0, edges 1..E (service-time multipliers)
        self.service_s: Dict[int, float] = {
            CLOUD: sc.edge_service_s / sc.cloud_speedup}
        for nid, mult in zip(sc.edge_ids, sc.edge_speeds):
            self.service_s[nid] = sc.edge_service_s * mult
        for t_fail, nid in sc.failures:
            if nid not in self.service_s or nid == CLOUD:
                raise ValueError(
                    f"scenario {sc.name!r}: failure at t={t_fail} references "
                    f"node {nid}, but failable edges are {list(sc.edge_ids)}")
        self.sched = Scheduler(sorted(self.service_s),
                               interval_s=sc.interval_s)
        self.bus = Bus()
        self.db = ParamDB(self.bus)
        for nid, svc in self.service_s.items():
            self.db.put(f"t{nid}", svc)
            self.db.put(f"Q{nid}", 0)
            self.sched.nodes[nid].estimator.t = svc

    # --- event machinery ------------------------------------------------------
    def _enqueue(self, t: float, node: int, task: Task) -> None:
        self.nodes.push(node, task)
        self.sched.on_enqueue(node)
        self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
        if not self.nodes.busy[node]:
            self._start_service(t, node)

    def _start_service(self, t: float, node: int) -> None:
        task, svc = self.nodes.begin(t, node)
        self.events.push(t + svc, ServiceDone(node, task, svc))

    def _finish(self, t: float, node: int, it: Item, decision: bool) -> None:
        self._lat.append(t - it.t_arrival)
        self._dec.append(decision)
        self._tru.append(it.is_query)
        self._fin.append(t)
        self.nodes.served[node] += 1

    def _dispatch(self, t: float, src: int, task: Task,
                  count_escalated: bool, exclude_src: bool = False) -> None:
        """Route one re-classification task via Eq. 7 and ship it.

        ``exclude_src`` is for overload shedding: work shed *because* src
        is drowning must not be allowed to win the argmin and land right
        back on src at the heavier re-classify cost.
        """
        if self.sc.scheme == "surveiledge_fixed":
            target = CLOUD          # local-edge-first: escalations go up
        else:
            try:
                # edge_only has no cloud path: its failovers stay on the
                # surviving edges (cloud only as a last resort below)
                target = self.sched.select_node(
                    exclude_cloud=self.sc.scheme == "edge_only",
                    exclude={src} if exclude_src else (),
                    extra_cost={CLOUD: self.transport.wan_backlog(t)})
            except ValueError:
                target = CLOUD      # the cloud never fails in our scenarios
        if count_escalated:
            self._escalated += 1
        nbytes = task.item.nbytes
        if target == src:
            self.events.push(t, Transfer(target, task))
        elif target == CLOUD:
            done = self.transport.wan_send(t, nbytes)
            task.tx_s += done - t
            self.events.push(done, Transfer(target, task))
        else:
            done = self.transport.lan_send(t, nbytes)
            task.tx_s += done - t
            self.events.push(done, Transfer(target, task))

    # --- per-tick fused triage ------------------------------------------------
    def _on_tick(self, t: float, batches: Dict[int, List[Item]]) -> None:
        """One scheduler tick's arrivals: failover dead edges' batches, shed
        overloaded edges' raw batches via Eq. 7, triage everything else in
        ONE fused fleet launch, enqueue per-route."""
        live: Dict[int, List[Item]] = {}
        for edge, batch in batches.items():
            if edge in self.nodes.dead:
                # dead edge's cameras re-home: raw frames to survivors
                for it in batch:
                    self._rerouted += 1
                    self._dispatch(t, edge, self._failover_task(it),
                                   count_escalated=False)
            else:
                live[edge] = batch
        if not live:
            return
        if self.sc.scheme == "edge_only":
            for edge, batch in live.items():
                for it in batch:
                    self._enqueue(t, edge, Task(it, "classify",
                                                it.conf > 0.5))
            return
        self.triage_stage.refresh(t, sorted(live))
        if self.sc.scheme == "surveiledge":
            for e in live:
                self.db.put(f"alpha{e}", self.triage_stage.states[e].alpha)
                self.db.put(f"beta{e}", self.triage_stage.states[e].beta)
            # a home edge that can't drain its queue within the gate sheds
            # this tick's raw batch across cloud/edges via Eq. 7 (the
            # overloaded home has maximal Q*t, so it is effectively skipped)
            for edge in [e for e in live
                         if self.sched.nodes[e].drain_time
                         > self.sc.offload_drain_s]:
                for it in live.pop(edge):
                    self._rerouted += 1
                    self._dispatch(t, edge, Task(it, "reclassify", None),
                                   count_escalated=False, exclude_src=True)
        for edge, (routes, slots, conf_used) in self.triage_stage.triage_tick(
                live).items():
            for it, route, slot, cal in zip(live[edge], routes, slots,
                                            conf_used):
                if route == ESCALATE and slot >= 0:
                    decision = None                 # cloud-model's call
                elif route == ESCALATE:             # capacity overflow:
                    # stays un-escalated; the edge decides with its LIVE
                    # (calibrated) confidence, same value the kernel
                    # routed on
                    decision = bool(cal > 0.5)
                else:
                    decision = route == ACCEPT
                self._enqueue(t, edge, Task(it, "classify", decision))

    def _failover_task(self, it: Item) -> Task:
        """A dead edge's work re-homed to a survivor: under edge_only the
        peer re-runs the CQ model (conf > 0.5); otherwise the heavyweight
        re-classifier answers."""
        if self.sc.scheme == "edge_only":
            return Task(it, "classify", it.conf > 0.5)
        return Task(it, "reclassify", None)

    def _fail_node(self, t: float, node: int) -> None:
        """Edge death: drop it from Eq. 7, re-dispatch its queued and
        in-flight work to survivors."""
        self.sched.mark_down(node)
        stranded = self.nodes.fail(t, node)
        self.sched.nodes[node].queue_len = 0
        self.db.put(f"Q{node}", 0)
        for task in stranded:
            self._rerouted += 1
            self._dispatch(t, node, self._failover_task(task.item),
                           count_escalated=False)

    def _on_done(self, t: float, node: int, task: Task, svc: float) -> None:
        if node in self.nodes.dead:
            return                               # work was re-dispatched
        self.nodes.complete(node)
        # The estimator sees SERVICE time only.  Transfer time is the
        # link's (Transport accumulates it); feeding it here would let one
        # WAN burst permanently inflate the cloud's t_0 while wan_backlog
        # separately charges the same congestion in Eq. 7 — double-counted.
        # Reclassify observations on an edge run reclassify_factor x the CQ
        # cost; normalize them so t_j stays a per-CQ-item estimate and a
        # classify/reclassify mix cannot bias drain_time (Eqs. 7-9).
        # (Known residual: Q_j * t_j prices a reclassify-laden queue in
        # CQ units, underestimating its true drain; pricing per-phase
        # queue composition is the fuller alternative the paper's Eq. 7
        # doesn't model either.)
        obs = svc
        if task.phase == "reclassify" and node != CLOUD:
            obs = svc / self.sc.reclassify_factor
        self.sched.on_complete(node, obs)
        self.db.put(f"t{node}", self.sched.nodes[node].estimator.t)
        self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
        if task.phase == "reclassify":
            # accurate model == ground truth (paper: ResNet-152) — and an
            # exact label for the home edge's CQ score (feedback loop)
            self.feedback.observe(t, task.item)
            self._finish(t, node, task.item, task.item.is_query)
        elif task.decision is None:              # escalate: ship onward
            self._dispatch(t, node, Task(task.item, "reclassify", None),
                           count_escalated=True)
        else:
            self._finish(t, node, task.item, task.decision)
        if self.nodes.queues[node]:
            self._start_service(t, node)

    # --- main loop ------------------------------------------------------------
    def run(self, items: Sequence[Item],
            frontend_timings: Optional[Dict[str, float]] = None
            ) -> MX.QueryReport:
        sc = self.sc
        self.events = EventQueue()
        self.transport = Transport(sc)
        self.nodes = NodeBank(sc, self.service_s, self.rng)
        self.triage_stage = TriageStage(sc, self.sched, self.transport)
        self.feedback = FeedbackStage(sc, self.transport)
        self._lat: List[float] = []
        self._dec: List[bool] = []
        self._tru: List[bool] = []
        self._fin: List[float] = []
        self._escalated = 0
        self._rerouted = 0
        tick_samples: List[Dict[int, int]] = []

        # arrivals: cloud_only streams per item; the cascade/edge_only paths
        # batch each tick's detections into ONE TickArrivals event (the
        # cascade schemes triage it with a single fused fleet launch)
        last_t = max((it.t_arrival for it in items), default=0.0)
        n_ticks = max(1, int(math.ceil(
            max(sc.duration_s, last_t + 1e-9) / sc.interval_s)))
        if sc.scheme == "cloud_only":
            for it in items:
                self.events.push(it.t_arrival, Arrive(it))
        else:
            for k, batches in group_arrivals(items, sc.interval_s):
                self.events.push((k + 1) * sc.interval_s,
                                 TickArrivals(batches))
        for k in range(1, n_ticks + 1):
            self.events.push(k * sc.interval_s, Sample())
        for t_fail, node in sc.failures:
            self.events.push(t_fail, EdgeFail(node))
        if self.feedback.enabled:
            horizon = n_ticks * sc.interval_s
            k = 1
            while k * sc.update_period_s <= horizon + 1e-9:
                self.events.push(k * sc.update_period_s, FeedbackTick())
                k += 1

        while self.events:
            t, ev = self.events.pop()
            if isinstance(ev, Sample):
                tick_samples.append({
                    n: self.nodes.occupancy(n) for n in self.service_s})
            elif isinstance(ev, Arrive):         # cloud_only
                it = ev.item
                task = Task(it, "reclassify", None)
                done = self.transport.wan_send(t, it.nbytes)
                task.tx_s = done - t
                self.events.push(done, Transfer(CLOUD, task))
            elif isinstance(ev, TickArrivals):
                self._on_tick(t, ev.batches)
            elif isinstance(ev, Transfer):
                if ev.node in self.nodes.dead:   # died while in transit
                    self._rerouted += 1
                    self._dispatch(t, ev.node, ev.task,
                                   count_escalated=False)
                else:
                    self._enqueue(t, ev.node, ev.task)
            elif isinstance(ev, EdgeFail):
                if ev.node not in self.nodes.dead:
                    self._fail_node(t, ev.node)
            elif isinstance(ev, FeedbackTick):
                # one fused fleet recalibration launch; the per-edge
                # results land as ModelUpdate events at downlink delivery
                for done, update in self.feedback.tick(t, self.nodes.dead):
                    self.events.push(done, update)
            elif isinstance(ev, ModelUpdate):
                if ev.edge not in self.nodes.dead:
                    self.triage_stage.apply_update(ev.edge, ev.params)
            else:
                assert isinstance(ev, ServiceDone), ev
                self._on_done(t, ev.node, ev.task, ev.service_s)

        return MX.QueryReport(
            scenario=sc.name,
            scheme=sc.scheme,
            latencies=np.asarray(self._lat),
            decisions=np.asarray(self._dec, bool),
            truths=np.asarray(self._tru, bool),
            finish_times=np.asarray(self._fin),
            uploaded_bytes=self.transport.uploaded_bytes,
            lan_bytes=self.transport.lan_bytes,
            downloaded_bytes=self.transport.downloaded_bytes,
            model_updates=self.feedback.model_updates,
            wan_transfer_s=self.transport.wan_transfer_s,
            lan_transfer_s=self.transport.lan_transfer_s,
            escalated=self._escalated,
            rerouted=self._rerouted,
            kernel_launches=self.triage_stage.launches,
            ticks=n_ticks,
            queue_timeline=MX.merge_timelines(tick_samples),
            per_node_busy=dict(self.nodes.busy_s),
            per_node_served=dict(self.nodes.served),
            thresholds=self.triage_stage.final_thresholds()
            if sc.scheme in ("surveiledge", "surveiledge_fixed") else {},
            stage_timings={**(frontend_timings or {}),
                           "triage_s": self.triage_stage.elapsed_s},
        )


def run_query(scenario: Scenario,
              items: Optional[Sequence[Item]] = None,
              frontend: Optional[Frontend] = None) -> MX.QueryReport:
    """Run one query scenario end to end and return its ``QueryReport``.

    The detection stream comes from ``frontend`` (any ``Frontend``
    implementation); by default a ``ConfidenceStreamFrontend`` over
    ``items`` (or ``scenario.items``) — a pre-scored stream, e.g. the
    CQ-model-scored benchmark workload, re-homed onto this scenario's
    topology — or, when no items are given, a model-free synthetic stream
    from the scenario's camera fleet.  Pass
    ``frontend=PixelFrontend(...)`` (``repro.system.pixel_frontend``) to
    run the paper's full pixel path instead: rendered frames -> Pallas
    framediff/morphology -> motion crops -> CQ-classifier confidences,
    with per-stage wall-clock in ``QueryReport.stage_timings``.
    """
    if frontend is not None and items is not None:
        raise ValueError("pass either items= or frontend=, not both "
                         "(a custom frontend produces its own stream)")
    if frontend is None:
        frontend = ConfidenceStreamFrontend(
            items if items is not None else scenario.items)
    stream = frontend.stream(scenario)
    return QueryPipeline(scenario).run(
        stream, frontend_timings=frontend.timings)
