"""Slim orchestrator for the end-to-end cloud-edge query engine.

The engine is layered; this module only composes the layers and runs the
event loop:

  frontend   repro.system.frontend   detection stream (confidence-based
                                     today; the pixel/CNN path slots in
                                     behind the same ``Frontend`` seam)
  events     repro.system.events     typed events + time-ordered queue
  queries    repro.system.queries    runtime CQ lifecycle: arrival ->
                                     Fig. 5 cloud fine-tune -> per-edge
                                     weight shipment (WAN downlink) ->
                                     serve -> retire; detections whose
                                     query has no model on their edge yet
                                     wait in a deferral buffer
  triage     repro.system.triage     per-(query, edge) Eqs. 8-9 thresholds
                                     + ONE fused (Q, E, N) triage Pallas
                                     launch per scheduler tick
                                     (``ops.triage_fleet``)
  allocator  repro.core.scheduler    Eq. 7: argmin_j Q_j * t_j (+ WAN
                                     backlog for the cloud), node liveness
  nodes      repro.system.nodes      per-node deque queues, service state,
                                     failure bookkeeping
  transport  repro.system.transport  shared-FIFO WAN uplink + downlink,
                                     dedicated LAN links, byte accounting
  feedback   repro.system.feedback   cloud->edge learning loop: cloud
                                     labels -> ONE fused calibrate launch
                                     per update_period_s -> per-edge Platt
                                     params over the WAN downlink
  metrics    repro.system.metrics    QueryReport

Beyond-paper stress is first-class: scenarios may declare traffic bursts
and mid-run edge failures (queued work is re-dispatched, the dead edge's
cameras re-home to survivors via Eq. 7).  Entry point unchanged:
``run_query(scenario) -> QueryReport``.

The event loop itself is a pluggable **driver** behind a three-method
seam — ``setup(items)`` / ``handle_event(t, ev)`` / ``finalize()``:

  SimDriver    (here)                  classic DES: drain the heap in
                                       time order at zero wall-clock cost.
                                       The default; every preset and the
                                       superstep path run on it unchanged.
  AsyncDriver  repro.serving.engine    the same heap pumped from an
                                       asyncio loop against a ``Clock``
                                       (virtual for deterministic tests —
                                       bit-identical pops to SimDriver —
                                       or wall for real-time serving),
                                       with ``call_at`` hooks for live
                                       query submission (serving/api.py).

The serving control plane rides on the seam: per-tenant admission
(token-bucket quotas + backlog shedding, ``repro.serving.api``), priority
tiers woven into Eq. 7 as an SLO-pressure cost term, and an alert/health
stream published on the Bus (``alerts/#`` — admission sheds, failovers,
queue depth, threshold drift) that ``QueryReport`` snapshots.  All of it
is opt-in per scenario; the tierless/quota-free defaults are
bit-identical to the pre-control-plane engine.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import CLOUD, Scheduler
from repro.serving.alerts import AlertStream
from repro.serving.api import AdmissionController
from repro.serving.bus import Bus, ParamDB
from repro.serving.simulator import Item
from repro.system import metrics as MX
from repro.system.events import (
    BOUNDARY_EVENTS,
    Arrive,
    EdgeFail,
    EventQueue,
    FeedbackTick,
    ModelUpdate,
    QueryArrival,
    QueryRetire,
    ReleaseTick,
    Sample,
    ServiceDone,
    Task,
    TickArrivals,
    TrainDone,
    Transfer,
)
from repro.system.feedback import FeedbackStage
from repro.system.frontend import ConfidenceStreamFrontend
from repro.system.nodes import NodeBank
from repro.system.queries import QuerySet, QuerySpec
from repro.system.scenario import Scenario
from repro.system.superstep import Ctrl, SuperstepDriver
from repro.system.tracks import TrackStage
from repro.system.transport import Transport
from repro.system.triage import ACCEPT, ESCALATE, TriageStage


def group_arrivals(items: Sequence[Item], interval_s: float
                   ) -> List[Tuple[int, Dict[int, List[Item]]]]:
    """Group a stream into per-tick, per-edge batches with numpy.

    Returns ``[(tick_index, {edge: [items]}), ...]`` in tick order; within
    each (tick, edge) group arrival order is preserved (stable lexsort over
    an already arrival-sorted stream).  The grouping work is O(n) numpy —
    no per-item Python dict churn, which matters at city scale."""
    if not items:
        return []
    n = len(items)
    arr = np.empty(n, object)
    arr[:] = list(items)
    t = np.fromiter((it.t_arrival for it in items), np.float64, n)
    e = np.fromiter((it.edge_device for it in items), np.int64, n)
    ticks = (t // interval_s).astype(np.int64)
    order = np.lexsort((e, ticks))
    arr, ticks, e = arr[order], ticks[order], e[order]
    out: List[Tuple[int, Dict[int, List[Item]]]] = []
    tick_cuts = np.flatnonzero(np.diff(ticks)) + 1
    for s0, s1 in zip(np.r_[0, tick_cuts], np.r_[tick_cuts, n]):
        seg_e = e[s0:s1]
        edge_cuts = np.flatnonzero(np.diff(seg_e)) + 1
        batches = {
            int(seg_e[b0]): list(arr[s0 + b0:s0 + b1])
            for b0, b1 in zip(np.r_[0, edge_cuts],
                              np.r_[edge_cuts, s1 - s0])}
        out.append((int(ticks[s0]), batches))
    return out


class SimDriver:
    """Classic discrete-event driver: drain the heap in time order.

    Zero wall-clock cost per event; the default for every preset and the
    only driver the superstep path supports.  ``AsyncDriver``
    (``repro.serving.engine``) pumps the same heap from an asyncio loop —
    in virtual time it pops in exactly this order, which is what the
    differential tests assert."""

    def drive(self, pipe: "QueryPipeline") -> None:
        while pipe.events:
            t, ev = pipe.events.pop()
            pipe.handle_event(t, ev)


class QueryPipeline:
    """Event loop over one scenario.  Build once, ``run()`` once.

    ``driver`` plugs the event-loop strategy (default ``SimDriver``); any
    driver calls the same ``setup`` / ``handle_event`` / ``finalize``
    seam, so simulated and real-time runs share every handler."""

    def __init__(self, sc: Scenario, driver: Optional[object] = None):
        self.sc = sc
        self.driver = driver
        self.rng = np.random.default_rng(sc.seed + 1)
        # topology: cloud is node 0, edges 1..E (service-time multipliers)
        self.service_s: Dict[int, float] = {
            CLOUD: sc.edge_service_s / sc.cloud_speedup}
        for nid, mult in zip(sc.edge_ids, sc.edge_speeds):
            self.service_s[nid] = sc.edge_service_s * mult
        for t_fail, nid in sc.failures:
            if nid not in self.service_s or nid == CLOUD:
                raise ValueError(
                    f"scenario {sc.name!r}: failure at t={t_fail} references "
                    f"node {nid}, but failable edges are {list(sc.edge_ids)}")
        self.sched = Scheduler(sorted(self.service_s),
                               interval_s=sc.interval_s)
        self.bus = Bus()
        self.db = ParamDB(self.bus)
        for nid, svc in self.service_s.items():
            self.db.put(f"t{nid}", svc)
            self.db.put(f"Q{nid}", 0)
            self.sched.nodes[nid].estimator.t = svc
        # control plane (all opt-in per scenario; absent -> bit-identical
        # to the pre-control-plane engine): priority tiers feed the
        # SLO-pressure Eq. 7 term and per-tier latency accounting; the
        # alert stream snapshots every alerts/# publication for the report
        self._tiers = {ts.tier: ts for ts in sc.tiers}
        self._tier_of: Dict[int, int] = {
            sp.query: sp.tier for sp in sc.queries}
        self.alerts = AlertStream(self.bus)

    # --- event machinery ------------------------------------------------------
    def _enqueue(self, t: float, node: int, task: Task) -> None:
        self.nodes.push(node, task)
        self.sched.on_enqueue(node)
        self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
        if not self.nodes.busy[node]:
            self._start_service(t, node)

    def _start_service(self, t: float, node: int) -> None:
        task, svc = self.nodes.begin(t, node)
        self.events.push(t + svc, ServiceDone(node, task, svc))

    def _finish(self, t: float, node: int, it: Item, decision: bool,
                serve_t: Optional[float] = None) -> None:
        # serve_t: when the user actually saw the answer.  For speculative
        # escalations that is the provisional serve instant (upload start),
        # not the reconcile instant ``t`` — latency and window placement
        # follow what was served; accuracy follows the reconciled decision.
        ts = t if serve_t is None else serve_t
        if self._tier_acc is not None:
            # per-tier latency/accuracy cells + SLO breach counts (the
            # control plane's acceptance signal: tier 0 must stay at zero
            # breaches while lower tiers absorb the rush)
            k = self._tier_of.get(it.query, 0)
            lat = ts - it.t_arrival
            self._tier_acc[k].add(lat, decision, it.is_query)
            if lat > self._tiers[k].slo_s:
                self._tier_breach[k] += 1
        if self._agg is not None:
            # streaming windowed aggregates (metrics_window_s): O(1) per
            # item, no per-item arrays held for the report
            self._agg.add(ts, ts - it.t_arrival, decision, it.is_query,
                          it.query)
        else:
            self._lat.append(ts - it.t_arrival)
            self._dec.append(decision)
            self._tru.append(it.is_query)
            self._fin.append(ts)
            self._qid.append(it.query)
        self.nodes.served[node] += 1

    def _dispatch(self, t: float, src: int, task: Task,
                  count_escalated: bool, exclude_src: bool = False) -> None:
        """Route one re-classification task via Eq. 7 and ship it.

        ``exclude_src`` is for overload shedding: work shed *because* src
        is drowning must not be allowed to win the argmin and land right
        back on src at the heavier re-classify cost.
        """
        if self.sc.scheme == "surveiledge_fixed":
            target = CLOUD          # local-edge-first: escalations go up
        else:
            extra = {CLOUD: self.transport.wan_backlog(t)}
            if self._tiers:
                # priority tiers: a weighted tier's item adds SLO
                # pressure to Eq. 7 — nodes that would blow its
                # remaining slack are penalized in proportion (weight 0
                # or no tiers leaves the argmin bit-identical)
                tsp = self._tiers.get(
                    self._tier_of.get(task.item.query, 0))
                if tsp is not None and tsp.weight > 0.0:
                    extra = self.sched.slo_pressure(
                        tsp.weight,
                        tsp.slo_s - (t - task.item.t_arrival), extra)
            try:
                # edge_only has no cloud path: its failovers stay on the
                # surviving edges (cloud only as a last resort below)
                target = self.sched.select_node(
                    exclude_cloud=self.sc.scheme == "edge_only",
                    exclude={src} if exclude_src else (),
                    extra_cost=extra)
            except ValueError:
                target = CLOUD      # the cloud never fails in our scenarios
        if count_escalated:
            self._escalated += 1
        nbytes = task.item.nbytes
        if target == src:
            self.events.push(t, Transfer(target, task))
        elif target == CLOUD:
            done = self.transport.wan_send(t, nbytes)
            task.tx_s += done - t
            self.events.push(done, Transfer(target, task))
        else:
            done = self.transport.lan_send(t, nbytes)
            task.tx_s += done - t
            self.events.push(done, Transfer(target, task))

    # --- scan-superstep support -----------------------------------------------
    def _sample_ctrl(self, t: float) -> None:
        """Sample the Eqs. 8-9 / shed control signals for the superstep
        path: the Eq. 7 escalation-target drain (incl. WAN backlog for
        the cloud), every edge's own queue drain, and the overload-shed
        set.  Called at the first triaged tick after each boundary event
        (``_ctrl_dirty``) and held until the next one — the resample
        points are boundary-determined, never K-determined, which is
        what makes any superstep segmentation bit-exact vs. any other."""
        try:
            d = self.sched.select_node(
                extra_cost={CLOUD: self.transport.wan_backlog(t)})
        except ValueError:
            d = CLOUD
        esc_drain = self.sched.nodes[d].drain_time
        if d == CLOUD:
            esc_drain += self.transport.wan_backlog(t)
        edge_drain = {e: self.sched.nodes[e].drain_time
                      for e in self.sc.edge_ids}
        self._ctrl = Ctrl(
            esc_drain=esc_drain, edge_drain=edge_drain,
            overloaded=frozenset(
                e for e, dr in edge_drain.items()
                if dr > self.sc.offload_drain_s))
        self._ctrl_dirty = False

    def _ready_of(self, batches: Dict[int, List[Item]]
                  ) -> Dict[Tuple[int, int], List[Item]]:
        """Pure (side-effect-free) version of ``_on_tick``'s ready
        classification, used by the superstep planner on FUTURE ticks.
        Everything it reads — node liveness, query liveness/retirement —
        only mutates at boundary events, and plans never span one, so
        the plan-time result equals the fold-time result exactly."""
        ready: Dict[Tuple[int, int], List[Item]] = {}
        for edge, batch in batches.items():
            if edge in self.nodes.dead:
                continue
            for it in batch:
                if self.queries.live_on(it.query, edge):
                    ready.setdefault((it.query, edge), []).append(it)
        return ready

    # --- per-tick fused triage ------------------------------------------------
    def _on_tick(self, t: float, batches: Dict[int, List[Item]],
                 tick: int = -1) -> None:
        """One scheduler tick's arrivals: failover dead edges' batches,
        defer queries whose CQ weights haven't reached their edge yet, shed
        overloaded edges' raw batches via Eq. 7, triage everything else —
        every live query on every live edge — in ONE fused (Q, E, N)
        launch, enqueue per-route."""
        if self._release:
            # weights delivered since last tick: the items that were
            # waiting join this tick's batches (ONE launch covers both)
            merged = {e: list(b) for e, b in batches.items()}
            for e, pend in self._release.items():
                merged.setdefault(e, []).extend(pend)
            self._release = {}
            batches = merged
        live: Dict[int, List[Item]] = {}
        for edge, batch in batches.items():
            if edge in self.nodes.dead:
                # dead edge's cameras re-home: raw frames to survivors
                for it in batch:
                    if self.queries.is_shed(it.query):
                        self._shed_items += 1
                        continue
                    self._rerouted += 1
                    self._dispatch(t, edge, self._failover_task(it),
                                   count_escalated=False)
            else:
                live[edge] = batch
        if not live:
            return
        if self.track is not None:
            # cross-camera association: every live track query's embedded
            # detections, fleet-wide, in ONE fused similarity launch per
            # tick (same launch budget discipline as triage).  Runs before
            # the edge_only split so both cascade and edge_only schemes
            # track; cloud_only has no ticks, so it never associates.
            tb: Dict[Tuple[int, int], List[Item]] = {}
            for edge, batch in live.items():
                for it in batch:
                    if (it.query in self._track_qs
                            and it.emb is not None
                            and not self.queries.is_shed(it.query)
                            and self.queries.live_on(it.query, edge)):
                        tb.setdefault((it.query, edge), []).append(it)
            if tb:
                for done, upd in self.track.tick(t, tb):
                    self.events.push(done, upd)
        if self.sc.scheme == "edge_only":
            for edge, batch in live.items():
                for it in batch:
                    self._enqueue(t, edge, Task(it, "classify",
                                                it.conf > 0.5))
            return
        # split each edge batch along the query axis, holding back items
        # whose query can't be served on this edge yet: while the cloud
        # fine-tunes (or the weights ride the downlink), that query's
        # escalations are blocked by construction — nothing of it triages
        ready: Dict[Tuple[int, int], List[Item]] = {}
        for edge, batch in live.items():
            for it in batch:
                if self.queries.is_shed(it.query):
                    # admission refused this query: its detections drop
                    # (counted), they never defer and never triage
                    self._shed_items += 1
                elif self.queries.live_on(it.query, edge):
                    ready.setdefault((it.query, edge), []).append(it)
                elif self.queries.is_retired(it.query):
                    # straggler of a retired query: the edge answers with
                    # the pre-trained prior (no CQ model to consult)
                    self._enqueue(t, edge, Task(it, "classify",
                                                it.conf > 0.5))
                else:
                    self._deferred.setdefault((it.query, edge),
                                              []).append(it)
                    self._deferred_count[it.query] = \
                        self._deferred_count.get(it.query, 0) + 1
        if not ready:
            return
        self._triaged_ticks += 1
        if self.superstep.enabled:
            # scan-superstep path: this tick's routes/thresholds come out
            # of ONE fused multi-tick launch (built now if this tick
            # wasn't covered by a previous plan).  Control signals are
            # boundary-held: resampled at the first triaged tick after
            # each boundary event, constant in between.
            if self._ctrl_dirty:
                self._sample_ctrl(t)
            outs, ths = self.superstep.tick_out(tick, ready, self._ctrl)
            if self.sc.scheme == "surveiledge":
                for q, e in ready:
                    a, b = ths[(q, e)]
                    tag = f"{e}" if q == self.queries.default \
                        else f"{e}q{q}"
                    self.db.put(f"alpha{tag}", a)
                    self.db.put(f"beta{tag}", b)
                for key in [k for k in ready
                            if k[1] in self._ctrl.overloaded]:
                    shed = ready.pop(key)
                    self.bus.publish(
                        f"alerts/edge{key[1]}/shed_batch",
                        dict(t=t, query=key[0], items=len(shed)))
                    for it in shed:
                        self._rerouted += 1
                        self._dispatch(t, key[1],
                                       Task(it, "reclassify", None),
                                       count_escalated=False,
                                       exclude_src=True)
        else:
            self.triage_stage.refresh(t, sorted(ready))
            if self.sc.scheme == "surveiledge":
                for q, e in ready:
                    st = self.triage_stage.states[(q, e)]
                    tag = f"{e}" if q == self.queries.default \
                        else f"{e}q{q}"
                    self.db.put(f"alpha{tag}", st.alpha)
                    self.db.put(f"beta{tag}", st.beta)
                # a home edge that can't drain its queue within the gate
                # sheds this tick's raw batch — every query's — across
                # cloud/edges via Eq. 7 (the overloaded home has maximal
                # Q*t, so it is effectively skipped)
                overloaded = {e for _, e in ready
                              if self.sched.nodes[e].drain_time
                              > self.sc.offload_drain_s}
                for key in [k for k in ready if k[1] in overloaded]:
                    shed = ready.pop(key)
                    self.bus.publish(
                        f"alerts/edge{key[1]}/shed_batch",
                        dict(t=t, query=key[0], items=len(shed)))
                    for it in shed:
                        self._rerouted += 1
                        self._dispatch(t, key[1],
                                       Task(it, "reclassify", None),
                                       count_escalated=False,
                                       exclude_src=True)
                if self.sc.alert_threshold_drift is not None:
                    self._check_drift(t, ready)
            if not ready:
                return
            outs = self.triage_stage.triage_tick(ready)
        if not ready:
            return
        sc_spec = self.sc.speculative_escalation
        for (q, edge), items in ready.items():
            routes, slots, conf_used = outs[(q, edge)]
            for it, route, slot, cal in zip(items, routes, slots,
                                            conf_used):
                if route == ESCALATE and slot >= 0:
                    decision = None                 # cloud-model's call
                elif route == ESCALATE:             # capacity overflow:
                    # stays un-escalated; the edge decides with its LIVE
                    # (calibrated) confidence, same value the kernel
                    # routed on
                    decision = bool(cal > 0.5)
                else:
                    decision = route == ACCEPT
                task = Task(it, "classify", decision)
                if decision is None and sc_spec:
                    # speculative escalation: remember the verdict the
                    # edge's CQ would have given — it is served the
                    # instant the upload starts (see _on_done) and
                    # reconciled when the cloud answers
                    task.provisional = bool(cal > 0.5)
                self._enqueue(t, edge, task)

    def _check_drift(self, t: float,
                     ready: Dict[Tuple[int, int], List[Item]]) -> None:
        """Alert (once per (query, edge) row, latched) when Eqs. 8-9 have
        walked a row's (alpha, beta) further than ``alert_threshold_drift``
        from the scheme prototype — the health signal an operator watches
        to spot a bracket collapsing shut under sustained load."""
        a0, b0 = self._base_th
        for key in ready:
            if key in self._drift_alerted:
                continue
            st = self.triage_stage.states[key]
            if abs(st.alpha - a0) + abs(st.beta - b0) \
                    > self.sc.alert_threshold_drift:
                self._drift_alerted.add(key)
                self.bus.publish(
                    f"alerts/edge{key[1]}/threshold_drift",
                    dict(t=t, query=key[0], alpha=round(st.alpha, 4),
                         beta=round(st.beta, 4)))

    def _failover_task(self, it: Item, prior: Optional[Task] = None) -> Task:
        """A dead edge's work re-homed to a survivor: under edge_only the
        peer re-runs the CQ model (conf > 0.5); otherwise the heavyweight
        re-classifier answers.  A stranded speculative reclassify keeps its
        provisional verdict — the edge already served it, so the re-homed
        cloud answer must still reconcile against it."""
        if self.sc.scheme == "edge_only":
            return Task(it, "classify", it.conf > 0.5)
        task = Task(it, "reclassify", None)
        if prior is not None and prior.phase == "reclassify":
            task.provisional = prior.provisional
            task.t_provisional = prior.t_provisional
        return task

    def _fail_node(self, t: float, node: int) -> None:
        """Edge death: drop it from Eq. 7, re-dispatch its queued and
        in-flight work to survivors."""
        self.sched.mark_down(node)
        stranded = self.nodes.fail(t, node)
        self.sched.nodes[node].queue_len = 0
        self.db.put(f"Q{node}", 0)
        self.bus.publish(f"alerts/edge{node}/failover",
                         dict(t=t, stranded=len(stranded)))
        for task in stranded:
            self._rerouted += 1
            self._dispatch(t, node, self._failover_task(task.item, task),
                           count_escalated=False)
        # items parked on this edge waiting for CQ weights die with it:
        # survivors' accurate models answer them (the weights that were in
        # flight to the dead edge are simply never applied)
        for key in [k for k in self._deferred if k[1] == node]:
            for it in self._deferred.pop(key):
                self._rerouted += 1
                self._dispatch(t, node, self._failover_task(it),
                               count_escalated=False)
        for it in self._release.pop(node, []):
            self._rerouted += 1
            self._dispatch(t, node, self._failover_task(it),
                           count_escalated=False)

    def _on_done(self, t: float, node: int, task: Task, svc: float) -> None:
        if node in self.nodes.dead:
            return                               # work was re-dispatched
        self.nodes.complete(node)
        # The estimator sees SERVICE time only.  Transfer time is the
        # link's (Transport accumulates it); feeding it here would let one
        # WAN burst permanently inflate the cloud's t_0 while wan_backlog
        # separately charges the same congestion in Eq. 7 — double-counted.
        # Reclassify observations on an edge run reclassify_factor x the CQ
        # cost; normalize them so t_j stays a per-CQ-item estimate and a
        # classify/reclassify mix cannot bias drain_time (Eqs. 7-9).
        # (Known residual: Q_j * t_j prices a reclassify-laden queue in
        # CQ units, underestimating its true drain; pricing per-phase
        # queue composition is the fuller alternative the paper's Eq. 7
        # doesn't model either.)
        obs = svc
        if task.phase == "reclassify" and node != CLOUD:
            obs = svc / self.sc.reclassify_factor
        self.sched.on_complete(node, obs)
        self.db.put(f"t{node}", self.sched.nodes[node].estimator.t)
        self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
        if task.phase == "reclassify":
            # accurate model == ground truth (paper: ResNet-152) — and an
            # exact label for the home edge's CQ score (feedback loop);
            # a reconciliation FLIP is exactly the label the calibrator
            # most needs, so flips feed the ring buffers like any verdict
            self.feedback.observe(t, task.item)
            if task.provisional is not None:
                # reconcile the speculatively served verdict: accuracy
                # counts the cloud's answer, latency counts the moment
                # the edge actually answered the user
                self._reconciled += 1
                if task.provisional != task.item.is_query:
                    self._flips += 1
                self._finish(t, node, task.item, task.item.is_query,
                             serve_t=task.t_provisional)
            else:
                self._finish(t, node, task.item, task.item.is_query)
        elif task.decision is None:              # escalate: ship onward
            nxt = Task(task.item, "reclassify", None)
            if task.provisional is not None:
                # the upload starts NOW: the edge serves its provisional
                # verdict immediately (counted here, reconciled above)
                nxt.provisional = task.provisional
                nxt.t_provisional = t
                self._provisional += 1
                self._prov_lat_sum += t - task.item.t_arrival
            self._dispatch(t, node, nxt, count_escalated=True)
        else:
            self._finish(t, node, task.item, task.decision)
        if self.nodes.queues[node]:
            self._start_service(t, node)

    # --- driver seam: setup -> handle_event* -> finalize ----------------------
    def setup(self, items: Sequence[Item],
              frontend_timings: Optional[Dict[str, float]] = None) -> None:
        """Build run state and seed the event queue (pops no events —
        that is the driver's job)."""
        sc = self.sc
        self._frontend_timings = frontend_timings
        self.events = EventQueue()
        self.transport = Transport(sc)
        self.nodes = NodeBank(sc, self.service_s, self.rng)
        self.triage_stage = TriageStage(sc, self.sched, self.transport)
        self.feedback = FeedbackStage(sc, self.transport)
        self.queries = QuerySet(sc)
        # cross-camera track queries: the fleet-wide track registry exists
        # only when a track-kind query does (classify-only runs carry zero
        # extra state and stay bit-identical)
        self._track_qs = {q for q, sp in self.queries.specs.items()
                          if sp.kind == "track"}
        self.track = TrackStage(sc, self.transport) \
            if self._track_qs else None
        self._lat: List[float] = []
        self._dec: List[bool] = []
        self._tru: List[bool] = []
        self._fin: List[float] = []
        self._qid: List[int] = []
        self._escalated = 0
        self._rerouted = 0
        # speculative-escalation accounting: served provisionals, cloud
        # reconciliations, verdict flips, sum of provisional latencies
        self._provisional = 0
        self._reconciled = 0
        self._flips = 0
        self._prov_lat_sum = 0.0
        # (query, edge) -> items waiting for that query's CQ weights to
        # reach that edge; edge -> items released by a delivery, absorbed
        # by the next tick's fused launch
        self._deferred: Dict[Tuple[int, int], List[Item]] = {}
        self._release: Dict[int, List[Item]] = {}
        self._deferred_count: Dict[int, int] = {}
        self._train_total = 0.0
        # admission control (token-bucket tenant quotas + fine-tune
        # backlog shedding): with it on, Fig. 5 fine-tunes SERIALIZE on
        # the cloud (``_train_free_at`` is when it frees up), so a
        # submission wave builds exactly the backlog the controller sheds
        # on.  Off (the default), training stays concurrent —
        # bit-identical to the pre-control-plane engine.
        self.admission = AdmissionController(
            sc.tenants, sc.admission_backlog_s) \
            if (sc.tenants or sc.admission_backlog_s is not None) else None
        self._train_free_at = 0.0
        self._submitted = 0
        self._shed_queries = 0
        self._shed_items = 0
        # per-tier latency cells + SLO breach counts (tiers declared only)
        self._tier_acc = {k: MX._Acc() for k in self._tiers} \
            if self._tiers else None
        self._tier_breach = {k: 0 for k in self._tiers}
        self._drift_alerted: set = set()
        self._base_th = (self.triage_stage._proto.alpha,
                        self.triage_stage._proto.beta)
        self._tick_samples: List[Dict[int, int]] = []
        # streaming windowed aggregates (metrics_window_s): the per-item
        # report arrays stay empty and _finish folds into O(window) cells
        self._agg = MX.StreamingWindows(sc.metrics_window_s) \
            if sc.metrics_window_s is not None else None
        # scan-superstep driver (Scenario.superstep): fuses boundary-free
        # runs of ticks into one jitted scan + triage launch
        self.superstep = SuperstepDriver(self)
        self._ctrl: Optional[Ctrl] = None
        self._ctrl_dirty = True
        self._triaged_ticks = 0
        self._tick_batches: Dict[int, Dict[int, List[Item]]] = {}
        self._tick_order: List[int] = []

        # an item tagged with an undeclared query would defer forever (no
        # lifecycle events ever activate it) and silently vanish from the
        # report — reject the stream up front instead
        unknown = {it.query for it in items} - set(self.queries.specs)
        if unknown:
            raise ValueError(
                f"scenario {sc.name!r}: stream items reference undeclared "
                f"query ids {sorted(unknown)} (declared: "
                f"{sorted(self.queries.specs)})")

        # arrivals: cloud_only streams per item; the cascade/edge_only paths
        # batch each tick's detections into ONE TickArrivals event (the
        # cascade schemes triage it with a single fused fleet launch)
        last_t = max((it.t_arrival for it in items), default=0.0)
        n_ticks = self._n_ticks = max(1, int(math.ceil(
            max(sc.duration_s, last_t + 1e-9) / sc.interval_s)))
        if sc.scheme == "cloud_only":
            for it in items:
                self.events.push(it.t_arrival, Arrive(it))
        else:
            for k, batches in group_arrivals(items, sc.interval_s):
                # kept (sorted) for the superstep planner, which packs
                # future arrival ticks into the current fused launch
                self._tick_batches[k] = batches
                self._tick_order.append(k)
                self.events.push((k + 1) * sc.interval_s,
                                 TickArrivals(batches, k))
        for k in range(1, n_ticks + 1):
            self.events.push(k * sc.interval_s, Sample())
        for t_fail, node in sc.failures:
            self.events.push(t_fail, EdgeFail(node))
        if self.queries.lifecycle:
            for sp in sorted(self.queries.specs.values(),
                             key=lambda s: s.query):
                self.events.push(sp.t_arrive_s,
                                 QueryArrival(sp.query, sp.kind))
                if sp.t_retire_s is not None:
                    self.events.push(sp.t_retire_s, QueryRetire(sp.query))
        if self.feedback.enabled:
            horizon = n_ticks * sc.interval_s
            k = 1
            while k * sc.update_period_s <= horizon + 1e-9:
                self.events.push(k * sc.update_period_s, FeedbackTick())
                k += 1

    def handle_event(self, t: float, ev: object) -> None:
        """Apply ONE event.  Drivers own the loop (SimDriver drains the
        heap; AsyncDriver pumps it from asyncio); this owns the physics —
        every driver funnels through here, which is what makes the
        sim-vs-async differential tests meaningful."""
        sc = self.sc
        if isinstance(ev, BOUNDARY_EVENTS):
            # boundary events mutate state the fused superstep math
            # reads: the boundary-held control signals resample at
            # the next triaged tick (and plans never span this pop —
            # the planner stopped strictly before it)
            self._ctrl_dirty = True
        if isinstance(ev, Sample):
            self._tick_samples.append({
                n: self.nodes.occupancy(n) for n in self.service_s})
            if sc.alert_queue_depth is not None:
                for e in sc.edge_ids:
                    if e in self.nodes.dead:
                        continue
                    occ = self.nodes.occupancy(e)
                    if occ > sc.alert_queue_depth:
                        self.bus.publish(f"alerts/edge{e}/queue_depth",
                                         dict(t=t, depth=occ))
        elif isinstance(ev, Arrive):         # cloud_only
            it = ev.item
            task = Task(it, "reclassify", None)
            done = self.transport.wan_send(t, it.nbytes)
            task.tx_s = done - t
            self.events.push(done, Transfer(CLOUD, task))
        elif isinstance(ev, TickArrivals):
            self._on_tick(t, ev.batches, ev.tick)
        elif isinstance(ev, Transfer):
            if ev.node in self.nodes.dead:   # died while in transit
                self._rerouted += 1
                self._dispatch(t, ev.node, ev.task,
                               count_escalated=False)
            else:
                self._enqueue(t, ev.node, ev.task)
        elif isinstance(ev, EdgeFail):
            if ev.node not in self.nodes.dead:
                self._fail_node(t, ev.node)
        elif isinstance(ev, QueryArrival):
            self._on_query_arrival(t, ev.query)
        elif isinstance(ev, TrainDone):
            if not self.queries.is_retired(ev.query):
                # ship the fresh CQ weights to every live edge over the
                # shared WAN downlink (FIFO: a fleet-wide push
                # serializes, so edges go live staggered)
                for e in sorted(self.sc.edge_ids):
                    if e in self.nodes.dead:
                        continue
                    # weights ship through the quantized wire path
                    # (simulated model: byte accounting only — the
                    # accuracy cost of int8 CQ weights is measured by
                    # the report gate's F2 band, not re-simulated)
                    done, _ = self.transport.ship_update(
                        t, self.sc.cq_nbytes)
                    self.events.push(done, ModelUpdate(
                        e, None, query=ev.query, kind="weights"))
        elif isinstance(ev, QueryRetire):
            self.queries.retire(ev.query)
            self.triage_stage.retire_query(ev.query)
            self.feedback.retire_query(ev.query)
            if self.track is not None:
                # the query's fleet-wide track table dies with it
                self.track.retire_query(ev.query)
            # stragglers still waiting for weights are answered with
            # the pre-trained prior; in-flight escalations complete
            # normally and are still counted
            for key in [k for k in self._deferred if k[0] == ev.query]:
                q, e = key
                for it in self._deferred.pop(key):
                    self._enqueue(t, e, Task(it, "classify",
                                             it.conf > 0.5))
        elif isinstance(ev, ReleaseTick):
            # only fires a launch if this tick boundary had no natural
            # TickArrivals (which would have absorbed the release)
            if self._release:
                self._on_tick(t, {}, ev.tick)
        elif isinstance(ev, FeedbackTick):
            # one fused fleet recalibration launch; the per-row
            # results land as ModelUpdate events at downlink delivery
            for done, update in self.feedback.tick(
                    t, self.nodes.dead, self.queries.retired):
                self.events.push(done, update)
        elif isinstance(ev, ModelUpdate):
            if ev.kind == "weights":
                if ev.edge in self.nodes.dead \
                        or self.queries.is_retired(ev.query):
                    return
                self.queries.activate(ev.query, ev.edge)
                pend = self._deferred.pop((ev.query, ev.edge), None)
                if pend:
                    self._release.setdefault(ev.edge, []).extend(pend)
                    self.events.push(
                        (math.floor(t / sc.interval_s) + 1)
                        * sc.interval_s,
                        ReleaseTick(int(math.floor(t / sc.interval_s))))
            elif ev.kind == "prewarm":
                # predictive hand-off delivered: the edge holds the
                # query's track state hot for prewarm_ttl_s.  A late
                # delivery (target already arrived cold) simply misses —
                # that is the stale-in-flight cost the ablation measures.
                if ev.edge not in self.nodes.dead \
                        and not self.queries.is_retired(ev.query) \
                        and self.track is not None:
                    self.track.apply_prewarm(t, ev.query, ev.edge)
            elif ev.edge not in self.nodes.dead \
                    and not self.queries.is_retired(ev.query):
                # a calibration that retired mid-flight must not undo
                # retire_query's reset
                self.triage_stage.apply_update(ev.query, ev.edge,
                                               ev.params)
        else:
            assert isinstance(ev, ServiceDone), ev
            self._on_done(t, ev.node, ev.task, ev.service_s)

    def _on_query_arrival(self, t: float, query: int) -> None:
        """A query submission reaches the cloud.

        Without admission (the default): the Fig. 5 fine-tune is charged
        immediately and concurrently — bit-identical to the
        pre-control-plane engine.  With admission: the submission first
        passes its tenant's token bucket, then the fine-tune-backlog gate
        (tier-scaled allowance; tier 0 exempt) — a refusal sheds the query
        (its stream items drop, counted) and publishes an
        ``alerts/admission/<reason>`` event; an accepted query's fine-tune
        QUEUES behind the cloud's in-flight ones."""
        sp = self.queries.specs[query]
        if self.admission is not None:
            self._submitted += 1
            backlog = max(0.0, self._train_free_at - t)
            reason = self.admission.admit(t, sp.tenant, sp.tier, backlog)
            if reason is not None:
                self.queries.shed_query(query)
                self._shed_queries += 1
                self.bus.publish(
                    f"alerts/admission/{reason}",
                    dict(t=t, query=query, tenant=sp.tenant, tier=sp.tier,
                         backlog_s=round(backlog, 3)))
                return
            start = max(t, self._train_free_at)
            dt = self.queries.arrive(query, start)
            self.nodes.busy_s[CLOUD] += dt
            self._train_total += dt
            self._train_free_at = start + dt
            self.events.push(start + dt, TrainDone(query))
            return
        # charge the Fig. 5 fine-tune on the cloud; this query's
        # detections defer (its escalations are blocked) until its
        # weights deliver per edge
        dt = self.queries.arrive(query, t)
        self.nodes.busy_s[CLOUD] += dt
        self._train_total += dt
        self.events.push(t + dt, TrainDone(query))

    def register_query(self, sp: QuerySpec) -> None:
        """Admit a runtime-submitted query into every stage's state
        (serving/api.py's ``QueryAPI.submit`` calls this, then pushes the
        ``QueryArrival`` event that starts the lifecycle)."""
        self.queries.register(sp)
        self._tier_of[sp.query] = sp.tier
        tsp = self._tiers.get(sp.tier)
        self.triage_stage.add_query(sp.query,
                                    tsp.weight if tsp is not None else 0.0)
        self.feedback.add_query(sp.query)
        if sp.kind == "track":
            self._track_qs.add(sp.query)
            if self.track is None:
                self.track = TrackStage(self.sc, self.transport)

    def finalize(self) -> MX.QueryReport:
        """Assemble the QueryReport once the driver has drained the run."""
        sc = self.sc
        qinfo: Dict[int, Dict] = {}
        if sc.queries or len(self.queries.specs) > 1:
            by_query = self.triage_stage.thresholds_by_query()
            for q, sp in sorted(self.queries.specs.items()):
                qinfo[q] = {
                    "train_scheme": sp.train_scheme,
                    "t_arrive_s": sp.t_arrive_s,
                    "t_retire_s": sp.t_retire_s,
                    "train_s": round(self.queries.train_s.get(q, 0.0), 3),
                    "deferred": self._deferred_count.get(q, 0),
                    "live_edges": sorted(self.queries.live_edges[q]),
                    "thresholds": {e: (round(a, 4), round(b, 4))
                                   for e, (a, b) in
                                   sorted(by_query.get(q, {}).items())}
                    if sc.scheme in ("surveiledge", "surveiledge_fixed")
                    else {},
                }
        tier_rows: Dict[int, Dict[str, float]] = {}
        if self._tier_acc is not None:
            for k in sorted(self._tier_acc):
                acc = self._tier_acc[k]
                tier_rows[k] = {
                    "n": acc.n,
                    "mean_latency_s": acc.mean,
                    "p99_latency_s": acc.percentile(0.99),
                    "slo_s": self._tiers[k].slo_s,
                    "slo_breaches": self._tier_breach[k],
                }
        # cross-camera track accounting (absent -> zeros, summary stays
        # schema-identical for classify-only runs)
        trk = self.track
        track_kwargs = dict(
            track_items=trk.items,
            tracks_born=trk.tracks_born,
            track_matches=trk.matches,
            id_switches=trk.id_switches,
            track_opportunities=trk.opportunities,
            track_handoffs=trk.handoffs,
            prewarms_shipped=trk.prewarms,
            prewarm_hits=trk.prewarm_hits,
            track_launches=trk.launches,
        ) if trk is not None else {}
        return MX.QueryReport(
            scenario=sc.name,
            scheme=sc.scheme,
            latencies=np.asarray(self._lat),
            decisions=np.asarray(self._dec, bool),
            truths=np.asarray(self._tru, bool),
            finish_times=np.asarray(self._fin),
            query_ids=np.asarray(self._qid, np.int64),
            queries=qinfo,
            cloud_train_s=self._train_total,
            uploaded_bytes=self.transport.uploaded_bytes,
            lan_bytes=self.transport.lan_bytes,
            downloaded_bytes=self.transport.downloaded_bytes,
            downlink_fp_bytes=self.transport.downlink_fp_bytes,
            model_updates=self.feedback.model_updates,
            provisional=self._provisional,
            reconciled=self._reconciled,
            reconciliation_flips=self._flips,
            provisional_latency_sum=self._prov_lat_sum,
            wan_transfer_s=self.transport.wan_transfer_s,
            lan_transfer_s=self.transport.lan_transfer_s,
            escalated=self._escalated,
            rerouted=self._rerouted,
            kernel_launches=self.triage_stage.launches,
            supersteps=self.superstep.supersteps,
            triaged_ticks=self._triaged_ticks,
            stream=self._agg,
            ticks=self._n_ticks,
            queue_timeline=MX.merge_timelines(self._tick_samples),
            per_node_busy=dict(self.nodes.busy_s),
            per_node_served=dict(self.nodes.served),
            thresholds=self.triage_stage.final_thresholds()
            if sc.scheme in ("surveiledge", "surveiledge_fixed") else {},
            stage_timings={**(self._frontend_timings or {}),
                           "triage_s": self.triage_stage.elapsed_s,
                           **({"associate_s": trk.elapsed_s}
                              if trk is not None else {})},
            alerts=self.alerts.snapshot(),
            submitted_queries=self._submitted,
            shed_queries=self._shed_queries,
            shed_items=self._shed_items,
            tier_latency=tier_rows,
            **track_kwargs,
            edge_health={e: self.alerts.health_snapshot(e)
                         for e in sc.edge_ids},
        )

    def run(self, items: Sequence[Item],
            frontend_timings: Optional[Dict[str, float]] = None
            ) -> MX.QueryReport:
        """setup -> drive (the injected driver, or SimDriver) -> finalize."""
        self.setup(items, frontend_timings)
        (self.driver or SimDriver()).drive(self)
        return self.finalize()


def run_query(scenario: Scenario, *,
              items: Optional[Sequence[Item]] = None,
              frontend: Optional[object] = None,
              driver: Optional[object] = None) -> MX.QueryReport:
    """Run one query scenario end to end and return its ``QueryReport``.

    All knobs are keyword-only; the positional surface is the scenario.

    ``frontend`` is the ONE seam that picks the detection stream:

      "confidence" (default)   ``ConfidenceStreamFrontend`` over ``items``
                               (or ``scenario.items``) — a pre-scored
                               stream re-homed onto this scenario's
                               topology, or, with no items, the model-free
                               synthetic stream from the camera fleet.
      "pixel"                  the paper's full pixel path
                               (``repro.system.pixel_frontend``): rendered
                               frames -> Pallas framediff/morphology ->
                               motion crops -> CQ-classifier confidences,
                               with per-stage wall-clock in
                               ``QueryReport.stage_timings``.
      a ``Frontend`` instance  anything implementing the seam, for custom
                               streams (mutually exclusive with ``items``).

    ``driver`` selects the event-loop strategy: None/``SimDriver`` for the
    classic DES, or ``repro.serving.engine.AsyncDriver`` to pump the same
    events from asyncio (virtual or wall clock) — the real-time serving
    mode with live query submission (``repro.serving.api.QueryAPI``).
    """
    if isinstance(frontend, str):
        if frontend == "confidence":
            frontend = ConfidenceStreamFrontend(
                items if items is not None else scenario.items)
            items = None
        elif frontend == "pixel":
            if items is not None:
                raise ValueError(
                    "items= cannot combine with frontend='pixel' "
                    "(the pixel path renders its own stream)")
            from repro.system.pixel_frontend import PixelFrontend
            frontend = PixelFrontend()
        else:
            raise ValueError(
                f"unknown frontend {frontend!r} (expected 'confidence', "
                "'pixel', or a Frontend instance)")
    if frontend is not None and items is not None:
        raise ValueError("pass either items= or frontend=, not both "
                         "(a custom frontend produces its own stream)")
    if frontend is None:
        frontend = ConfidenceStreamFrontend(
            items if items is not None else scenario.items)
    stream = frontend.stream(scenario)
    return QueryPipeline(scenario, driver=driver).run(
        stream, frontend_timings=frontend.timings)
