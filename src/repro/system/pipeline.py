"""End-to-end multi-camera cloud-edge query pipeline (the paper's system).

Tick-driven, event-accurate harness composing every SurveilEdge piece:

  camera streams         repro.data.synthetic_video arrivals (or a pre-scored
        |                workload from repro.serving.workload)
  per-edge batched       ONE ``triage_batched`` Pallas launch per edge per
  cascade triage         tick over all of that edge's camera detections,
        |                with the *current* Eqs. 8-9 thresholds as runtime
        |                inputs (no retrace as they adapt)
  Eq. 7 allocator        escalations routed to argmin_j Q_j * t_j across the
        |                cloud and every live edge (repro.core.scheduler)
  per-node queues        FIFO service with calibrated latency profiles: edge
        |                CQ model vs cloud model vs heavyweight re-classify,
        |                WAN uplink as a shared FIFO, LAN edge-to-edge links
  metrics                per-query latency / F2 accuracy / bandwidth + queue
                         timelines (repro.system.metrics.QueryReport)

Thresholds adapt online: every enqueue/complete refreshes Eqs. 8-9 through
the scheduler exactly as the in-process parameter bus replicates them.
Beyond-paper stress is first-class: scenarios may declare traffic bursts and
mid-run edge failures (queued work is re-dispatched, the dead edge's cameras
re-home to surviving nodes via Eq. 7).

Entry point: ``run_query(scenario) -> QueryReport``.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.scheduler import CLOUD, Scheduler
from repro.core.thresholds import ThresholdState
from repro.kernels import ops
from repro.serving.bus import Bus, FifoLink, ParamDB
from repro.serving.simulator import Item
from repro.system import metrics as MX
from repro.system.scenario import Scenario, synthetic_confidence_stream

# route codes emitted by the triage kernel
ACCEPT, REJECT, ESCALATE = 0, 1, 2


@dataclasses.dataclass
class _Task:
    """One item travelling through the pipeline."""
    item: Item
    phase: str                    # 'classify' (CQ) or 'reclassify' (accurate)
    decision: Optional[bool]      # set for classify tasks at triage time
    tx_s: float = 0.0             # transfer time to attribute to the node


class QueryPipeline:
    """Event loop over one scenario.  Build once, ``run()`` once."""

    def __init__(self, sc: Scenario):
        self.sc = sc
        self.rng = np.random.default_rng(sc.seed + 1)
        # topology: cloud is node 0, edges 1..E (service-time multipliers)
        self.service_s: Dict[int, float] = {
            CLOUD: sc.edge_service_s / sc.cloud_speedup}
        for nid, mult in zip(sc.edge_ids, sc.edge_speeds):
            self.service_s[nid] = sc.edge_service_s * mult
        for t_fail, nid in sc.failures:
            if nid not in self.service_s or nid == CLOUD:
                raise ValueError(
                    f"scenario {sc.name!r}: failure at t={t_fail} references "
                    f"node {nid}, but failable edges are {list(sc.edge_ids)}")
        # the pipeline owns the cascade thresholds: Eqs. 8-9 are driven once
        # per edge-batch by the drain of the node Eq. 7 would hand an
        # escalation to (incl. WAN backlog), with slow idle-widening —
        # not by every parameter write as the per-write refresh inside
        # Scheduler does (that oscillates between idle edges and the
        # loaded cloud path).  The scheduler keeps its own default
        # ThresholdState, which this pipeline never reads.
        if sc.scheme == "surveiledge_fixed":
            a, b = sc.fixed_thresholds or (0.8, 0.1)
            self.th = ThresholdState(alpha=a, beta=b, gamma1=0.0,
                                     gamma2=b / max(1.0 - a, 1e-6))
        else:
            self.th = ThresholdState(gamma1_up=0.005)
        self.sched = Scheduler(sorted(self.service_s),
                               interval_s=sc.interval_s)
        self.bus = Bus()
        self.db = ParamDB(self.bus)
        for nid, svc in self.service_s.items():
            self.db.put(f"t{nid}", svc)
            self.db.put(f"Q{nid}", 0)
            self.sched.nodes[nid].estimator.t = svc

    # --- stochastic service / links ------------------------------------------
    def _service_time(self, node: int, phase: str) -> float:
        base = self.service_s[node]
        if phase == "reclassify" and node != CLOUD:
            base *= self.sc.reclassify_factor
        return float(base * self.rng.lognormal(0.0, 0.15))

    def _wan_done(self, t: float, nbytes: int) -> float:
        """Shared WAN uplink: FIFO — concurrent uploads serialize."""
        return self._uplink.send(t, nbytes)

    def _lan_done(self, t: float, nbytes: int) -> float:
        """Edge-to-edge link: dedicated, non-contending."""
        return t + nbytes / (self.sc.lan_MBps * 1e6) + self.sc.rtt_s

    def _uplink_backlog(self, t: float) -> float:
        """Seconds of queued WAN transfers ahead of a new upload."""
        return self._uplink.backlog(t)

    # --- event machinery ------------------------------------------------------
    def _push(self, t: float, kind: str, payload) -> None:
        self._seq += 1
        heapq.heappush(self._pq, (t, self._seq, kind, payload))

    def _enqueue(self, t: float, node: int, task: _Task) -> None:
        self._queues[node].append(task)
        self.sched.on_enqueue(node)
        self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
        if not self._busy[node]:
            self._start_service(t, node)

    def _start_service(self, t: float, node: int) -> None:
        task = self._queues[node].pop(0)
        self._busy[node] = True
        svc = self._service_time(node, task.phase)
        self._inflight[node] = (task, svc, t)
        self._busy_s[node] += svc
        self._push(t + svc, "done", (node, task, svc))

    def _finish(self, t: float, node: int, it: Item, decision: bool) -> None:
        self._lat.append(t - it.t_arrival)
        self._dec.append(decision)
        self._tru.append(it.is_query)
        self._fin.append(t)
        self._served[node] += 1

    def _dispatch(self, t: float, src: int, task: _Task,
                  count_escalated: bool, exclude_src: bool = False) -> None:
        """Route one re-classification task via Eq. 7 and ship it.

        ``exclude_src`` is for overload shedding: work shed *because* src
        is drowning must not be allowed to win the argmin and land right
        back on src at the heavier re-classify cost.
        """
        if self.sc.scheme == "surveiledge_fixed":
            target = CLOUD          # local-edge-first: escalations go up
        else:
            try:
                # edge_only has no cloud path: its failovers stay on the
                # surviving edges (cloud only as a last resort below)
                target = self.sched.select_node(
                    exclude_cloud=self.sc.scheme == "edge_only",
                    exclude={src} if exclude_src else (),
                    extra_cost={CLOUD: self._uplink_backlog(t)})
            except ValueError:
                target = CLOUD      # the cloud never fails in our scenarios
        if count_escalated:
            self._escalated += 1
        nbytes = task.item.nbytes
        if target == src:
            self._push(t, "xfer", (target, task))
        elif target == CLOUD:
            self._uploaded += nbytes
            done = self._wan_done(t, nbytes)
            task.tx_s += done - t
            self._push(done, "xfer", (target, task))
        else:
            self._lan_bytes += nbytes
            done = self._lan_done(t, nbytes)
            task.tx_s += done - t
            self._push(done, "xfer", (target, task))

    # --- per-tick batched triage ---------------------------------------------
    def _refresh_thresholds(self, t: float, edge: int) -> None:
        """Eqs. 8-9 driven by the drain of "the chosen queue": the busiest
        of this edge's own queue (where classification tasks land) and the
        node Eq. 7 would hand an escalation to (incl. WAN backlog)."""
        if self.sc.scheme != "surveiledge":
            return
        try:
            d = self.sched.select_node(
                extra_cost={CLOUD: self._uplink_backlog(t)})
        except ValueError:
            d = CLOUD
        esc_drain = self.sched.nodes[d].drain_time
        if d == CLOUD:
            esc_drain += self._uplink_backlog(t)
        drain = max(self.sched.nodes[edge].drain_time, esc_drain)
        self.th = self.th.update(drain, 1.0, self.sc.interval_s)
        self.db.put("alpha", self.th.alpha)
        self.db.put("beta", self.th.beta)

    def _triage_batch(self, t: float, edge: int, batch: List[Item]) -> None:
        self._refresh_thresholds(t, edge)
        th = self.th
        conf = np.asarray([it.conf for it in batch], np.float32)
        routes, slots, _ = ops.triage_batched(
            conf, alpha=th.alpha, beta=th.beta,
            capacity=self.sc.escalation_capacity)
        self._launches += 1
        routes, slots = np.asarray(routes), np.asarray(slots)
        if (self.sc.scheme == "surveiledge"
                and self.sched.nodes[edge].drain_time
                > self.sc.offload_drain_s):
            # the home edge can't drain its queue within the gate: the Eq. 7
            # allocator sheds this tick's raw batch across cloud/edges (the
            # overloaded home has maximal Q*t, so it is effectively skipped)
            for it in batch:
                self._rerouted += 1
                self._dispatch(t, edge, _Task(it, "reclassify", None),
                               count_escalated=False, exclude_src=True)
            return
        for it, route, slot in zip(batch, routes, slots):
            if route == ESCALATE and slot >= 0:
                decision = None                     # cloud-model's call
            elif route == ESCALATE:                 # capacity overflow:
                decision = it.conf > 0.5            # stays un-escalated
            else:
                decision = route == ACCEPT
            self._enqueue(t, edge, _Task(it, "classify", decision))

    def _failover_task(self, it: Item) -> _Task:
        """A dead edge's work re-homed to a survivor: under edge_only the
        peer re-runs the CQ model (conf > 0.5); otherwise the heavyweight
        re-classifier answers."""
        if self.sc.scheme == "edge_only":
            return _Task(it, "classify", it.conf > 0.5)
        return _Task(it, "reclassify", None)

    def _fail_node(self, t: float, node: int) -> None:
        """Edge death: drop it from Eq. 7, re-dispatch its queued and
        in-flight work to survivors."""
        self._dead.add(node)
        self.sched.mark_down(node)
        stranded = list(self._queues[node])
        self._queues[node].clear()
        if self._inflight[node] is not None:
            task, svc, started = self._inflight[node]
            stranded.insert(0, task)
            self._inflight[node] = None
            # aborted mid-service: the node did work from `started` until
            # the failure; only the unserved remainder is not busy time
            self._busy_s[node] -= max(0.0, svc - (t - started))
        self._busy[node] = False
        self.sched.nodes[node].queue_len = 0
        self.db.put(f"Q{node}", 0)
        for task in stranded:
            self._rerouted += 1
            self._dispatch(t, node, self._failover_task(task.item),
                           count_escalated=False)

    # --- main loop ------------------------------------------------------------
    def run(self, items: Sequence[Item]) -> MX.QueryReport:
        sc = self.sc
        cascade = sc.scheme in ("surveiledge", "surveiledge_fixed")
        self._pq: List = []
        self._seq = 0
        self._uplink = FifoLink(sc.uplink_MBps, sc.rtt_s)
        self._queues: Dict[int, List[_Task]] = {n: [] for n in self.service_s}
        self._busy: Dict[int, bool] = {n: False for n in self.service_s}
        self._inflight: Dict[int, Optional[Tuple[_Task, float, float]]] = {
            n: None for n in self.service_s}
        self._busy_s: Dict[int, float] = {n: 0.0 for n in self.service_s}
        self._served: Dict[int, int] = {n: 0 for n in self.service_s}
        self._dead: set = set()
        self._lat: List[float] = []
        self._dec: List[bool] = []
        self._tru: List[bool] = []
        self._fin: List[float] = []
        self._uploaded = 0
        self._lan_bytes = 0
        self._escalated = 0
        self._rerouted = 0
        self._launches = 0
        tick_samples: List[Dict[int, int]] = []

        # arrivals: cloud_only streams per item; the cascade/edge_only paths
        # batch each tick's detections per home edge (one triage launch each)
        last_t = max((it.t_arrival for it in items), default=0.0)
        n_ticks = max(1, int(math.ceil(
            max(sc.duration_s, last_t + 1e-9) / sc.interval_s)))
        if sc.scheme == "cloud_only":
            for it in items:
                self._push(it.t_arrival, "arrive", it)
        else:
            groups: Dict[Tuple[int, int], List[Item]] = {}
            for it in items:
                k = int(it.t_arrival // sc.interval_s)
                groups.setdefault((k, it.edge_device), []).append(it)
            for (k, edge), batch in sorted(groups.items()):
                self._push((k + 1) * sc.interval_s, "batch", (edge, batch))
        for k in range(1, n_ticks + 1):
            self._push(k * sc.interval_s, "sample", None)
        for t_fail, node in sc.failures:
            self._push(t_fail, "fail", node)

        while self._pq:
            t, _, kind, payload = heapq.heappop(self._pq)
            if kind == "sample":
                tick_samples.append({
                    n: len(self._queues[n]) + int(self._busy[n])
                    for n in self.service_s})
            elif kind == "arrive":               # cloud_only
                it = payload
                self._uploaded += it.nbytes
                task = _Task(it, "reclassify", None)
                done = self._wan_done(t, it.nbytes)
                task.tx_s = done - t
                self._push(done, "xfer", (CLOUD, task))
            elif kind == "batch":
                edge, batch = payload
                if edge in self._dead:
                    # dead edge's cameras re-home: raw frames to survivors
                    for it in batch:
                        self._rerouted += 1
                        self._dispatch(t, edge, self._failover_task(it),
                                       count_escalated=False)
                elif cascade:
                    self._triage_batch(t, edge, batch)
                else:                            # edge_only
                    for it in batch:
                        self._enqueue(t, edge, _Task(it, "classify",
                                                     it.conf > 0.5))
            elif kind == "xfer":
                node, task = payload
                if node in self._dead:           # died while in transit
                    self._rerouted += 1
                    self._dispatch(t, node, task, count_escalated=False)
                else:
                    self._enqueue(t, node, task)
            elif kind == "fail":
                if payload not in self._dead:
                    self._fail_node(t, payload)
            elif kind == "done":
                node, task, svc = payload
                if node in self._dead:
                    continue                     # work was re-dispatched
                self._busy[node] = False
                self._inflight[node] = None
                self.sched.on_complete(node, svc + task.tx_s)
                self.db.put(f"t{node}", self.sched.nodes[node].estimator.t)
                self.db.put(f"Q{node}", self.sched.nodes[node].queue_len)
                if task.phase == "reclassify":
                    # accurate model == ground truth (paper: ResNet-152)
                    self._finish(t, node, task.item, task.item.is_query)
                elif task.decision is None:      # escalate: ship onward
                    self._dispatch(t, node,
                                   _Task(task.item, "reclassify", None),
                                   count_escalated=True)
                else:
                    self._finish(t, node, task.item, task.decision)
                if self._queues[node]:
                    self._start_service(t, node)

        return MX.QueryReport(
            scenario=sc.name,
            scheme=sc.scheme,
            latencies=np.asarray(self._lat),
            decisions=np.asarray(self._dec, bool),
            truths=np.asarray(self._tru, bool),
            finish_times=np.asarray(self._fin),
            uploaded_bytes=self._uploaded,
            lan_bytes=self._lan_bytes,
            escalated=self._escalated,
            rerouted=self._rerouted,
            kernel_launches=self._launches,
            ticks=n_ticks,
            queue_timeline=MX.merge_timelines(tick_samples),
            per_node_busy=dict(self._busy_s),
            per_node_served=dict(self._served),
        )


def run_query(scenario: Scenario,
              items: Optional[Sequence[Item]] = None) -> MX.QueryReport:
    """Run one query scenario end to end and return its ``QueryReport``.

    ``items`` (or ``scenario.items``) injects a pre-scored detection stream
    — e.g. the CQ-model-scored benchmark workload; camera->edge homes are
    remapped onto this scenario's topology.  Otherwise a model-free stream
    is synthesized from the scenario's camera fleet.
    """
    stream = items if items is not None else scenario.items
    if stream is None:
        stream = synthetic_confidence_stream(scenario)
    else:
        E = scenario.num_edges
        stream = [dataclasses.replace(
            it, edge_device=(it.edge_device - 1) % E + 1) for it in stream]
        stream.sort(key=lambda it: it.t_arrival)
    return QueryPipeline(scenario).run(stream)
