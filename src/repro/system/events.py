"""Typed simulation events + the time-ordered event queue.

The pipeline's event loop is a plain priority queue over ``(time, seq,
event)`` triples; ``seq`` breaks time ties in push order, which the
orchestrator relies on (per-tick arrival batches are pushed before the
tick's queue-length sample, failures after both).  Events are small frozen
dataclasses so each handler dispatches on type, not on string tags.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.serving.simulator import Item


@dataclasses.dataclass
class Task:
    """One item travelling through the pipeline."""
    item: Item
    phase: str                    # 'classify' (CQ) or 'reclassify' (accurate)
    decision: Optional[bool]      # set for classify tasks at triage time
    tx_s: float = 0.0             # transfer time to attribute to the node


@dataclasses.dataclass(frozen=True)
class Sample:
    """Per-tick queue-length sampling point."""


@dataclasses.dataclass(frozen=True)
class Arrive:
    """One item entering the system directly (cloud_only streams per item)."""
    item: Item


@dataclasses.dataclass(frozen=True)
class TickArrivals:
    """All of one scheduler tick's detections, grouped by home edge.

    The cascade schemes consume this as ONE fused fleet-triage launch."""
    batches: Dict[int, List[Item]]


@dataclasses.dataclass(frozen=True)
class Transfer:
    """A task finishing its WAN/LAN transfer and landing on ``node``."""
    node: int
    task: Task


@dataclasses.dataclass(frozen=True)
class EdgeFail:
    """Edge ``node`` dies at this instant."""
    node: int


@dataclasses.dataclass(frozen=True)
class ServiceDone:
    """``node`` finishes serving ``task`` after ``service_s`` seconds."""
    node: int
    task: Task
    service_s: float


class EventQueue:
    """Min-heap of timestamped events with stable FIFO tie-breaking."""

    def __init__(self) -> None:
        self._pq: List[Tuple[float, int, object]] = []
        self._seq = 0

    def push(self, t: float, event: object) -> None:
        self._seq += 1
        heapq.heappush(self._pq, (t, self._seq, event))

    def pop(self) -> Tuple[float, object]:
        t, _, event = heapq.heappop(self._pq)
        return t, event

    def __bool__(self) -> bool:
        return bool(self._pq)

    def __len__(self) -> int:
        return len(self._pq)
