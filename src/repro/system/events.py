"""Typed simulation events + the time-ordered event queue.

The pipeline's event loop is a plain priority queue over ``(time, seq,
event)`` triples; ``seq`` breaks time ties in push order, which the
orchestrator relies on (per-tick arrival batches are pushed before the
tick's queue-length sample, failures after both).  Events are small frozen
dataclasses so each handler dispatches on type, not on string tags.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.serving.simulator import Item


@dataclasses.dataclass
class Task:
    """One item travelling through the pipeline."""
    item: Item
    phase: str                    # 'classify' (CQ) or 'reclassify' (accurate)
    decision: Optional[bool]      # set for classify tasks at triage time
    tx_s: float = 0.0             # seconds this task spent on the wire
    #                               (informational; the aggregate lives in
    #                               Transport — never fed to the node
    #                               latency estimators, which would let one
    #                               congestion burst bias Eq. 7 forever)


@dataclasses.dataclass(frozen=True)
class Sample:
    """Per-tick queue-length sampling point."""


@dataclasses.dataclass(frozen=True)
class Arrive:
    """One item entering the system directly (cloud_only streams per item)."""
    item: Item


@dataclasses.dataclass(frozen=True)
class TickArrivals:
    """All of one scheduler tick's detections, grouped by home edge.

    The cascade schemes consume this as ONE fused fleet-triage launch."""
    batches: Dict[int, List[Item]]


@dataclasses.dataclass(frozen=True)
class Transfer:
    """A task finishing its WAN/LAN transfer and landing on ``node``."""
    node: int
    task: Task


@dataclasses.dataclass(frozen=True)
class EdgeFail:
    """Edge ``node`` dies at this instant."""
    node: int


@dataclasses.dataclass(frozen=True)
class ServiceDone:
    """``node`` finishes serving ``task`` after ``service_s`` seconds."""
    node: int
    task: Task
    service_s: float


@dataclasses.dataclass(frozen=True)
class FeedbackTick:
    """Periodic cloud-side recalibration instant (every ``update_period_s``).

    The feedback stage fits every ready edge's Platt calibration in ONE
    fused ``ops.calibrate_fleet`` launch and ships the parameters down the
    WAN downlink as per-edge ``ModelUpdate`` events."""


@dataclasses.dataclass(frozen=True)
class ModelUpdate:
    """Recalibrated CQ confidence parameters arriving at ``edge`` over the
    WAN downlink.  Applied at *delivery* time: ticks that fire while the
    update is in flight still triage with the stale calibration — the same
    race a real edge device lives with."""
    edge: int
    params: Tuple[float, float]       # Platt (a, b)


class EventQueue:
    """Min-heap of timestamped events with stable FIFO tie-breaking."""

    def __init__(self) -> None:
        self._pq: List[Tuple[float, int, object]] = []
        self._seq = 0

    def push(self, t: float, event: object) -> None:
        self._seq += 1
        heapq.heappush(self._pq, (t, self._seq, event))

    def pop(self) -> Tuple[float, object]:
        t, _, event = heapq.heappop(self._pq)
        return t, event

    def __bool__(self) -> bool:
        return bool(self._pq)

    def __len__(self) -> int:
        return len(self._pq)
