"""Typed simulation events + the time-ordered event queue.

The pipeline's event loop is a plain priority queue over ``(time, seq,
event)`` triples; ``seq`` breaks time ties in push order, which the
orchestrator relies on (per-tick arrival batches are pushed before the
tick's queue-length sample, failures after both).  Events are small frozen
dataclasses so each handler dispatches on type, not on string tags.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from repro.serving.simulator import Item


@dataclasses.dataclass
class Task:
    """One item travelling through the pipeline.

    The item's ``query`` rides along on the ``Item`` itself — routing and
    service are query-agnostic (the Eq. 7 allocator prices a node by its
    total load across every live query sharing it)."""
    item: Item
    phase: str                    # 'classify' (CQ) or 'reclassify' (accurate)
    decision: Optional[bool]      # set for classify tasks at triage time
    tx_s: float = 0.0             # seconds this task spent on the wire
    #                               (informational; the aggregate lives in
    #                               Transport — never fed to the node
    #                               latency estimators, which would let one
    #                               congestion burst bias Eq. 7 forever)
    # speculative escalation (Scenario.speculative_escalation): the edge's
    # provisional CQ verdict, served the instant the WAN upload *starts*
    # and reconciled when the cloud's reclassify verdict lands — the
    # stale-in-flight ModelUpdate delivery semantics generalized to
    # verdicts.  None on non-speculative tasks; carried across failover so
    # a stranded reclassify still reconciles against what was served.
    provisional: Optional[bool] = None
    t_provisional: Optional[float] = None     # when the edge served it


@dataclasses.dataclass(frozen=True)
class Sample:
    """Per-tick queue-length sampling point."""


@dataclasses.dataclass(frozen=True)
class Arrive:
    """One item entering the system directly (cloud_only streams per item)."""
    item: Item


@dataclasses.dataclass(frozen=True)
class TickArrivals:
    """All of one scheduler tick's detections, grouped by home edge.

    The cascade schemes consume this as ONE fused fleet-triage launch.
    ``tick`` is the scheduler tick index (the superstep planner keys its
    per-tick plan slices by it; -1 for legacy callers that never plan)."""
    batches: Dict[int, List[Item]]
    tick: int = -1


@dataclasses.dataclass(frozen=True)
class Transfer:
    """A task finishing its WAN/LAN transfer and landing on ``node``."""
    node: int
    task: Task


@dataclasses.dataclass(frozen=True)
class EdgeFail:
    """Edge ``node`` dies at this instant."""
    node: int


@dataclasses.dataclass(frozen=True)
class ServiceDone:
    """``node`` finishes serving ``task`` after ``service_s`` seconds."""
    node: int
    task: Task
    service_s: float


@dataclasses.dataclass(frozen=True)
class FeedbackTick:
    """Periodic cloud-side recalibration instant (every ``update_period_s``).

    The feedback stage fits every ready (query, edge) row's Platt
    calibration in ONE fused ``ops.calibrate_fleet`` launch and ships the
    parameters down the WAN downlink as per-row ``ModelUpdate`` events."""


@dataclasses.dataclass(frozen=True)
class ModelUpdate:
    """A per-query CQ model artifact arriving at ``edge`` over the WAN
    downlink.  Two kinds share the stale-in-flight delivery semantics:

    * ``kind="calibration"`` — recalibrated Platt ``params`` for the
      (query, edge) CQ confidence (the online feedback loop).
    * ``kind="weights"`` — the freshly fine-tuned CQ model itself (§IV-B):
      the edge starts serving the query only once this delivers; the
      query's detections wait in the edge's deferral buffer until then.
    * ``kind="prewarm"`` — a track query's predictive hand-off: the track
      stage predicted the target's next-likely edge and ships that edge's
      thresholds/CQ weights *before* the target arrives, turning the WAN
      downlink speculative.  At delivery the edge is marked warm for the
      query (``tracks.TrackStage.apply_prewarm``); ``params`` is None.

    Applied at *delivery* time: ticks that fire while the update is in
    flight still triage with the stale model/calibration — the same race a
    real edge device lives with (a pre-warm that delivers after the target
    has already crossed simply arrives too late to help)."""
    edge: int
    params: Optional[Tuple[float, float]]     # Platt (a, b); None otherwise
    query: int = 0
    kind: str = "calibration"                 # or "weights" / "prewarm"


@dataclasses.dataclass(frozen=True)
class QueryArrival:
    """A new continuous query (CQ) enters the system: the cloud starts its
    Fig. 5 fine-tune (``core.finetune.scheme_train_time``) the instant this
    fires; ``TrainDone`` follows after the scheme's training time.
    ``kind`` mirrors the spec's ``QuerySpec.kind`` so event consumers can
    dispatch without a registry lookup."""
    query: int
    kind: str = "classify"


@dataclasses.dataclass(frozen=True)
class TrainDone:
    """The cloud finished fine-tuning ``query``'s CQ model: per-edge weight
    shipments start on the WAN downlink (one ``ModelUpdate(kind="weights")``
    per live edge, FIFO-serialized like every other downlink transfer)."""
    query: int


@dataclasses.dataclass(frozen=True)
class QueryRetire:
    """``query`` leaves the system: its per-(query, edge) threshold rows
    drop out of the fused triage launch (freeing edge escalation capacity),
    its feedback buffers are cleared, and detections still waiting for its
    weights are answered with the pre-trained prior.  Escalations already
    in flight complete and are counted — retirement never loses answers."""
    query: int


@dataclasses.dataclass(frozen=True)
class ReleaseTick:
    """Deferred-item release barrier at a scheduler-tick boundary.

    When a query's CQ weights deliver at an edge mid-tick, the items that
    were waiting are NOT triaged immediately (that would cost an extra
    kernel launch): they join the next tick boundary.  A natural
    ``TickArrivals`` at the same boundary absorbs them first (setup-time
    events win FIFO tie-breaks), keeping the one-launch-per-tick
    invariant; this event only launches if that tick had no arrivals of
    its own."""
    tick: int = -1


#: Host event boundaries for the scan-superstep path: the events that
#: mutate state the fused tick math reads (query liveness, node liveness,
#: calibrations, thresholds' drain signals via transport/scheduler load
#: shifts).  A superstep may only fuse ticks strictly between two
#: boundaries — an event landing mid-superstep must SPLIT it, never be
#: absorbed — and the pipeline re-samples its boundary-held control
#: signals at the first tick after each one.  Pure tick/DES flow
#: (Sample, Arrive, TickArrivals, Transfer, ServiceDone) never *creates*
#: a boundary event: every boundary is either pushed at setup or by
#: another boundary's handler, so the event queue always knows the next
#: boundary time before a superstep is planned.
BOUNDARY_EVENTS = (EdgeFail, QueryArrival, TrainDone, QueryRetire,
                   ModelUpdate, FeedbackTick, ReleaseTick)


class EventQueue:
    """Min-heap of timestamped events with stable FIFO tie-breaking.

    Boundary events (``BOUNDARY_EVENTS``) are additionally tracked in a
    side heap so the superstep planner can ask for the next boundary time
    in O(1) without scanning the queue.  Because events pop in global
    time order, the side heap's minimum always equals a popping boundary
    event's time, so pops stay O(log n)."""

    def __init__(self) -> None:
        self._pq: List[Tuple[float, int, object]] = []
        self._seq = 0
        self._boundary: List[float] = []

    def push(self, t: float, event: object) -> None:
        self._seq += 1
        heapq.heappush(self._pq, (t, self._seq, event))
        if isinstance(event, BOUNDARY_EVENTS):
            heapq.heappush(self._boundary, t)

    def pop(self) -> Tuple[float, object]:
        t, _, event = heapq.heappop(self._pq)
        if isinstance(event, BOUNDARY_EVENTS):
            heapq.heappop(self._boundary)
        return t, event

    def peek_time(self) -> Optional[float]:
        """Earliest queued event time without popping (None when empty).
        The async driver sleeps its clock to this instant before popping,
        so virtual-time runs pop in exactly the DES order."""
        return self._pq[0][0] if self._pq else None

    def next_boundary(self) -> float:
        """Earliest boundary-event time still queued (+inf if none)."""
        return self._boundary[0] if self._boundary else float("inf")

    def __bool__(self) -> bool:
        return bool(self._pq)

    def __len__(self) -> int:
        return len(self._pq)
