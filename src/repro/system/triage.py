"""Fleet cascade stage: per-(query, edge) Eqs. 8-9 state + one fused
launch per tick.

Every scheduler tick, ALL live queries' detection batches across ALL live
edges are packed into one (Q, E, N) confidence tensor (lanes right-padded
with -1.0, which always routes to 'reject'; absent (query, edge) rows are
all-pad) alongside the (Q, E, 2) tensor of each row's *current* adaptive
thresholds, and triaged by a single ``ops.triage_fleet`` launch — the
per-tick kernel-launch count is 1, not E and not Q·E.  Before packing,
each (query, edge) row's raw confidences pass through its *live* Platt
calibration (cloud->edge feedback loop, ``system/feedback.py``) —
identity until the first ``ModelUpdate`` delivers.

Thresholds are per-(query, edge) state: each pair runs its own Eqs. 8-9
update, driven by the drain of "its chosen queue" — the busier of the
edge's own queue (where classification tasks land, across every query
sharing the edge) and the node Eq. 7 would hand an escalation to
(including WAN backlog; computed once per tick, it is the same target for
every row).  A loaded edge therefore tightens every query's bracket on
that edge, while the same query on an idle edge widens its own — and two
queries with different score quality on one edge diverge through their
separate feedback calibrations.  A retired query's rows simply stop
appearing in the pack, freeing that edge capacity (its escalation buffer
rows) for the survivors.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import CLOUD, Scheduler
from repro.core.thresholds import ThresholdState
from repro.kernels import ops
from repro.serving.simulator import Item
from repro.system.feedback import IDENTITY, calibrate_row
from repro.system.scenario import Scenario
from repro.system.transport import Transport

# route codes emitted by the triage kernel
ACCEPT, REJECT, ESCALATE = 0, 1, 2

#: a (query, edge) pair — the row key of the fused (Q, E, N) launch
Key = Tuple[int, int]


class TriageStage:
    """Per-(query, edge) adaptive thresholds + the fused triage hot path."""

    def __init__(self, sc: Scenario, sched: Scheduler, transport: Transport):
        self.sc = sc
        self.sched = sched
        self.transport = transport
        # Per-(query, edge) Eqs. 8-9 state (the paper runs the adaptation
        # on every edge device per CQ model; one global (alpha, beta)
        # would let one hot edge — or one blurry query — drag every
        # bracket shut).  The fixed scheme freezes one shared pair.
        if sc.scheme == "surveiledge_fixed":
            a, b = sc.fixed_thresholds or (0.8, 0.1)
            proto = ThresholdState(alpha=a, beta=b, gamma1=0.0,
                                   gamma2=b / max(1.0 - a, 1e-6))
        else:
            proto = ThresholdState(gamma1_up=0.005)
        self._proto = proto
        self.states: Dict[Key, ThresholdState] = {
            (q, e): proto for q in sc.query_ids for e in sc.edge_ids}
        # per-(query, edge) live Platt calibration (a, b): identity until a
        # ModelUpdate *delivers* over the WAN downlink (feedback loop)
        self.calibrations: Dict[Key, Tuple[float, float]] = {
            (q, e): IDENTITY for q in sc.query_ids for e in sc.edge_ids}
        # priority tiers (control plane): a query's tier weight amplifies
        # the drain signal its Eqs. 8-9 rows see, so a high-priority
        # query's brackets tighten EARLIER under the same load — it backs
        # off from escalating (keeping its latency inside the SLO) while
        # best-effort queries keep riding the shared escalation path.
        # Empty/zero weights keep every row's update bit-identical.
        self.tier_weight: Dict[int, float] = {}
        if sc.tiers:
            w_of = {ts.tier: ts.weight for ts in sc.tiers}
            tier_of = {sp.query: sp.tier for sp in sc.queries}
            self.tier_weight = {
                q: w for q in sc.query_ids
                if (w := w_of.get(tier_of.get(q, 0), 0.0)) > 0.0}
        self.launches = 0
        self.elapsed_s = 0.0         # wall clock inside triage_tick

    # --- Eqs. 8-9, once per (query, edge) per tick ----------------------------
    def refresh(self, t: float, keys: Iterable[Key]) -> None:
        """Advance each listed (query, edge) row's (alpha, beta) by one
        Eqs. 8-9 step.

        The escalation-target drain (argmin Eq. 7 cost, incl. WAN backlog
        for the cloud) is fleet-global and computed once; each row then
        maxes it against its edge's *own* queue drain — which counts every
        query sharing that edge, so multi-query load couples the brackets
        of co-located queries exactly as shared hardware would."""
        if self.sc.scheme != "surveiledge":
            return
        try:
            d = self.sched.select_node(
                extra_cost={CLOUD: self.transport.wan_backlog(t)})
        except ValueError:
            d = CLOUD
        esc_drain = self.sched.nodes[d].drain_time
        if d == CLOUD:
            esc_drain += self.transport.wan_backlog(t)
        for key in keys:
            q, e = key
            drain = max(self.sched.nodes[e].drain_time, esc_drain)
            w = self.tier_weight.get(q)
            if w:
                drain *= 1.0 + w
            self.states[key] = self.states[key].update(
                drain, 1.0, self.sc.interval_s)

    # --- the fused launch -----------------------------------------------------
    def triage_tick(self, batches: Dict[Key, List[Item]]
                    ) -> Dict[Key, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Triage every (query, edge) tick batch in ONE kernel launch.

        ``batches`` maps (query, edge) -> that row's items this tick.
        Returns per-key ``(routes, slots, conf_used)`` arrays trimmed to
        the true batch lengths — ``conf_used`` is the (calibrated)
        confidence the kernel actually routed on, so downstream fallback
        decisions (escalation-capacity overflow) judge with the row's
        live calibration, not the stale raw score."""
        if not batches:
            return {}
        t0 = time.perf_counter()
        qs = sorted({q for q, _ in batches})
        es = sorted({e for _, e in batches})
        qi = {q: i for i, q in enumerate(qs)}
        ei = {e: i for i, e in enumerate(es)}
        n = max(len(b) for b in batches.values())
        conf = np.full((len(qs), len(es), n), -1.0, np.float32)
        # absent (query, edge) rows stay all-pad; give them inert
        # thresholds (1, 0) like the kernel's own pad rows
        thresholds = np.tile(np.asarray([1.0, 0.0], np.float32),
                             (len(qs), len(es), 1))
        for (q, e), items in batches.items():
            row = conf[qi[q], ei[e]]
            row[:len(items)] = [it.conf for it in items]
            # live recalibration from the cloud->edge feedback loop; pad
            # lanes stay -1.0 (always 'reject', never a slot).  Shared
            # with the superstep slab pack — see feedback.calibrate_row.
            calibrate_row(row, len(items), self.calibrations[(q, e)])
            st = self.states[(q, e)]
            thresholds[qi[q], ei[e]] = (st.alpha, st.beta)
        routes, slots, _ = ops.triage_fleet(
            conf, thresholds, capacity=self.sc.escalation_capacity)
        self.launches += 1
        routes, slots = np.asarray(routes), np.asarray(slots)
        out = {
            key: (routes[qi[key[0]], ei[key[1]], :len(items)],
                  slots[qi[key[0]], ei[key[1]], :len(items)],
                  conf[qi[key[0]], ei[key[1]], :len(items)])
            for key, items in batches.items()}
        self.elapsed_s += time.perf_counter() - t0
        return out

    def add_query(self, query: int, weight: float = 0.0) -> None:
        """Register a runtime-submitted query (live API): fresh threshold
        rows from the scheme prototype, identity calibration, optional
        tier weight — the same starting state a declared query gets."""
        for e in self.sc.edge_ids:
            self.states.setdefault((query, e), self._proto)
            self.calibrations.setdefault((query, e), IDENTITY)
        if weight > 0.0:
            self.tier_weight[query] = weight

    def apply_update(self, query: int, edge: int,
                     params: Tuple[float, float]) -> None:
        """A calibration ``ModelUpdate`` delivered: this (query, edge) row
        triages later ticks with the new Platt map (earlier ticks already
        ran stale)."""
        self.calibrations[(query, edge)] = params

    def retire_query(self, query: int) -> None:
        """Drop a retired query's live calibrations (its threshold states
        stay readable for the end-of-run report; its rows never enter
        ``triage_tick`` again because the pipeline stops producing them)."""
        for key in list(self.calibrations):
            if key[0] == query:
                self.calibrations[key] = IDENTITY

    def final_thresholds(self, query: Optional[int] = None
                         ) -> Dict[int, Tuple[float, float]]:
        """Per-edge (alpha, beta) at end of run for one query (default: the
        lowest-id query — for single-query runs, THE query)."""
        if query is None:
            query = min(q for q, _ in self.states)
        return {e: (s.alpha, s.beta)
                for (q, e), s in self.states.items() if q == query}

    def thresholds_by_query(self) -> Dict[int, Dict[int, Tuple[float, float]]]:
        """query -> edge -> final (alpha, beta) (per-query report rows)."""
        out: Dict[int, Dict[int, Tuple[float, float]]] = {}
        for (q, e), s in self.states.items():
            out.setdefault(q, {})[e] = (s.alpha, s.beta)
        return out
