"""Fleet cascade stage: per-edge Eqs. 8-9 state + one fused launch per tick.

Every scheduler tick, all live edges' detection batches are packed into one
(E, N) confidence matrix (rows right-padded with -1.0, which always routes
to 'reject') alongside the (E, 2) matrix of each edge's *current* adaptive
thresholds, and triaged by a single ``ops.triage_fleet`` Pallas launch —
the per-tick kernel-launch count is 1, not E.  Before packing, each edge's
raw confidences pass through its *live* Platt calibration (cloud->edge
feedback loop, ``system/feedback.py``) — identity until the first
``ModelUpdate`` delivers.

Thresholds are per-edge state: each edge runs its own Eqs. 8-9 update,
driven by the drain of "its chosen queue" — the busier of the edge's own
queue (where classification tasks land) and the node Eq. 7 would hand an
escalation to (including WAN backlog; computed once per tick, it is the
same target for every edge).  A loaded edge therefore tightens its
[beta, alpha] escalation bracket while an idle edge in the same fleet
widens its own, independently.
"""
from __future__ import annotations

import time
from typing import Dict, Iterable, List, Tuple

import numpy as np

from repro.core.scheduler import CLOUD, Scheduler
from repro.core.thresholds import ThresholdState
from repro.kernels import ops
from repro.serving.simulator import Item
from repro.system.feedback import IDENTITY, apply_calibration
from repro.system.scenario import Scenario
from repro.system.transport import Transport

# route codes emitted by the triage kernel
ACCEPT, REJECT, ESCALATE = 0, 1, 2


class TriageStage:
    """Per-edge adaptive thresholds + the fused fleet-triage hot path."""

    def __init__(self, sc: Scenario, sched: Scheduler, transport: Transport):
        self.sc = sc
        self.sched = sched
        self.transport = transport
        # Per-edge Eqs. 8-9 state (the paper runs the adaptation on every
        # edge device; a single global (alpha, beta) would let one hot edge
        # drag the whole fleet's bracket shut).  The fixed scheme freezes
        # one shared pair instead.
        if sc.scheme == "surveiledge_fixed":
            a, b = sc.fixed_thresholds or (0.8, 0.1)
            proto = ThresholdState(alpha=a, beta=b, gamma1=0.0,
                                   gamma2=b / max(1.0 - a, 1e-6))
        else:
            proto = ThresholdState(gamma1_up=0.005)
        self.states: Dict[int, ThresholdState] = {
            e: proto for e in sc.edge_ids}
        # per-edge live Platt calibration (a, b): identity until a
        # ModelUpdate *delivers* over the WAN downlink (feedback loop)
        self.calibrations: Dict[int, Tuple[float, float]] = {
            e: IDENTITY for e in sc.edge_ids}
        self.launches = 0
        self.elapsed_s = 0.0         # wall clock inside triage_tick

    # --- Eqs. 8-9, once per edge per tick ------------------------------------
    def refresh(self, t: float, edges: Iterable[int]) -> None:
        """Advance each listed edge's (alpha, beta) by one Eqs. 8-9 step.

        The escalation-target drain (argmin Eq. 7 cost, incl. WAN backlog
        for the cloud) is fleet-global and computed once; each edge then
        maxes it against its *own* queue drain, so per-edge load asymmetry
        shows up as threshold divergence."""
        if self.sc.scheme != "surveiledge":
            return
        try:
            d = self.sched.select_node(
                extra_cost={CLOUD: self.transport.wan_backlog(t)})
        except ValueError:
            d = CLOUD
        esc_drain = self.sched.nodes[d].drain_time
        if d == CLOUD:
            esc_drain += self.transport.wan_backlog(t)
        for e in edges:
            drain = max(self.sched.nodes[e].drain_time, esc_drain)
            self.states[e] = self.states[e].update(
                drain, 1.0, self.sc.interval_s)

    # --- the fused launch -----------------------------------------------------
    def triage_tick(self, batches: Dict[int, List[Item]]
                    ) -> Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Triage every edge's tick batch in ONE kernel launch.

        ``batches`` maps live edge id -> that edge's items this tick.
        Returns per-edge ``(routes, slots, conf_used)`` arrays trimmed to
        the true batch lengths — ``conf_used`` is the (calibrated)
        confidence the kernel actually routed on, so downstream fallback
        decisions (escalation-capacity overflow) judge with the edge's
        live calibration, not the stale raw score."""
        if not batches:
            return {}
        t0 = time.perf_counter()
        edges = sorted(batches)
        lengths = [len(batches[e]) for e in edges]
        conf = np.full((len(edges), max(lengths)), -1.0, np.float32)
        for i, e in enumerate(edges):
            conf[i, :lengths[i]] = [it.conf for it in batches[e]]
            a, b = self.calibrations[e]
            if (a, b) != IDENTITY:
                # live recalibration from the cloud->edge feedback loop;
                # pad lanes stay -1.0 (always 'reject', never a slot)
                conf[i, :lengths[i]] = apply_calibration(
                    conf[i, :lengths[i]], a, b)
        thresholds = np.asarray(
            [[self.states[e].alpha, self.states[e].beta] for e in edges],
            np.float32)
        routes, slots, _ = ops.triage_fleet(
            conf, thresholds, capacity=self.sc.escalation_capacity)
        self.launches += 1
        routes, slots = np.asarray(routes), np.asarray(slots)
        out = {e: (routes[i, :lengths[i]], slots[i, :lengths[i]],
                   conf[i, :lengths[i]])
               for i, e in enumerate(edges)}
        self.elapsed_s += time.perf_counter() - t0
        return out

    def apply_update(self, edge: int, params: Tuple[float, float]) -> None:
        """A ``ModelUpdate`` delivered: this edge triages later ticks with
        the new Platt calibration (earlier ticks already ran stale)."""
        self.calibrations[edge] = params

    def final_thresholds(self) -> Dict[int, Tuple[float, float]]:
        """Per-edge (alpha, beta) at end of run (reported for inspection)."""
        return {e: (s.alpha, s.beta) for e, s in self.states.items()}
