"""Scan-superstep driver: K scheduler ticks fused into ONE jitted launch.

The per-tick driver (``pipeline._on_tick`` + ``triage.triage_tick``) pays
one host->device round trip per scheduler tick: pack the tick's
(query, edge) batches, launch the fused triage kernel, pull the routes
back.  At metropolis scale (>=1024 edges, ~10k cameras, dozens of live
queries, 10 Hz ticks) the host loop — not the kernel — is the bottleneck.

This module fuses runs of consecutive ticks into one device program:

  host (numpy)                      device (ONE jit per superstep)
  ------------                      ------------------------------
  segment the event queue into      lax.scan over the tick axis:
  boundary-free runs of ticks;        Eqs. 8-9 threshold update per
  pack a (S, R, N) confidence         (query, edge) row (masked to the
  slab over the run's ACTIVE          ticks where the row had items)
  (query, edge) keys; apply live    then ONE row-folded
  Platt calibration per row           ``triage_fleet_pallas`` launch
  (feedback.calibrate_row)            over all S*R rows
  fold routes/slots/thresholds      <- (S, R, N) routes/slots,
  back into per-tick plans             (S, R, 2) per-tick thresholds

Axes: S = ticks in the run (<= scenario.superstep), R = |union of
(query, edge) keys with >=1 ready item in the run| — the fleet's
(Q, E) grid is ~99.8% empty per tick at metropolis scale, so the slab
is packed over active keys, not the dense grid.  R is the axis
``distributed.sharding.fleet_specs`` shards across devices (rows are
mutually independent; the kernel runs shard-local with no collectives).

Correctness contract (the differential harness in
``tests/test_superstep.py`` enforces all of it bit-exactly):

* **Boundaries split supersteps, never the reverse.**  A superstep may
  only cover ticks that process strictly before the next queued
  ``events.BOUNDARY_EVENTS`` time — those events mutate state the fused
  math reads (query/node liveness, calibrations, control signals).  No
  boundary event is ever created by pure tick/DES flow, so
  ``EventQueue.next_boundary()`` is always known at plan time.
* **K-invariance.**  The run's control signals (Eq. 7 escalation-target
  drain, per-edge queue drains, the overload-shed set) are sampled once
  at the first triaged tick after each boundary and held until the next
  one — by the *pipeline*, independent of K — so any segmentation of a
  boundary-free run produces bit-identical decisions, thresholds and
  latencies.  ``superstep=1`` is therefore a per-tick reference driver
  for any ``superstep=K``, which is exactly what the differential tests
  compare.
* **Threshold arithmetic is f32 end to end.**  The scan carries (alpha,
  beta) in f32; the host write-back stores the f32 values (f32 -> f64
  -> f32 round trips are exact), so splitting a run at any point does
  not change the trajectory.
"""
from __future__ import annotations

import bisect
import dataclasses
import functools
import time
from typing import Dict, FrozenSet, List, Tuple

import numpy as np

from repro.kernels.buckets import MAX_SUPERSTEP_ELEMS, bucket
from repro.serving.simulator import Item
from repro.system.feedback import calibrate_row

#: a (query, edge) pair — the row key of the packed slab
Key = Tuple[int, int]
#: per-tick triage outputs: key -> (routes, slots, conf_used), trimmed
TickOuts = Dict[Key, Tuple[np.ndarray, np.ndarray, np.ndarray]]
#: per-tick post-update thresholds: key -> (alpha, beta)
TickThs = Dict[Key, Tuple[float, float]]


@dataclasses.dataclass(frozen=True)
class Ctrl:
    """Boundary-held control signals (sampled by ``pipeline._sample_ctrl``
    at the first triaged tick after each boundary event, constant until
    the next boundary).

    ``esc_drain`` is the Eq. 7 escalation-target drain (incl. WAN backlog
    when the target is the cloud); ``edge_drain`` each edge's own queue
    drain; ``overloaded`` the edges whose drain exceeds the shed gate."""
    esc_drain: float
    edge_drain: Dict[int, float]
    overloaded: FrozenSet[int]


@functools.lru_cache(maxsize=None)
def _superstep_fn(capacity: int, n_shards: int):
    """One compiled superstep program per (capacity, shard count).

    Shapes retrace inside the returned jit (bucket padding keeps the set
    small).  ``n_shards > 1`` wraps the body in a ``shard_map`` over the
    1-D fleet mesh — the row axis R splits across devices; each shard
    runs the scan and the triage kernel on its own rows (no collectives,
    bit-exact vs. the unsharded program)."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import triage as _tr

    def body(conf, th0, mask, drain, gains):
        # gains = [gamma1, gamma1_up (== gamma1 when unset), gamma2,
        #          interval_s]; all rows share them (TriageStage builds
        # every state from one prototype).
        g1, g1u, g2, interval = gains[0], gains[1], gains[2], gains[3]
        gain = jnp.where(drain >= interval, g1, g1u)

        def step(th, m):
            # Eqs. 8-9 on every row, applied only where the row had
            # items this tick (mask) — rows hold otherwise, exactly like
            # the per-tick driver's refresh(ready-keys-only).
            alpha = jnp.clip(th[:, 0] - gain * (drain - interval),
                             0.5, 1.0)
            new = jnp.stack([alpha, g2 * (1.0 - alpha)], axis=-1)
            th = jnp.where(m[:, None], new, th)
            return th, th

        _, ths = jax.lax.scan(step, th0, mask)          # (S, R, 2)
        S, R, N = conf.shape
        routes, slots, _ = _tr.triage_fleet_pallas(
            conf.reshape(S * R, N), ths.reshape(S * R, 2),
            capacity=capacity)
        return routes.reshape(S, R, N), slots.reshape(S, R, N), ths

    if n_shards > 1:
        from jax.experimental.shard_map import shard_map

        from repro.distributed.sharding import fleet_specs
        from repro.launch.mesh import make_fleet_mesh

        sp = fleet_specs()
        body = shard_map(
            body, mesh=make_fleet_mesh(n_shards),
            in_specs=(sp["conf"], sp["thresholds"], sp["mask"],
                      sp["drain"], sp["gains"]),
            out_specs=(sp["routes"], sp["slots"], sp["ths_out"]),
            # the pallas launch has no replication rule; rows are
            # independent so shard-local execution IS the semantics
            check_rep=False)
    return jax.jit(body)


class SuperstepDriver:
    """Plans and executes scan-supersteps for one pipeline run.

    The pipeline calls ``tick_out`` from ``_on_tick`` for every tick
    with ready work.  On a plan miss the driver greedily accumulates the
    current tick plus future arrival ticks — stopping at the scenario's
    K, at the next event boundary, or at the element cap — executes the
    fused program ONCE, and caches each covered tick's outputs; the
    following ticks of the run then pop their slice with no device work.
    """

    def __init__(self, pipe):
        self.pipe = pipe
        sc = pipe.sc
        self.sc = sc
        self.enabled = (sc.superstep is not None
                        and sc.scheme in ("surveiledge",
                                          "surveiledge_fixed"))
        self.k = max(1, int(sc.superstep or 1))
        self.supersteps = 0
        self.n_shards = 1
        if self.enabled and sc.shard_fleet:
            import jax
            self.n_shards = max(1, jax.device_count())
        self._plans: Dict[int, Tuple[TickOuts, TickThs]] = {}

    # --- per-tick entry point -------------------------------------------------
    def tick_out(self, tick: int, ready: Dict[Key, List[Item]],
                 ctrl: Ctrl) -> Tuple[TickOuts, TickThs]:
        """This tick's (routes, slots, conf_used) per key + the per-key
        post-update thresholds.  ``ready`` is the tick's PRE-shed ready
        map (threshold updates and db snapshots cover keys the shed then
        drops, matching the per-tick driver's ordering)."""
        plan = self._plans.pop(tick, None)
        if plan is None:
            self._build(tick, ready, ctrl)
            plan = self._plans.pop(tick)
        return plan

    # --- planning + one fused launch ------------------------------------------
    def _build(self, k0: int, ready0: Dict[Key, List[Item]],
               ctrl: Ctrl) -> None:
        t0 = time.perf_counter()
        pipe, sc = self.pipe, self.sc
        adaptive = sc.scheme == "surveiledge"
        shed = ctrl.overloaded if adaptive else frozenset()
        next_boundary = pipe.events.next_boundary()

        # Greedy segmentation: the current tick always belongs to its
        # own superstep; future arrival ticks join while (a) the run
        # stays under K triaged ticks, (b) the tick processes STRICTLY
        # before the next boundary event (conservative: a boundary at
        # the exact tick boundary cuts the run — cutting early is always
        # bit-exact, absorbing an event never is), and (c) the padded
        # slab stays under the element cap.  Ticks whose pure
        # classification comes back empty are skipped, not counted: the
        # pipeline never asks for a plan on an empty tick.
        ticks = [k0]
        readies = [ready0]
        keys = set(ready0)
        max_n = max(len(v) for v in ready0.values())
        order = pipe._tick_order
        i = bisect.bisect_right(order, k0)
        while len(ticks) < self.k and i < len(order):
            k = order[i]
            if (k + 1) * sc.interval_s >= next_boundary - 1e-9:
                break
            i += 1
            ready = pipe._ready_of(pipe._tick_batches[k])
            if not ready:
                continue
            cand_keys = keys | set(ready)
            cand_n = max(max_n, max(len(v) for v in ready.values()))
            if (bucket(len(ticks) + 1, 1) * bucket(len(cand_keys))
                    * bucket(cand_n)) > MAX_SUPERSTEP_ELEMS:
                break
            ticks.append(k)
            readies.append(ready)
            keys, max_n = cand_keys, cand_n

        # pack the slab over the run's active keys only
        keys_sorted = sorted(keys)
        ki = {key: r for r, key in enumerate(keys_sorted)}
        S, R = len(ticks), len(keys_sorted)
        Sb, Rb, Nb = bucket(S, 1), bucket(R), bucket(max_n)
        conf = np.full((Sb, Rb, Nb), -1.0, np.float32)
        mask = np.zeros((Sb, Rb), bool)
        th0 = np.tile(np.asarray([1.0, 0.0], np.float32), (Rb, 1))
        drain = np.zeros(Rb, np.float32)
        stage = pipe.triage_stage
        for r, key in enumerate(keys_sorted):
            st = stage.states[key]
            th0[r] = (st.alpha, st.beta)
            if adaptive:
                drain[r] = max(ctrl.edge_drain[key[1]], ctrl.esc_drain)
        for s, ready in enumerate(readies):
            for key, items in ready.items():
                r = ki[key]
                if adaptive:
                    mask[s, r] = True
                if key[1] in shed:
                    continue        # row stays pad: outputs never read
                row = conf[s, r]
                row[:len(items)] = [it.conf for it in items]
                calibrate_row(row, len(items), stage.calibrations[key])
        proto = next(iter(stage.states.values()))
        g1u = proto.gamma1 if proto.gamma1_up is None else proto.gamma1_up
        gains = np.asarray([proto.gamma1, g1u, proto.gamma2,
                            sc.interval_s], np.float32)

        n_shards = self.n_shards if Rb % self.n_shards == 0 else 1
        fn = _superstep_fn(sc.escalation_capacity, n_shards)
        routes, slots, ths = (np.asarray(a)
                              for a in fn(conf, th0, mask, drain, gains))
        stage.launches += 1
        self.supersteps += 1

        # fold back into per-tick plans
        for s, (k, ready) in enumerate(zip(ticks, readies)):
            outs: TickOuts = {}
            ths_k: TickThs = {}
            for key, items in ready.items():
                r = ki[key]
                if adaptive:
                    ths_k[key] = (float(ths[s, r, 0]),
                                  float(ths[s, r, 1]))
                if key[1] not in shed:
                    n = len(items)
                    outs[key] = (routes[s, r, :n], slots[s, r, :n],
                                 conf[s, r, :n])
            self._plans[k] = (outs, ths_k)

        # write the end-of-run thresholds back so the next superstep (or
        # the end-of-run report) starts where this one ended.  ONLY the
        # adaptive scheme: the fixed scheme never refreshes, and writing
        # f32-cast copies would perturb its frozen f64 (alpha, beta).
        if adaptive:
            for r, key in enumerate(keys_sorted):
                stage.states[key] = dataclasses.replace(
                    stage.states[key],
                    alpha=float(ths[S - 1, r, 0]),
                    beta=float(ths[S - 1, r, 1]))
        stage.elapsed_s += time.perf_counter() - t0
