"""Scenario definitions for the end-to-end cloud-edge query pipeline.

A ``Scenario`` fixes everything the harness needs: topology (edge speed
multipliers + one cloud), link capacities, the camera fleet and query
duration, the scheme, and optional stress events (traffic bursts, edge
failures).  Paper settings (Tables II-IV) and beyond-paper settings are
plain factory functions registered in ``SCENARIOS``.

Scenarios can either carry a pre-scored item stream (``items`` — e.g. the
benchmark workload scored by the fine-tuned CQ model from
``repro.serving.workload``) or let the harness synthesize one cheaply with
``synthetic_confidence_stream`` (confidence drawn from class-conditional
Beta distributions — no model in the loop, for tests/examples).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data import synthetic_video as SV
from repro.kernels.buckets import validate_fleet_dims, validate_frame_hw
from repro.serving.api import TenantSpec, TierSpec
from repro.serving.simulator import Item
from repro.system.queries import QuerySpec

SCHEMES = ("surveiledge", "surveiledge_fixed", "edge_only", "cloud_only")

# Fig. 5's accuracy side of the training-scheme trade, expressed as the
# class-conditional Beta sharpness of each query's synthetic CQ
# confidences: All-Fine-tune scores sharpest (it paid ~num_cameras-x the
# training time), No-Fine-tune ships instantly but its pre-trained-only
# scores blur toward the middle of the axis.
_SCHEME_BETAS: Dict[str, Tuple[Tuple[float, float], Tuple[float, float]]] = {
    "surveiledge": ((8.0, 2.0), (2.0, 8.0)),
    "all_finetune": ((9.0, 1.5), (1.5, 9.0)),
    "no_finetune": ((4.0, 2.5), (2.5, 4.0)),
}


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    scheme: str = "surveiledge"
    # --- fleet ---------------------------------------------------------------
    num_cameras: int = 8
    duration_s: float = 120.0
    interval_s: float = 1.0                 # scheduler tick == sampling period
    # --- topology ------------------------------------------------------------
    edge_speeds: Tuple[float, ...] = (1.0,)  # service-time multiplier per edge
    edge_service_s: float = 0.08             # 1.0x edge per-item CQ inference
    cloud_speedup: float = 6.0               # cloud GPU vs 1.0x edge CPU
    reclassify_factor: float = 2.0           # accurate model vs CQ model cost
    offload_drain_s: float = 2.0             # Eq. 7 sheds raw batches above
    #                                          this home-edge drain time
    # --- links ---------------------------------------------------------------
    uplink_MBps: float = 0.5                 # shared WAN FIFO, edge -> cloud
    downlink_MBps: float = 5.0               # shared WAN FIFO, cloud -> edge
    lan_MBps: float = 10.0                   # edge <-> edge, non-contending
    rtt_s: float = 0.1
    # --- cascade -------------------------------------------------------------
    escalation_capacity: int = 64            # per edge per tick (kernel buffer)
    fixed_thresholds: Optional[Tuple[float, float]] = None
    # --- feedback loop (cloud -> edge online recalibration) ------------------
    update_period_s: Optional[float] = None  # None disables the loop (the
    #                                          ablation); else one fused
    #                                          calibrate launch per period
    update_nbytes: int = 64 * 1024           # per-edge downlink payload (the
    #                                          recalibrated CQ head)
    feedback_window: int = 256               # per-edge (score, truth) buffer
    feedback_min_count: int = 8              # labels needed before fitting
    feedback_max_age_periods: float = 2.0    # labels older than this many
    #                                          update periods age out of the
    #                                          fit (recency bounds staleness
    #                                          under drift)
    # --- stress events -------------------------------------------------------
    burst_boost: Optional[float] = None      # override CameraSpec.busy_boost
    burst_rate: Optional[float] = None       # override CameraSpec.base_rate
    failures: Tuple[Tuple[float, int], ...] = ()   # (t_s, edge node id)
    # concept drift: at drift_at_s the class-conditional Beta parameters of
    # the synthetic confidence stream switch from (8,2)/(2,8) to drift_beta
    # ((query_a, query_b), (other_a, other_b)).  The default is gain-style
    # drift — query scores compress into the middle of the axis while
    # clutter compresses low, so the classes STAY separable but the frozen
    # thresholds (and the raw conf > 0.5 fallback cut) sit in the wrong
    # place; a monotone recalibration can recover it, which is exactly what
    # the feedback loop fits
    drift_at_s: Optional[float] = None
    drift_beta: Tuple[Tuple[float, float], Tuple[float, float]] = \
        ((5.0, 5.0), (1.2, 12.0))
    # --- runtime query lifecycle ---------------------------------------------
    # explicit continuous queries with staggered arrivals/retirements; empty
    # means ONE implicit query live for the whole run (the pre-lifecycle
    # engine, bit-identical).  Each arrival charges its Fig. 5 fine-tune on
    # the cloud and ships per-edge CQ weights down the WAN before serving.
    queries: Tuple[QuerySpec, ...] = ()
    train_step_s: float = 0.05               # cloud seconds per fine-tune step
    #                                          (Fig. 5 cost model's knob)
    cq_nbytes: int = 4 * 1024 * 1024         # per-edge CQ weight shipment
    # --- control plane (serving layer: admission, priority, alerting) ---------
    # Priority tiers: each QuerySpec.tier indexes this tuple; a tier's SLO
    # and pressure weight thread into the Eq. 7 allocator and the Eqs. 8-9
    # bracket updates (repro.serving.api.TierSpec).  Empty keeps the
    # tierless engine bit-identical.
    tiers: Tuple[TierSpec, ...] = ()
    # Per-tenant submission quotas (token bucket; repro.serving.api).
    tenants: Tuple[TenantSpec, ...] = ()
    # Enables admission control at QueryArrival: fine-tunes SERIALIZE on
    # the cloud (one training run at a time — the realistic regime where a
    # backlog can exist at all) and submissions shed on quota exhaustion
    # or when the training backlog exceeds the tier's allowance (tier 0
    # exempt; tier k's allowance is this * 0.5**(k-1)).  None keeps the
    # legacy concurrent-training path bit-identical.
    admission_backlog_s: Optional[float] = None
    # health alerting lines (None disables each alert kind): a sampled
    # edge queue depth above alert_queue_depth, or an Eqs. 8-9 bracket
    # drifted more than alert_threshold_drift (L1 on (alpha, beta)) from
    # its starting point, publishes on alerts/edge<e>/...
    alert_queue_depth: Optional[int] = None
    alert_threshold_drift: Optional[float] = None
    # --- bandwidth endgame ----------------------------------------------------
    # ship every WAN-downlink model artifact (per-query CQ weights, Platt
    # calibration heads) int8-quantized (distributed/quantize.py wire
    # format): the link is charged the real quantized byte count — scale/
    # zero-point overhead included — and shipped calibration values
    # round-trip encode->decode, so the edge applies the (slightly lossy)
    # parameters it actually received.  False keeps the full-width fp path
    # as the differential reference; QueryReport.downlink_fp_bytes records
    # the fp-equivalent cost either way, so one row shows the reduction.
    quantize_downlink: bool = False
    # serve escalations speculatively: while an escalated crop's WAN upload
    # is in flight, the edge emits its provisional CQ verdict (calibrated
    # conf > 0.5) immediately and reconciles when the cloud's reclassify
    # verdict lands — the stale-in-flight ModelUpdate delivery semantics
    # generalized to verdicts.  Escalated items' reported latency becomes
    # the provisional serve time; accuracy still counts the reconciled
    # (cloud) verdict, and the flip rate is reported and gated.
    speculative_escalation: bool = False
    # --- cross-camera track queries (QuerySpec.kind == "track") ---------------
    # Knobs are inert unless a track query is declared; classify-only
    # scenarios are bit-identical to the pre-track engine.
    embedding_dim: int = 32                  # re-ID embedding width D
    track_objects: int = 4                   # persistent trajectory targets
    #                                          (query class) per track query
    track_distractors: int = 2               # persistent non-query movers
    track_speed_px_s: Tuple[float, float] = (24.0, 48.0)  # |vx| draw range
    # (warm, cold) cosine acceptance floors: an edge that is warm for the
    # query (a live track was just there, or a pre-warm delivered) accepts
    # cross-camera matches down to `warm`; a cold edge demands `cold` —
    # which only a same-camera continuation clears.  The gap is exactly
    # what the predictive hand-off buys.
    track_thresholds: Tuple[float, float] = (0.85, 0.97)
    track_ttl_s: float = 3.0                 # unseen tracks retire after this
    predictive_handoff: bool = True          # ship pre-warms ahead of targets
    prewarm_nbytes: int = 4096               # downlink payload per pre-warm
    prewarm_ttl_s: float = 12.0              # delivered pre-warm stays warm
    # --- stream --------------------------------------------------------------
    seed: int = 0
    items: Optional[Sequence[Item]] = None   # injected pre-scored stream
    frame_hw: Optional[Tuple[int, int]] = None   # pixel path: camera frame
    #                                              size override (H, W)
    # --- superstep execution (metropolis scale) -------------------------------
    # None runs the legacy per-tick live-signal loop (bit-identical to every
    # pre-superstep release).  K >= 1 switches the cascade schemes to
    # boundary-sampled control semantics: the Eqs. 8-9 drain signals and the
    # overload-shedding gate are sampled once per host event boundary (query
    # lifecycle, failures, model deliveries, feedback ticks) and held
    # constant between boundaries, which makes results invariant to K — up
    # to K consecutive ticks then fuse into ONE jitted lax.scan superstep
    # (system/superstep.py).  K=1 is the same semantics driven tick by tick:
    # the differential harness proves K=1 == K=N bit-exactly.
    superstep: Optional[int] = None
    # shard the superstep's folded row axis across jax devices (no-op on a
    # single device; exercised on CPU via
    # XLA_FLAGS=--xla_force_host_platform_device_count=8)
    shard_fleet: bool = False
    # accumulate the report in streaming windowed aggregates of this width
    # instead of O(items) per-item arrays (system/metrics.py); None keeps
    # the exact per-item arrays
    metrics_window_s: Optional[float] = None

    def __post_init__(self):
        # plain ValueError, never assert: `python -O` strips asserts, and a
        # scenario with a bogus scheme or thresholds must fail loudly either
        # way.  dataclasses.replace() re-runs this, so with_scheme and the
        # ablation replaces are covered too.
        if self.scheme not in SCHEMES:
            raise ValueError(
                f"scenario {self.name!r}: unknown scheme {self.scheme!r} "
                f"(expected one of {SCHEMES})")
        if self.fixed_thresholds is not None:
            a, b = self.fixed_thresholds
            if not 0.5 <= a <= 1.0:
                raise ValueError(
                    f"scenario {self.name!r}: fixed alpha={a} must satisfy "
                    f"0.5 <= alpha <= 1 (Eq. 8 clamp)")
            if not 0.0 <= b < 0.5:
                raise ValueError(
                    f"scenario {self.name!r}: fixed beta={b} must satisfy "
                    f"0 <= beta < 0.5 (Eq. 9 range)")
        if self.update_period_s is not None and self.update_period_s <= 0:
            raise ValueError(
                f"scenario {self.name!r}: update_period_s="
                f"{self.update_period_s} must be positive (or None)")
        if self.queries:
            ids = [sp.query for sp in self.queries]
            if len(set(ids)) != len(ids):
                raise ValueError(
                    f"scenario {self.name!r}: duplicate query ids in "
                    f"queries={ids}")
        if self.train_step_s < 0:
            raise ValueError(
                f"scenario {self.name!r}: train_step_s={self.train_step_s} "
                f"must be >= 0")
        if self.interval_s <= 0:
            raise ValueError(
                f"scenario {self.name!r}: interval_s={self.interval_s} "
                f"must be positive")
        if self.duration_s <= 0:
            raise ValueError(
                f"scenario {self.name!r}: duration_s={self.duration_s} "
                f"must be positive")
        if self.num_cameras < 1:
            raise ValueError(
                f"scenario {self.name!r}: num_cameras={self.num_cameras} "
                f"must be >= 1")
        # fleet dims checked against the kernel padding-bucket table here,
        # where the numbers are still legible — not at first launch, where
        # an oversized fold surfaces as an opaque Pallas shape error
        validate_fleet_dims(self.name, len(self.query_ids), self.num_edges,
                            self.escalation_capacity)
        # frame sizes checked against the pixel-cascade tile table for the
        # same reason: a bad frame_hw must raise here, not as a Pallas
        # block-shape error at the first rendered tick
        if self.frame_hw is not None:
            validate_frame_hw(self.name, *self.frame_hw)
        if self.superstep is not None and self.superstep < 1:
            raise ValueError(
                f"scenario {self.name!r}: superstep={self.superstep} must "
                f"be >= 1 (or None for the legacy per-tick loop)")
        # --- control plane ----------------------------------------------------
        if self.tiers:
            declared = sorted(ts.tier for ts in self.tiers)
            if declared != list(range(len(self.tiers))):
                raise ValueError(
                    f"scenario {self.name!r}: tiers must declare contiguous "
                    f"tier ids 0..{len(self.tiers) - 1}, got {declared}")
        max_tier = len(self.tiers) - 1 if self.tiers else 0
        tenant_names = {tn.tenant for tn in self.tenants}
        if len(tenant_names) != len(self.tenants):
            raise ValueError(
                f"scenario {self.name!r}: duplicate tenant names in "
                f"tenants={[tn.tenant for tn in self.tenants]}")
        for sp in self.queries:
            if sp.tier > max_tier:
                raise ValueError(
                    f"scenario {self.name!r}: query {sp.query} declares "
                    f"tier={sp.tier} but only tiers 0..{max_tier} exist "
                    f"(declare Scenario.tiers)")
            if sp.tenant and self.tenants and sp.tenant not in tenant_names:
                raise ValueError(
                    f"scenario {self.name!r}: query {sp.query} declares "
                    f"tenant={sp.tenant!r}, not one of "
                    f"{sorted(tenant_names)}")
        if self.admission_backlog_s is not None \
                and self.admission_backlog_s <= 0:
            raise ValueError(
                f"scenario {self.name!r}: admission_backlog_s="
                f"{self.admission_backlog_s} must be positive (or None)")
        if self.alert_queue_depth is not None and self.alert_queue_depth < 1:
            raise ValueError(
                f"scenario {self.name!r}: alert_queue_depth="
                f"{self.alert_queue_depth} must be >= 1 (or None)")
        if self.alert_threshold_drift is not None \
                and self.alert_threshold_drift <= 0:
            raise ValueError(
                f"scenario {self.name!r}: alert_threshold_drift="
                f"{self.alert_threshold_drift} must be positive (or None)")
        # the admission path (serialized fine-tunes, shed queries) and
        # nonzero tier weights both feed per-tick live signals the fused
        # scan cannot reproduce — the control plane requires the per-tick
        # driver, exactly like the feedback loop requires deliveries to
        # land at tick boundaries
        if self.superstep is not None:
            if self.admission_backlog_s is not None:
                raise ValueError(
                    f"scenario {self.name!r}: admission control "
                    f"(admission_backlog_s) requires superstep=None — "
                    f"shed/serialization decisions are per-arrival live "
                    f"signals the scan path does not model")
            if any(ts.weight > 0 for ts in self.tiers):
                raise ValueError(
                    f"scenario {self.name!r}: tier weights > 0 require "
                    f"superstep=None — SLO pressure is a per-item live "
                    f"signal the scan path does not model")
        if self.metrics_window_s is not None and self.metrics_window_s <= 0:
            raise ValueError(
                f"scenario {self.name!r}: metrics_window_s="
                f"{self.metrics_window_s} must be positive (or None for "
                f"per-item arrays)")
        # --- cross-camera track queries ---------------------------------------
        if self.track_query_ids:
            if self.superstep is not None:
                raise ValueError(
                    f"scenario {self.name!r}: track queries require "
                    f"superstep=None — track birth/hand-off decisions are "
                    f"per-tick live signals the scan path does not model")
            if self.embedding_dim < self.num_cameras:
                raise ValueError(
                    f"scenario {self.name!r}: embedding_dim="
                    f"{self.embedding_dim} must be >= num_cameras="
                    f"{self.num_cameras} (per-camera appearance tints are "
                    f"orthonormal in the embedding space)")
            warm, cold = self.track_thresholds
            if not 0.0 < warm <= cold <= 1.0:
                raise ValueError(
                    f"scenario {self.name!r}: track_thresholds="
                    f"{self.track_thresholds} must satisfy "
                    f"0 < warm <= cold <= 1")
            if self.track_ttl_s <= 0 or self.prewarm_ttl_s <= 0:
                raise ValueError(
                    f"scenario {self.name!r}: track_ttl_s and prewarm_ttl_s "
                    f"must be positive")
            if self.track_objects < 1:
                raise ValueError(
                    f"scenario {self.name!r}: track_objects="
                    f"{self.track_objects} must be >= 1")
            if self.track_distractors < 0:
                raise ValueError(
                    f"scenario {self.name!r}: track_distractors="
                    f"{self.track_distractors} must be >= 0")

    @property
    def num_edges(self) -> int:
        return len(self.edge_speeds)

    @property
    def edge_ids(self) -> Tuple[int, ...]:
        return tuple(range(1, self.num_edges + 1))

    @property
    def query_ids(self) -> Tuple[int, ...]:
        """Every declared query id (sorted); ``(0,)`` for the implicit
        single-query run."""
        return tuple(sorted(sp.query for sp in self.queries)) or (0,)

    @property
    def track_query_ids(self) -> Tuple[int, ...]:
        """Declared cross-camera track queries (sorted; empty when the
        scenario is classify-only)."""
        return tuple(sorted(sp.query for sp in self.queries
                            if sp.kind == "track"))

    def with_scheme(self, scheme: str) -> "Scenario":
        """Same scenario under another query scheme (validated in
        ``__post_init__`` — raises ``ValueError``, survives ``python -O``)."""
        return dataclasses.replace(self, scheme=scheme)


def scenario_cameras(sc: Scenario) -> List[SV.CameraSpec]:
    """The scenario's camera fleet with its overrides applied.

    Shared by the confidence-stream synthesizer and the pixel frontend so
    both paths see the *same* cameras: burst overrides reshape the traffic
    profile, ``frame_hw`` shrinks/grows the rendered frames (pixel path
    only — the confidence path never renders)."""
    cams = SV.make_cameras(sc.num_cameras, seed=sc.seed)
    if (sc.burst_boost is None and sc.burst_rate is None
            and sc.frame_hw is None):
        return cams
    h, w = sc.frame_hw if sc.frame_hw is not None else (None, None)
    return [dataclasses.replace(
        c,
        busy_boost=sc.burst_boost if sc.burst_boost is not None
        else c.busy_boost,
        base_rate=sc.burst_rate if sc.burst_rate is not None
        else c.base_rate,
        height=h if h is not None else c.height,
        width=w if w is not None else c.width) for c in cams]


def frame_schedule(sc: Scenario) -> np.ndarray:
    """Per-camera frame-capture schedule for the pixel path.

    Returns a (T, C) matrix of capture instants: camera ``j`` samples one
    frame triple per scheduler tick ``k`` at ``k*interval_s + stagger_j``,
    where the per-camera stagger is a deterministic draw in [0, interval_s)
    — a fleet's captures spread across the tick instead of all landing on
    the same instant, as real cameras' sampling clocks do."""
    ts = np.arange(0.0, sc.duration_s, sc.interval_s)
    rng = np.random.default_rng(sc.seed + 13)
    stagger = rng.uniform(0.0, sc.interval_s, sc.num_cameras)
    return ts[:, None] + stagger[None, :]


def _query_substream(sc: Scenario, cams: List[SV.CameraSpec],
                     rng: np.random.Generator, query: int,
                     betas: Tuple[Tuple[float, float], Tuple[float, float]],
                     t0: float, t1: float) -> List[Item]:
    """One query's detections: Poisson arrivals from the camera fleet,
    confidence from the query's class-conditional Betas, windowed to the
    query's [t0, t1) lifetime.

    All random draws are vectorized (one Poisson matrix over ticks x
    cameras, then per-camera class/confidence/jitter vectors) and the
    lifetime window is a post-draw mask, so a windowed query's draws stay
    deterministic under seed regardless of its lifetime."""
    (qa0, qb0), (oa0, ob0) = betas
    ts = np.arange(0.0, sc.duration_s, sc.interval_s)              # (T,)
    period = np.asarray([c.busy_period_s for c in cams])           # (C,)
    phase = 2 * np.pi * ts[:, None] / period[None, :] \
        + np.asarray([c.busy_phase for c in cams])[None, :]
    rates = np.asarray([c.base_rate for c in cams]) * (
        1.0 + np.asarray([c.busy_boost for c in cams])
        * np.maximum(0.0, np.sin(phase)) ** 2)                     # (T, C)
    counts = rng.poisson(rates * sc.interval_s)                    # (T, C)
    items: List[Item] = []
    for j, cam in enumerate(cams):
        n = int(counts[:, j].sum())
        if n == 0:
            continue
        cls = rng.choice(SV.NUM_CLASSES, size=n, p=cam.class_mix)
        is_query = cls == SV.QUERY_CLASS
        conf = np.where(is_query, rng.beta(qa0, qb0, n),
                        rng.beta(oa0, ob0, n))
        t_arr = np.repeat(ts, counts[:, j]) \
            + rng.uniform(0, sc.interval_s, n)
        if sc.drift_at_s is not None:
            # concept drift: items after drift_at_s draw from the drifted
            # class-conditional Betas (drawn AFTER the stationary draws so
            # drift-free scenarios keep bit-identical streams per seed)
            (qa, qb), (oa, ob) = sc.drift_beta
            drifted = np.where(is_query, rng.beta(qa, qb, n),
                               rng.beta(oa, ob, n))
            conf = np.where(t_arr >= sc.drift_at_s, drifted, conf)
        keep = (t_arr >= t0) & (t_arr < t1)
        edge = cam.cam_id % sc.num_edges + 1
        items.extend(
            Item(t_arrival=float(t), camera=cam.cam_id, edge_device=edge,
                 conf=float(c), is_query=bool(q), query=query)
            for t, c, q in zip(t_arr[keep], conf[keep], is_query[keep]))
    return items


def _track_substream(sc: Scenario, cams: List[SV.CameraSpec],
                     rng: np.random.Generator, query: int,
                     betas: Tuple[Tuple[float, float], Tuple[float, float]],
                     t0: float, t1: float) -> List[Item]:
    """One track query's detections: trajectory-aware ground truth.

    Unlike ``_query_substream``'s memoryless Poisson clutter, a track
    query's world is a set of PERSISTENT objects with stable identities:
    ``sc.track_objects`` query-class targets plus ``sc.track_distractors``
    non-query movers, each travelling at constant signed speed along a 1-D
    chain of ``num_cameras`` camera fields (camera width
    ``SV.CAMERA_FIELD_W`` px, wrapping at the ends).  Every scheduler tick
    each object is observed once by whichever camera its world position
    falls in, yielding an ``Item`` that carries

    * ``gt_track`` — the object's stable id (the ID-switch metric's truth),
    * ``emb`` — a unit re-ID embedding built from three orthogonal parts:
      ``c*base[obj] + a*tint[camera] + b*noise``, where the per-camera
      tints are orthonormal (QR) and each object's base is projected off
      the tint subspace.  Same-camera re-observations then score
      ``~c^2 + a^2`` cosine (clears the cold floor), cross-camera ones
      ``~c^2`` (clears only the warm floor — the hand-off's whole value),
      and distinct objects ``~0``,
    * ``conf`` / ``is_query`` — the usual class-conditional Beta draw, so
      the same items ride the classify cascade untouched.

    All draws sit on the fixed (tick, object) grid before the lifetime
    window masks them — windowing never shifts the rng stream.
    """
    (qa, qb), (oa, ob) = betas
    C = sc.num_cameras
    W = SV.CAMERA_FIELD_W
    D = sc.embedding_dim
    total = sc.track_objects + sc.track_distractors
    ts = np.arange(0.0, sc.duration_s, sc.interval_s)              # (T,)
    T = len(ts)
    # per-object trajectory state
    x0 = rng.uniform(0.0, C * W, total)
    speed = rng.uniform(*sc.track_speed_px_s, total)
    sign = np.where(rng.uniform(size=total) < 0.5, -1.0, 1.0)
    vx = speed * sign
    # per-camera appearance tints: orthonormal rows (needs D >= C, checked
    # in __post_init__), so cross-camera interference is exactly zero
    tint = np.linalg.qr(rng.normal(size=(D, C)))[0].T[:C]          # (C, D)
    base = rng.normal(size=(total, D))
    base -= (base @ tint.T) @ tint        # project off the tint subspace
    base /= np.linalg.norm(base, axis=1, keepdims=True)
    a_tint, b_noise = 0.30, 0.05
    c_base = float(np.sqrt(1.0 - a_tint**2 - b_noise**2))
    # fixed-grid draws: (T, total)
    x = (x0[None, :] + vx[None, :] * ts[:, None]) % (C * W)
    cam = (x // W).astype(np.int64)                                # (T, total)
    jitter = rng.uniform(0.0, sc.interval_s, (T, total))
    is_q = np.arange(total) < sc.track_objects
    conf = np.where(is_q[None, :], rng.beta(qa, qb, (T, total)),
                    rng.beta(oa, ob, (T, total)))
    noise = rng.normal(size=(T, total, D))
    noise /= np.linalg.norm(noise, axis=-1, keepdims=True)
    emb = c_base * base[None] + a_tint * tint[cam] + b_noise * noise
    emb /= np.linalg.norm(emb, axis=-1, keepdims=True)
    t_arr = ts[:, None] + jitter
    keep = (t_arr >= t0) & (t_arr < t1)
    items: List[Item] = []
    for k, o in zip(*np.nonzero(keep)):
        cj = int(cam[k, o])
        items.append(Item(
            t_arrival=float(t_arr[k, o]), camera=cj,
            edge_device=cj % sc.num_edges + 1,
            conf=float(conf[k, o]), is_query=bool(is_q[o]), query=query,
            emb=emb[k, o].astype(np.float32), gt_track=int(o)))
    return items


def synthetic_confidence_stream(sc: Scenario) -> List[Item]:
    """Model-free item stream: Poisson arrivals from the procedural camera
    fleet, edge confidence drawn from class-conditional Beta distributions
    (query objects ~ Beta(8,2), others ~ Beta(2,8)) — overlapping enough
    that the [beta, alpha] escalation band carries real mass.

    With explicit ``sc.queries``, every query contributes its own
    substream (independent per-query rng, lifetime-windowed, confidence
    sharpness set by its Fig. 5 ``train_scheme`` via ``_SCHEME_BETAS``):
    each live CQ watches the same cameras but detects its own objects, so
    total traffic scales with concurrent live queries."""
    cams = scenario_cameras(sc)
    if not sc.queries:
        items = _query_substream(
            sc, cams, np.random.default_rng(sc.seed), 0,
            _SCHEME_BETAS["surveiledge"], 0.0, float("inf"))
    else:
        items = []
        for sp in sorted(sc.queries, key=lambda s: s.query):
            t1 = sp.t_retire_s if sp.t_retire_s is not None else float("inf")
            gen = _track_substream if sp.kind == "track" \
                else _query_substream
            items.extend(gen(
                sc, cams, np.random.default_rng((sc.seed, 1001 + sp.query)),
                sp.query, _SCHEME_BETAS[sp.train_scheme],
                sp.t_arrive_s, t1))
    items.sort(key=lambda it: it.t_arrival)
    return items


# --- paper settings (Tables II-IV) -------------------------------------------

def single_edge(**kw) -> Scenario:
    """Table II: one edge + cloud."""
    return Scenario(name="single_edge", edge_speeds=(1.0,), **kw)


def homogeneous_multi_edge(**kw) -> Scenario:
    """Table III: three identical edges + cloud."""
    return Scenario(name="homogeneous_multi_edge",
                    edge_speeds=(1.0, 1.0, 1.0), **kw)


def heterogeneous_multi_edge(**kw) -> Scenario:
    """Table IV: 2/4/8-core edge analogues (1.0 / 0.5 / 0.25 x service)."""
    return Scenario(name="heterogeneous_multi_edge",
                    edge_speeds=(1.0, 0.5, 0.25), **kw)


# --- beyond-paper settings ----------------------------------------------------

def bursty_crowds(**kw) -> Scenario:
    """Flash-crowd traffic: every camera's busy peaks are ~3x the paper
    profile, driving the adaptive thresholds through their full range."""
    return Scenario(name="bursty_crowds", edge_speeds=(1.0, 1.0, 1.0),
                    burst_boost=9.0, burst_rate=1.5, **kw)


def straggler_edge(**kw) -> Scenario:
    """One 4x-slow straggler edge, and it *fails outright* two-thirds into
    the run — Eq. 7 must route around it, then the harness re-dispatches its
    queued work and re-homes its cameras' frames to the surviving nodes."""
    duration = kw.pop("duration_s", 120.0)
    return Scenario(name="straggler_edge", edge_speeds=(4.0, 1.0, 0.5),
                    duration_s=duration,
                    failures=((duration * 2 / 3, 1),), **kw)


def city_scale(num_cameras: int = 512, num_edges: int = 64,
               num_failures: int = 6, **kw) -> Scenario:
    """Fleet-scale operating point: >= 64 heterogeneous edges serving
    >= 512 cameras, with *rolling* failures — a handful of distinct edges
    dying one after another across the run, so Eq. 7 keeps re-routing and
    camera fleets keep re-homing while the system stays under load.

    The floors are pinned (a smaller request is bumped up): this scenario
    exists to exercise the fused fleet-triage launch and the per-edge
    threshold state at scale, not to shrink down.  Links and the cloud are
    sized city-like — a fat shared uplink and a cloud cluster an order of
    magnitude faster than the paper's single GPU."""
    num_cameras = max(num_cameras, 512)
    num_edges = max(num_edges, 64)
    duration = kw.pop("duration_s", 60.0)
    seed = kw.pop("seed", 0)
    rng = np.random.default_rng(seed + 77)
    # heterogeneous service speeds: mostly 1x/0.5x, some fast 0.25x racks
    # and a tail of 2x-slow strugglers (service-time multipliers)
    speeds = tuple(float(s) for s in rng.choice(
        (0.25, 0.5, 1.0, 2.0), size=num_edges, p=(0.15, 0.3, 0.4, 0.15)))
    fail_edges = rng.choice(np.arange(1, num_edges + 1),
                            size=num_failures, replace=False)
    failures = tuple(
        (duration * (i + 1) / (num_failures + 1), int(e))
        for i, e in enumerate(fail_edges))
    return Scenario(name="city_scale", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    seed=seed, failures=failures,
                    uplink_MBps=8.0, lan_MBps=50.0, cloud_speedup=40.0,
                    **kw)


def metropolis(num_cameras: int = 10240, num_edges: int = 1024,
               num_queries: int = 24, num_failures: int = 3,
               **kw) -> Scenario:
    """Metropolis operating point: >= 1024 edges, ~10k cameras, dozens of
    concurrent CQs, 10 Hz sampling — the scale where per-tick Python
    dispatch dominates wall clock long before the kernels do, and the
    reason the scan-superstep path exists.

    The floors are pinned like ``city_scale``'s: >= 1024 edges, and at
    least one camera per edge.  Runs with ``superstep=128`` (boundary-free
    tick runs fuse into ONE jitted ``lax.scan`` superstep each),
    ``shard_fleet=True`` (the folded row axis splits across whatever jax
    devices exist — a no-op on one device), and streaming windowed report
    aggregates (``metrics_window_s``) so report memory is O(windows), not
    O(items).

    The workload shape is chosen so boundary events cluster in the opening
    act: every query registers within the first 2% of the run (city
    operators set up their query book up front), the per-edge CQ weight
    pushes drain over a fat downlink shortly after (each delivery is a
    host boundary — 24 queries x 1024 edges of them — so they must
    finish early or they fragment every superstep), and the rolling edge
    failures land inside that same window — after which the fleet serves
    dozens of concurrent queries across long boundary-free stretches,
    which is precisely where one superstep replaces up to K host-loop
    iterations.  The online recalibration loop stays off by default: each
    calibration shipment's delivery is a host boundary, and at this scale
    the study of interest is fleet orchestration, not the feedback loop
    (``drifting_city`` remains its measuring stick; pass
    ``update_period_s=...`` to combine them).
    """
    num_edges = max(num_edges, 1024)
    num_cameras = max(num_cameras, num_edges)
    num_queries = max(num_queries, 12)
    duration = kw.pop("duration_s", 60.0)
    interval = kw.pop("interval_s", 0.1)
    seed = kw.pop("seed", 0)
    rng = np.random.default_rng(seed + 177)
    speeds = tuple(float(s) for s in rng.choice(
        (0.25, 0.5, 1.0, 2.0), size=num_edges, p=(0.15, 0.3, 0.4, 0.15)))
    fail_edges = rng.choice(np.arange(1, num_edges + 1),
                            size=num_failures, replace=False)
    failures = tuple(
        (duration * (0.04 + 0.015 * i), int(e))
        for i, e in enumerate(fail_edges))
    queries = kw.pop("queries", tuple(
        QuerySpec(q,
                  t_arrive_s=duration * 0.02 * q / num_queries,
                  t_retire_s=duration * 0.95 if q >= num_queries - 2
                  else None,
                  train_scheme="no_finetune" if q % 3 == 2
                  else "surveiledge")
        for q in range(num_queries)))
    return Scenario(name="metropolis", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    interval_s=interval, seed=seed, failures=failures,
                    queries=queries,
                    burst_rate=kw.pop("burst_rate", 0.02),
                    escalation_capacity=kw.pop("escalation_capacity", 8),
                    edge_service_s=kw.pop("edge_service_s", 0.05),
                    uplink_MBps=kw.pop("uplink_MBps", 16.0),
                    downlink_MBps=kw.pop("downlink_MBps", 2000.0),
                    lan_MBps=kw.pop("lan_MBps", 100.0),
                    cloud_speedup=kw.pop("cloud_speedup", 80.0),
                    cq_nbytes=kw.pop("cq_nbytes", 32 * 1024),
                    train_step_s=kw.pop("train_step_s", duration / 4000.0),
                    superstep=kw.pop("superstep", 128),
                    shard_fleet=kw.pop("shard_fleet", True),
                    metrics_window_s=kw.pop("metrics_window_s",
                                            duration / 12.0),
                    **kw)


def drifting_city(num_cameras: int = 12, num_edges: int = 4,
                  **kw) -> Scenario:
    """Concept drift mid-run: the edge CQ model's confidence distribution
    decays a third of the way in (query scores slump toward the reject
    band, clutter compresses low), so a frozen calibration starts silently
    dropping true query objects below beta.

    This is the feedback loop's measuring stick: by default the loop is ON
    (``update_period_s`` set — every period the cloud fits all edges'
    Platt recalibration in ONE fused ``ops.calibrate_fleet`` launch and
    ships it down the WAN downlink); replace ``update_period_s=None`` for
    the open-loop ablation, and compare ``accuracy_F2`` /
    ``accuracy_timeline`` between the two (``examples/run_scenarios.py``
    emits both rows automatically)."""
    duration = kw.pop("duration_s", 90.0)
    drift_at = kw.pop("drift_at_s", duration / 3.0)
    update = kw.pop("update_period_s", 6.0)
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    # operating point: compute is ample (fast service, shedding only in
    # extremis) but the per-edge ESCALATION budget is tight, so the edge's
    # own verdicts — the thing calibration improves — carry real weight
    return Scenario(name="drifting_city", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    burst_rate=kw.pop("burst_rate", 4.0),
                    escalation_capacity=kw.pop("escalation_capacity", 3),
                    edge_service_s=kw.pop("edge_service_s", 0.04),
                    offload_drain_s=kw.pop("offload_drain_s", 8.0),
                    quantize_downlink=kw.pop("quantize_downlink", True),
                    speculative_escalation=kw.pop(
                        "speculative_escalation", True),
                    drift_at_s=drift_at, update_period_s=update, **kw)


def multi_query_city(num_cameras: int = 12, num_edges: int = 4,
                     **kw) -> Scenario:
    """Three concurrent CQs with staggered arrivals and overlapping
    lifetimes — the paper's headline workload (queries against a live
    fleet), one per Fig. 5 training scheme so the training-time/accuracy
    trade shows up in ONE run's per-query report rows:

      q0 (surveiledge)  — arrives at t=0, short cluster fine-tune, serves
                          almost the whole run
      q1 (all_finetune) — arrives a fifth in, pays the ~num_cameras-x
                          per-camera fine-tune (its early detections wait
                          in the deferral buffers — visible head-of-query
                          latency), retires before the run ends
      q2 (no_finetune)  — arrives mid-run, ships instantly, but its
                          pre-trained-only confidences are blurrier

    All three queries' detections across all edges still triage in ONE
    fused (Q, E, N) Pallas launch per scheduler tick, and Eq. 7 prices
    every node by its total load across the queries sharing it.
    ``train_step_s`` scales with duration so shrunken smoke runs keep the
    same training-time-to-lifetime proportions."""
    duration = kw.pop("duration_s", 90.0)
    queries = kw.pop("queries", (
        QuerySpec(0, 0.0, None, "surveiledge"),
        QuerySpec(1, duration * 0.2, duration * 0.85, "all_finetune"),
        QuerySpec(2, duration * 0.45, None, "no_finetune")))
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    return Scenario(name="multi_query_city", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    queries=queries,
                    quantize_downlink=kw.pop("quantize_downlink", True),
                    speculative_escalation=kw.pop(
                        "speculative_escalation", True),
                    train_step_s=kw.pop("train_step_s", duration / 1800.0),
                    update_period_s=kw.pop("update_period_s", 10.0), **kw)


def query_churn(num_cameras: int = 10, num_edges: int = 3, **kw) -> Scenario:
    """Query churn under concept drift: five CQs arriving and retiring
    across the run, including an arrival during another query's Fig. 5
    fine-tune (the cloud trains both back to back while their detections
    defer), a retire-mid-drift (q0 leaves just after the confidence
    distributions slip, while its last escalations are still in flight),
    and a late post-drift arrival whose fresh fine-tune is born into the
    drifted regime.

    The online recalibration loop is OFF here by default: at this
    operating point escalation is cheap, so the cloud's labels are
    censored to the [beta, alpha] band and a per-(query, edge) Platt fit
    extrapolates that biased sample to the whole axis — measurably worse
    than serving stale (the loop's measuring stick, with honest
    label-generating shedding, is ``drifting_city``).  Pass
    ``update_period_s=...`` to study exactly that failure mode."""
    duration = kw.pop("duration_s", 90.0)
    drift_at = kw.pop("drift_at_s", duration / 3.0)
    queries = kw.pop("queries", (
        QuerySpec(0, 0.0, duration * 0.4, "surveiledge"),
        QuerySpec(1, duration * 0.1, duration * 0.7, "surveiledge"),
        QuerySpec(2, duration * 0.15, None, "no_finetune"),
        QuerySpec(3, duration * 0.12, duration * 0.55, "all_finetune"),
        QuerySpec(4, duration * 0.6, None, "surveiledge")))
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    # churn multiplies traffic (every live query scores every camera's
    # detections), so compute and the shedding gate are sized for the
    # multi-query peak — the point is lifecycle churn, not overload
    return Scenario(name="query_churn", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    queries=queries, drift_at_s=drift_at,
                    edge_service_s=kw.pop("edge_service_s", 0.04),
                    offload_drain_s=kw.pop("offload_drain_s", 6.0),
                    train_step_s=kw.pop("train_step_s", duration / 1800.0),
                    update_period_s=kw.pop("update_period_s", None), **kw)


def rush_hour(num_cameras: int = 8, num_edges: int = 3, **kw) -> Scenario:
    """The serving control plane's acceptance workload: query submissions
    outpace the cloud's fine-tune throughput.

    With admission enabled (``admission_backlog_s``), fine-tunes SERIALIZE
    on the cloud, so a morning flood of submissions builds a training
    backlog.  The query book is three tenants across three priority tiers:

      tier 0 (``metro-pd``) — two queries onboarded in the opening act,
        before the backlog exists; backlog-exempt, highest Eq. 7 SLO
        weight.  The acceptance gate demands ZERO SLO breaches here.
      tier 1 (``retail``)   — four queries submitted as the rush begins;
        they tolerate the full backlog allowance, so the earliest ones
        train (late, with visible head-of-query latency) and the last one
        sheds once the backlog passes the tier-1 line.
      tier 2 (``hobby``)    — six best-effort queries flooding in on a
        starvation-rate token bucket: the first burns the only token and
        sheds on backlog (its allowance is HALF tier 1's), the rest shed
        on quota — overload sheds bottom-up, never by arrival order.

    One edge dies mid-rush (failover alerts on top of the admission
    alerts).  Everything is duration-relative so the smoke-sized run
    keeps the same shed/priority story as the full-length one."""
    duration = kw.pop("duration_s", 60.0)
    d = duration
    queries = kw.pop("queries", (
        QuerySpec(0, 0.0, None, "surveiledge", tenant="metro-pd", tier=0),
        QuerySpec(1, d * 0.04, None, "surveiledge",
                  tenant="metro-pd", tier=0),
        QuerySpec(2, d * 0.20, None, "surveiledge", tenant="retail", tier=1),
        QuerySpec(3, d * 0.24, None, "surveiledge", tenant="retail", tier=1),
        QuerySpec(4, d * 0.28, None, "surveiledge", tenant="retail", tier=1),
        QuerySpec(5, d * 0.32, None, "surveiledge", tenant="retail", tier=1),
        QuerySpec(6, d * 0.22, None, "surveiledge", tenant="hobby", tier=2),
        QuerySpec(7, d * 0.26, None, "surveiledge", tenant="hobby", tier=2),
        QuerySpec(8, d * 0.30, None, "surveiledge", tenant="hobby", tier=2),
        QuerySpec(9, d * 0.34, None, "surveiledge", tenant="hobby", tier=2),
        QuerySpec(10, d * 0.38, None, "surveiledge", tenant="hobby", tier=2),
        QuerySpec(11, d * 0.42, None, "surveiledge", tenant="hobby",
                  tier=2)))
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    return Scenario(
        name="rush_hour", edge_speeds=speeds,
        num_cameras=num_cameras, duration_s=duration, queries=queries,
        tiers=kw.pop("tiers", (
            TierSpec(0, "platinum", slo_s=d * 0.25, weight=3.0),
            TierSpec(1, "standard", slo_s=d * 0.15, weight=0.5),
            TierSpec(2, "besteffort", slo_s=d * 0.15, weight=0.0))),
        tenants=kw.pop("tenants", (
            TenantSpec("metro-pd", rate=1.0, burst=2),
            TenantSpec("retail", rate=0.5, burst=2),
            TenantSpec("hobby", rate=1.0 / duration, burst=1))),
        # each surveiledge fine-tune costs 0.1*duration of cloud time, so
        # the tier-1/2 submission wave (one every 0.02-0.04*duration)
        # outruns training ~3x — the backlog the admission gate sheds on
        admission_backlog_s=kw.pop("admission_backlog_s", d * 0.15),
        train_step_s=kw.pop("train_step_s", duration / 400.0),
        cq_nbytes=kw.pop("cq_nbytes", 512 * 1024),
        # per-camera rate scaled so FLEET traffic per live query is fixed
        # (~2.4 det/s): the rush must stress ADMISSION, not saturate the
        # three edges outright — a saturated fleet breaches every tier and
        # proves nothing about priority
        burst_rate=kw.pop("burst_rate", 2.4 / num_cameras),
        alert_queue_depth=kw.pop("alert_queue_depth", 8),
        alert_threshold_drift=kw.pop("alert_threshold_drift", 0.15),
        failures=kw.pop("failures", ((d * 0.6, 1),)),
        **kw)


def vehicle_pursuit(num_cameras: int = 12, num_edges: int = 6,
                    **kw) -> Scenario:
    """Cross-camera pursuit: a handful of fast vehicles sweep a 12-camera
    chain spread over 6 edges — consecutive cameras live on DIFFERENT
    edges (camera j homes on edge j % 6 + 1), so every camera crossing is
    an edge crossing and the predictive hand-off carries the whole
    track-continuity story.

    The track query's targets move at 24-48 px/s through 128 px camera
    fields (~3-5 s dwell per camera, many crossings per run).  A crossing
    lands the target on an edge that has never seen it: cold, the
    similarity floor is ``track_thresholds[1]`` and only a same-camera
    continuation clears it — the track fragments (an ID switch).  With
    ``predictive_handoff`` the registry ships a pre-warm down the WAN the
    moment the previous crossing reveals the direction, the next edge
    accepts at the warm floor, and the track survives.  The committed
    report pairs the default row with a ``surveiledge_no_handoff``
    ablation so the gap is a gated number, not a story."""
    duration = kw.pop("duration_s", 60.0)
    queries = kw.pop("queries", (
        QuerySpec(0, 0.0, None, "surveiledge", kind="track"),))
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    return Scenario(name="vehicle_pursuit", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    queries=queries,
                    interval_s=kw.pop("interval_s", 0.5),
                    track_objects=kw.pop("track_objects", 3),
                    track_distractors=kw.pop("track_distractors", 1),
                    track_speed_px_s=kw.pop("track_speed_px_s",
                                            (24.0, 48.0)),
                    train_step_s=kw.pop("train_step_s", duration / 1800.0),
                    **kw)


def crowd_flow(num_cameras: int = 8, num_edges: int = 4, **kw) -> Scenario:
    """Dense pedestrian flow: many slow walkers (6-14 px/s — ~10-20 s
    dwell per camera) under one track query, with a classify query riding
    the same stream — the kinded API's mixed-workload scenario.  Crossings
    are rarer than ``vehicle_pursuit``'s but the track table is much
    bigger, so this preset stresses association breadth (every crop
    against every live track, still ONE fused launch per tick) where
    pursuit stresses hand-off timing."""
    duration = kw.pop("duration_s", 45.0)
    queries = kw.pop("queries", (
        QuerySpec(0, 0.0, None, "surveiledge", kind="track"),
        QuerySpec(1, 0.0, None, "no_finetune")))
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    return Scenario(name="crowd_flow", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration,
                    queries=queries,
                    interval_s=kw.pop("interval_s", 0.5),
                    track_objects=kw.pop("track_objects", 10),
                    track_distractors=kw.pop("track_distractors", 4),
                    track_speed_px_s=kw.pop("track_speed_px_s",
                                            (6.0, 14.0)),
                    track_ttl_s=kw.pop("track_ttl_s", 5.0),
                    train_step_s=kw.pop("train_step_s", duration / 1800.0),
                    **kw)


def pixel_city(num_cameras: int = 12, num_edges: int = 4, **kw) -> Scenario:
    """Pixel-path operating point: the frames->query loop at a size the
    CPU-only interpret-mode kernels finish inside the CI smoke budget.

    Run it with ``run_query(pixel_city(), frontend=PixelFrontend())``: every
    camera renders one frame triple per tick (staggered within the tick via
    ``frame_schedule``), the Pallas framediff/morphology cascade extracts
    motion crops, and the CQ classifier scores each tick's fleet-wide crop
    batch in one bucket-padded launch.  A mixed 1.0x/0.5x edge rack keeps
    Eq. 7 non-trivial without city_scale's fleet size."""
    duration = kw.pop("duration_s", 12.0)
    speeds = tuple(1.0 if i % 2 == 0 else 0.5 for i in range(num_edges))
    return Scenario(name="pixel_city", edge_speeds=speeds,
                    num_cameras=num_cameras, duration_s=duration, **kw)


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "single_edge": single_edge,
    "homogeneous_multi_edge": homogeneous_multi_edge,
    "heterogeneous_multi_edge": heterogeneous_multi_edge,
    "bursty_crowds": bursty_crowds,
    "straggler_edge": straggler_edge,
    "city_scale": city_scale,
    "metropolis": metropolis,
    "drifting_city": drifting_city,
    "multi_query_city": multi_query_city,
    "query_churn": query_churn,
    "pixel_city": pixel_city,
    "rush_hour": rush_hour,
    "vehicle_pursuit": vehicle_pursuit,
    "crowd_flow": crowd_flow,
}
