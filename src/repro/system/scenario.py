"""Scenario definitions for the end-to-end cloud-edge query pipeline.

A ``Scenario`` fixes everything the harness needs: topology (edge speed
multipliers + one cloud), link capacities, the camera fleet and query
duration, the scheme, and optional stress events (traffic bursts, edge
failures).  Paper settings (Tables II-IV) and beyond-paper settings are
plain factory functions registered in ``SCENARIOS``.

Scenarios can either carry a pre-scored item stream (``items`` — e.g. the
benchmark workload scored by the fine-tuned CQ model from
``repro.serving.workload``) or let the harness synthesize one cheaply with
``synthetic_confidence_stream`` (confidence drawn from class-conditional
Beta distributions — no model in the loop, for tests/examples).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.data import synthetic_video as SV
from repro.serving.simulator import Item

SCHEMES = ("surveiledge", "surveiledge_fixed", "edge_only", "cloud_only")


@dataclasses.dataclass(frozen=True)
class Scenario:
    name: str
    scheme: str = "surveiledge"
    # --- fleet ---------------------------------------------------------------
    num_cameras: int = 8
    duration_s: float = 120.0
    interval_s: float = 1.0                 # scheduler tick == sampling period
    # --- topology ------------------------------------------------------------
    edge_speeds: Tuple[float, ...] = (1.0,)  # service-time multiplier per edge
    edge_service_s: float = 0.08             # 1.0x edge per-item CQ inference
    cloud_speedup: float = 6.0               # cloud GPU vs 1.0x edge CPU
    reclassify_factor: float = 2.0           # accurate model vs CQ model cost
    offload_drain_s: float = 2.0             # Eq. 7 sheds raw batches above
    #                                          this home-edge drain time
    # --- links ---------------------------------------------------------------
    uplink_MBps: float = 0.5                 # shared WAN FIFO, edge -> cloud
    lan_MBps: float = 10.0                   # edge <-> edge, non-contending
    rtt_s: float = 0.1
    # --- cascade -------------------------------------------------------------
    escalation_capacity: int = 64            # per edge per tick (kernel buffer)
    fixed_thresholds: Optional[Tuple[float, float]] = None
    # --- stress events -------------------------------------------------------
    burst_boost: Optional[float] = None      # override CameraSpec.busy_boost
    burst_rate: Optional[float] = None       # override CameraSpec.base_rate
    failures: Tuple[Tuple[float, int], ...] = ()   # (t_s, edge node id)
    # --- stream --------------------------------------------------------------
    seed: int = 0
    items: Optional[Sequence[Item]] = None   # injected pre-scored stream

    @property
    def num_edges(self) -> int:
        return len(self.edge_speeds)

    @property
    def edge_ids(self) -> Tuple[int, ...]:
        return tuple(range(1, self.num_edges + 1))

    def with_scheme(self, scheme: str) -> "Scenario":
        assert scheme in SCHEMES, scheme
        return dataclasses.replace(self, scheme=scheme)


def synthetic_confidence_stream(sc: Scenario) -> List[Item]:
    """Model-free item stream: Poisson arrivals from the procedural camera
    fleet, edge confidence drawn from class-conditional Beta distributions
    (query objects ~ Beta(8,2), others ~ Beta(2,8)) — overlapping enough
    that the [beta, alpha] escalation band carries real mass."""
    rng = np.random.default_rng(sc.seed)
    cams = SV.make_cameras(sc.num_cameras, seed=sc.seed)
    if sc.burst_boost is not None or sc.burst_rate is not None:
        cams = [dataclasses.replace(
            c,
            busy_boost=sc.burst_boost if sc.burst_boost is not None
            else c.busy_boost,
            base_rate=sc.burst_rate if sc.burst_rate is not None
            else c.base_rate) for c in cams]
    items: List[Item] = []
    for t in np.arange(0.0, sc.duration_s, sc.interval_s):
        for cam in cams:
            n = rng.poisson(cam.rate_at(float(t)) * sc.interval_s)
            for _ in range(int(n)):
                cls = int(rng.choice(SV.NUM_CLASSES, p=cam.class_mix))
                is_query = cls == SV.QUERY_CLASS
                conf = float(rng.beta(8, 2) if is_query else rng.beta(2, 8))
                items.append(Item(
                    t_arrival=float(t + rng.uniform(0, sc.interval_s)),
                    camera=cam.cam_id,
                    edge_device=cam.cam_id % sc.num_edges + 1,
                    conf=conf, is_query=is_query))
    items.sort(key=lambda it: it.t_arrival)
    return items


# --- paper settings (Tables II-IV) -------------------------------------------

def single_edge(**kw) -> Scenario:
    """Table II: one edge + cloud."""
    return Scenario(name="single_edge", edge_speeds=(1.0,), **kw)


def homogeneous_multi_edge(**kw) -> Scenario:
    """Table III: three identical edges + cloud."""
    return Scenario(name="homogeneous_multi_edge",
                    edge_speeds=(1.0, 1.0, 1.0), **kw)


def heterogeneous_multi_edge(**kw) -> Scenario:
    """Table IV: 2/4/8-core edge analogues (1.0 / 0.5 / 0.25 x service)."""
    return Scenario(name="heterogeneous_multi_edge",
                    edge_speeds=(1.0, 0.5, 0.25), **kw)


# --- beyond-paper settings ----------------------------------------------------

def bursty_crowds(**kw) -> Scenario:
    """Flash-crowd traffic: every camera's busy peaks are ~3x the paper
    profile, driving the adaptive thresholds through their full range."""
    return Scenario(name="bursty_crowds", edge_speeds=(1.0, 1.0, 1.0),
                    burst_boost=9.0, burst_rate=1.5, **kw)


def straggler_edge(**kw) -> Scenario:
    """One 4x-slow straggler edge, and it *fails outright* two-thirds into
    the run — Eq. 7 must route around it, then the harness re-dispatches its
    queued work and re-homes its cameras' frames to the surviving nodes."""
    duration = kw.pop("duration_s", 120.0)
    return Scenario(name="straggler_edge", edge_speeds=(4.0, 1.0, 0.5),
                    duration_s=duration,
                    failures=((duration * 2 / 3, 1),), **kw)


SCENARIOS: Dict[str, Callable[..., Scenario]] = {
    "single_edge": single_edge,
    "homogeneous_multi_edge": homogeneous_multi_edge,
    "heterogeneous_multi_edge": heterogeneous_multi_edge,
    "bursty_crowds": bursty_crowds,
    "straggler_edge": straggler_edge,
}
