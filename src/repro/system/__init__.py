"""End-to-end multi-camera cloud-edge query system (the paper, composed).

``run_query(scenario)`` wires a ``Frontend`` detection stream -> ONE fused
fleet-triage Pallas launch per tick (per-edge adaptive thresholds) -> Eq. 7
allocator -> per-node queues -> metrics.  Scenario presets cover the
paper's three settings (Tables II-IV) plus beyond-paper stress (bursty
crowds, straggler/failing edge, the 64-edge/512-camera ``city_scale``
fleet, the concept-drift ``drifting_city``, and the frames-in
``pixel_city`` operating point).  The engine is layered: ``events`` /
``transport`` / ``nodes`` / ``triage`` / ``feedback`` (the cloud->edge
online recalibration loop) / ``frontend`` (confidence-stream or the
pixel/CNN path in ``pixel_frontend``) behind a slim ``pipeline``
orchestrator.

The event loop itself is a pluggable driver: ``SimDriver`` (the DES
default) or ``repro.serving.engine.AsyncDriver`` (asyncio, virtual or
wall clock — the real-time serving mode, with ``repro.serving.api``'s
query-submission/admission control plane and the ``rush_hour`` preset
exercising it).
"""
from repro.system.feedback import FeedbackStage, apply_calibration
from repro.system.frontend import ConfidenceStreamFrontend, Frontend
from repro.system.metrics import QueryReport, StreamingWindows
from repro.system.pipeline import QueryPipeline, SimDriver, run_query
from repro.system.pixel_frontend import PixelFrontend
from repro.system.queries import DEFAULT_QUERY, QuerySet, QuerySpec
from repro.system.scenario import (
    SCENARIOS,
    SCHEMES,
    Scenario,
    bursty_crowds,
    city_scale,
    crowd_flow,
    drifting_city,
    frame_schedule,
    heterogeneous_multi_edge,
    homogeneous_multi_edge,
    metropolis,
    multi_query_city,
    pixel_city,
    query_churn,
    rush_hour,
    scenario_cameras,
    single_edge,
    straggler_edge,
    synthetic_confidence_stream,
    vehicle_pursuit,
)
from repro.system.superstep import Ctrl, SuperstepDriver
from repro.system.tracks import TrackStage

__all__ = [
    "ConfidenceStreamFrontend",
    "Ctrl",
    "DEFAULT_QUERY",
    "FeedbackStage",
    "Frontend",
    "PixelFrontend",
    "QueryPipeline",
    "QueryReport",
    "QuerySet",
    "QuerySpec",
    "SCENARIOS",
    "SCHEMES",
    "Scenario",
    "SimDriver",
    "StreamingWindows",
    "SuperstepDriver",
    "apply_calibration",
    "TrackStage",
    "bursty_crowds",
    "city_scale",
    "crowd_flow",
    "drifting_city",
    "frame_schedule",
    "heterogeneous_multi_edge",
    "homogeneous_multi_edge",
    "metropolis",
    "multi_query_city",
    "pixel_city",
    "query_churn",
    "run_query",
    "rush_hour",
    "scenario_cameras",
    "single_edge",
    "straggler_edge",
    "synthetic_confidence_stream",
    "vehicle_pursuit",
]
