"""End-to-end multi-camera cloud-edge query system (the paper, composed).

``run_query(scenario)`` wires camera streams -> per-edge batched Pallas
triage -> Eq. 7 allocator -> per-node queues -> metrics.  Scenario presets
cover the paper's three settings (Tables II-IV) plus beyond-paper stress
(bursty crowds, straggler/failing edge).
"""
from repro.system.metrics import QueryReport
from repro.system.pipeline import QueryPipeline, run_query
from repro.system.scenario import (
    SCENARIOS,
    SCHEMES,
    Scenario,
    bursty_crowds,
    heterogeneous_multi_edge,
    homogeneous_multi_edge,
    single_edge,
    straggler_edge,
    synthetic_confidence_stream,
)

__all__ = [
    "QueryPipeline",
    "QueryReport",
    "SCENARIOS",
    "SCHEMES",
    "Scenario",
    "bursty_crowds",
    "heterogeneous_multi_edge",
    "homogeneous_multi_edge",
    "run_query",
    "single_edge",
    "straggler_edge",
    "synthetic_confidence_stream",
]
