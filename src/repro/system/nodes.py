"""Per-node compute layer: FIFO queues, service state, failure bookkeeping.

``NodeBank`` owns what each computing node (cloud + every edge) is doing at
any instant — its FIFO queue (a ``collections.deque``: the pipeline pops
from the head on every service start, which must not be O(queue length)),
the in-flight task, cumulative busy seconds and served counts, and the set
of dead nodes.  It is purely mechanical: *where* work goes (Eq. 7) and
*when* events fire stay in the orchestrator.
"""
from __future__ import annotations

import collections
from typing import Deque, Dict, List, Optional, Tuple

import numpy as np

from repro.core.scheduler import CLOUD
from repro.system.events import Task
from repro.system.scenario import Scenario


class NodeBank:
    """Queue/service/failure state for every computing node."""

    def __init__(self, sc: Scenario, service_s: Dict[int, float],
                 rng: np.random.Generator):
        self.sc = sc
        self.service_s = dict(service_s)
        self.rng = rng
        self.queues: Dict[int, Deque[Task]] = {
            n: collections.deque() for n in service_s}
        self.busy: Dict[int, bool] = {n: False for n in service_s}
        self.inflight: Dict[int, Optional[Tuple[Task, float, float]]] = {
            n: None for n in service_s}
        self.busy_s: Dict[int, float] = {n: 0.0 for n in service_s}
        self.served: Dict[int, int] = {n: 0 for n in service_s}
        self.dead: set = set()

    # --- stochastic service ---------------------------------------------------
    def service_time(self, node: int, phase: str) -> float:
        base = self.service_s[node]
        if phase == "reclassify" and node != CLOUD:
            base *= self.sc.reclassify_factor
        return float(base * self.rng.lognormal(0.0, 0.15))

    # --- queue mechanics ------------------------------------------------------
    def push(self, node: int, task: Task) -> None:
        self.queues[node].append(task)

    def begin(self, t: float, node: int) -> Tuple[Task, float]:
        """Pop the head of ``node``'s queue and start serving it at ``t``."""
        task = self.queues[node].popleft()
        self.busy[node] = True
        svc = self.service_time(node, task.phase)
        self.inflight[node] = (task, svc, t)
        self.busy_s[node] += svc
        return task, svc

    def complete(self, node: int) -> None:
        self.busy[node] = False
        self.inflight[node] = None

    def occupancy(self, node: int) -> int:
        """Queued + in-service items (the per-tick timeline sample)."""
        return len(self.queues[node]) + int(self.busy[node])

    # --- failure --------------------------------------------------------------
    def fail(self, t: float, node: int) -> List[Task]:
        """Kill ``node`` at ``t``; returns its stranded tasks (the aborted
        in-flight task first, then the queue in FIFO order).

        An aborted mid-service task did real work from its start until the
        failure; only the unserved remainder is deducted from busy time."""
        self.dead.add(node)
        stranded = list(self.queues[node])
        self.queues[node].clear()
        if self.inflight[node] is not None:
            task, svc, started = self.inflight[node]
            stranded.insert(0, task)
            self.inflight[node] = None
            self.busy_s[node] -= max(0.0, svc - (t - started))
        self.busy[node] = False
        return stranded
