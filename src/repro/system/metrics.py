"""Per-query metrics for the end-to-end pipeline (Tables II-IV columns).

``QueryReport`` is the harness's single result object: per-item latencies and
decisions against ground truth, bandwidth split into WAN (edge->cloud upload)
and LAN (edge->edge re-dispatch), per-tick queue-length timelines, the count
of fused fleet-triage kernel launches (exactly ONE per tick-with-arrivals on
the cascade schemes, regardless of fleet size — asserted by the smoke tests),
and each edge's final adaptive (alpha, beta).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.scoring import f_score as _f_score


@dataclasses.dataclass
class QueryReport:
    scenario: str
    scheme: str
    latencies: np.ndarray                  # (n_items,) seconds, finish order
    decisions: np.ndarray                  # (n_items,) bool: "is query object"
    truths: np.ndarray                     # (n_items,) bool ground truth
    finish_times: np.ndarray               # (n_items,) absolute seconds
    uploaded_bytes: int                    # shipped over the WAN uplink
    lan_bytes: int                         # shipped edge-to-edge
    escalated: int                         # items sent for re-classification
    rerouted: int                          # raw batches shed / failed-over
    kernel_launches: int                   # batched triage_pallas calls
    ticks: int                             # scheduler intervals simulated
    queue_timeline: Dict[int, np.ndarray]  # node -> (ticks,) queue length
    per_node_busy: Dict[int, float]        # node -> total service seconds
    per_node_served: Dict[int, int]        # node -> items serviced
    # edge -> final (alpha, beta): per-edge Eqs. 8-9 state at end of run
    # (empty for the non-cascade schemes)
    thresholds: Dict[int, Tuple[float, float]] = \
        dataclasses.field(default_factory=dict)
    # stage -> wall-clock seconds: frontend stages (the pixel path reports
    # render_s / framediff_s / classify_s) plus the engine's triage_s —
    # where a frames-to-answers run actually spent its compute
    stage_timings: Dict[str, float] = dataclasses.field(default_factory=dict)
    # --- runtime query lifecycle ----------------------------------------------
    # per-item query id aligned with latencies/decisions/truths (all zeros
    # for implicit single-query runs)
    query_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    # query -> lifecycle facts from the pipeline (train_scheme, train_s,
    # t_arrive_s, t_retire_s, deferred, live_edges, thresholds); empty for
    # implicit single-query runs
    queries: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    cloud_train_s: float = 0.0             # total Fig. 5 fine-tune seconds
    #                                        charged on the cloud node
    # --- feedback loop (cloud -> edge online recalibration) -------------------
    downloaded_bytes: int = 0              # model updates over the downlink
    model_updates: int = 0                 # fused calibrate launches (one
    #                                        ops.calibrate_fleet per event)
    # simulated seconds-on-the-wire per link family (transfer time belongs
    # to transport, never to the node latency estimators)
    wan_transfer_s: float = 0.0
    lan_transfer_s: float = 0.0

    # --- accuracy -------------------------------------------------------------
    def f_score(self, lam: float = 2.0) -> float:
        """F_lambda (paper uses F2: recall-weighted)."""
        return _f_score(self.decisions, self.truths, lam)

    # --- latency --------------------------------------------------------------
    @property
    def avg_latency(self) -> float:
        return float(np.mean(self.latencies)) if len(self.latencies) else 0.0

    @property
    def p99_latency(self) -> float:
        return float(np.percentile(self.latencies, 99)) \
            if len(self.latencies) else 0.0

    @property
    def latency_var(self) -> float:
        return float(np.var(self.latencies)) if len(self.latencies) else 0.0

    def accuracy_timeline(self, window_s: float = 10.0,
                          lam: float = 2.0) -> List[Dict[str, float]]:
        """Windowed F_lambda over finish time: ``[{t_start, n, f2}, ...]``.

        This is how concept-drift recovery becomes visible: on
        ``drifting_city`` the open-loop ablation's windows slump after
        ``drift_at_s`` and stay down, while the closed loop's climb back
        once the first post-drift ``ModelUpdate`` delivers.  Windows with
        zero finished items are omitted (a NaN row would poison JSON
        artifact consumers)."""
        if not len(self.finish_times):
            return []
        out = []
        n_win = int(np.floor(float(self.finish_times.max()) / window_s)) + 1
        idx = np.minimum((self.finish_times // window_s).astype(int),
                         n_win - 1)
        for k in range(n_win):
            m = idx == k
            if not m.any():
                continue
            out.append({"t_start": round(k * window_s, 3),
                        "n": int(m.sum()),
                        "f2": round(_f_score(self.decisions[m],
                                             self.truths[m], lam), 4)})
        return out

    def per_query_summary(self, lam: float = 2.0) -> Dict[int, Dict]:
        """One row per query: accuracy/latency over ITS items, merged with
        the lifecycle facts the pipeline recorded (Fig. 5 train_scheme and
        train_s, arrival/retire instants, items deferred while its weights
        were training/in flight).

        This is where the Fig. 5 trade becomes legible at run time: an
        ``all_finetune`` query shows the largest ``train_s`` and the worst
        head-of-query latency (its early detections waited out the
        fine-tune), a ``no_finetune`` query shows ``train_s == 0`` but the
        lowest ``f2``."""
        qids = self.query_ids if len(self.query_ids) else \
            np.zeros(len(self.latencies), np.int64)
        out: Dict[int, Dict] = {}
        known = set(self.queries) | set(np.unique(qids[:len(self.latencies)])
                                        if len(self.latencies) else [])
        for q in sorted(int(q) for q in known):
            m = qids == q
            n = int(m.sum())
            row = {
                "n_items": n,
                "f2": round(_f_score(self.decisions[m], self.truths[m],
                                     lam), 4) if n else 0.0,
                "avg_latency_s": round(float(np.mean(self.latencies[m])), 3)
                if n else 0.0,
                "p99_latency_s": round(
                    float(np.percentile(self.latencies[m], 99)), 3)
                if n else 0.0,
            }
            row.update(self.queries.get(q, {}))
            out[q] = row
        return out

    def summary(self) -> Dict[str, float]:
        """Flat row with the Tables II-IV column schema (+ harness extras)."""
        return {
            "scheme": self.scheme,
            "accuracy_F2": round(self.f_score(2.0), 4),
            "avg_latency_s": round(self.avg_latency, 3),
            "p99_latency_s": round(self.p99_latency, 3),
            "latency_var": round(self.latency_var, 3),
            "bandwidth_MB": round(self.uploaded_bytes / 1e6, 2),
            "lan_MB": round(self.lan_bytes / 1e6, 2),
            "downloaded_MB": round(self.downloaded_bytes / 1e6, 3),
            # raw bytes too: the loader's updates-without-downlink gate
            # must not be fooled by MB rounding on tiny payloads
            "downloaded_bytes": self.downloaded_bytes,
            "model_updates": self.model_updates,
            "escalated": self.escalated,
            "rerouted": self.rerouted,
            "kernel_launches": self.kernel_launches,
            "ticks": self.ticks,
            "launches_per_tick": round(
                self.kernel_launches / max(self.ticks, 1), 3),
            # multi-query runtime: the launch columns above NOT scaling
            # with n_queries is the fused-(Q, E, N)-launch proof
            "n_queries": max(1, len(self.queries)
                             or (len(np.unique(self.query_ids))
                                 if len(self.query_ids) else 1)),
            "cloud_train_s": round(self.cloud_train_s, 3),
        }


def merge_timelines(samples: List[Dict[int, int]]) -> Dict[int, np.ndarray]:
    """Per-tick {node: queue_len} samples -> {node: (ticks,) array}."""
    if not samples:
        return {}
    nodes = sorted(samples[0])
    return {n: np.asarray([s[n] for s in samples], dtype=np.int64)
            for n in nodes}
