"""Per-query metrics for the end-to-end pipeline (Tables II-IV columns).

``QueryReport`` is the harness's single result object: per-item latencies and
decisions against ground truth, bandwidth split into WAN (edge->cloud upload)
and LAN (edge->edge re-dispatch), per-tick queue-length timelines, the count
of fused fleet-triage kernel launches (exactly ONE per tick-with-arrivals on
the cascade schemes, regardless of fleet size — asserted by the smoke tests),
and each edge's final adaptive (alpha, beta).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.scoring import f_score as _f_score
from repro.core.scoring import f_score_counts as _f_counts

# log-spaced latency histogram for streaming percentiles: 20 buckets per
# decade over [1e-4 s, 1e4 s] (+ underflow/overflow).  The p99 read-out
# returns a bucket's upper edge clamped to the observed maximum, so its
# relative error is bounded by one bucket width (10^(1/20)-1 ~ 12%).
_LAT_LO, _LAT_HI, _LAT_BPD = 1e-4, 1e4, 20
_LAT_BUCKETS = int(round(math.log10(_LAT_HI / _LAT_LO) * _LAT_BPD))


def _lat_bucket(lat: float) -> int:
    if lat <= _LAT_LO:
        return 0
    if lat >= _LAT_HI:
        return _LAT_BUCKETS + 1
    return 1 + min(_LAT_BUCKETS - 1,
                   int(math.floor(math.log10(lat / _LAT_LO) * _LAT_BPD)))


class _Acc:
    """One streaming cell: confusion counts + Welford latency moments +
    the log-bucket latency histogram.  O(1) per item, O(1) memory."""

    __slots__ = ("n", "tp", "fp", "fn", "mean", "m2", "max_lat", "hist")

    def __init__(self) -> None:
        self.n = 0
        self.tp = self.fp = self.fn = 0
        self.mean = 0.0
        self.m2 = 0.0
        self.max_lat = 0.0
        self.hist = np.zeros(_LAT_BUCKETS + 2, np.int64)

    def add(self, lat: float, decision: bool, truth: bool) -> None:
        self.n += 1
        if decision and truth:
            self.tp += 1
        elif decision:
            self.fp += 1
        elif truth:
            self.fn += 1
        d = lat - self.mean
        self.mean += d / self.n
        self.m2 += d * (lat - self.mean)
        if lat > self.max_lat:
            self.max_lat = lat
        self.hist[_lat_bucket(lat)] += 1

    def f_score(self, lam: float = 2.0) -> float:
        return _f_counts(self.tp, self.fp, self.fn, lam)

    @property
    def var(self) -> float:
        return self.m2 / self.n if self.n else 0.0

    def percentile(self, q: float = 0.99) -> float:
        """Histogram percentile: upper edge of the rank's bucket, clamped
        to the observed max (single-sample cells are therefore exact)."""
        if not self.n:
            return 0.0
        rank = max(1, int(math.ceil(q * self.n)))
        cum = 0
        for i, c in enumerate(self.hist):
            cum += int(c)
            if cum >= rank:
                if i == 0:
                    return min(_LAT_LO, self.max_lat)
                if i > _LAT_BUCKETS:
                    return self.max_lat
                edge = _LAT_LO * 10.0 ** (i / _LAT_BPD)
                return min(edge, self.max_lat)
        return self.max_lat


class StreamingWindows:
    """Streaming windowed report aggregates: O(windows + queries) memory
    instead of O(items) arrays.

    The metropolis preset finishes ~10^6 items per run; keeping per-item
    latency/decision/truth arrays (and then binning them at report time)
    is the O(items) cost this replaces.  ``add`` folds each finished item
    into three cells at O(1): the run total, its fixed-width finish-time
    window (``accuracy_timeline``), and its query's row
    (``per_query_summary``).  Enabled by ``Scenario.metrics_window_s``;
    the exact array path stays the default everywhere else."""

    def __init__(self, window_s: float):
        if window_s <= 0:
            raise ValueError(f"window_s must be positive, got {window_s}")
        self.window_s = float(window_s)
        self.total = _Acc()
        self.windows: Dict[int, _Acc] = {}
        self.queries: Dict[int, _Acc] = {}

    @property
    def n(self) -> int:
        return self.total.n

    def add(self, t: float, lat: float, decision: bool, truth: bool,
            query: int) -> None:
        self.total.add(lat, decision, truth)
        w = int(t // self.window_s)
        cell = self.windows.get(w)
        if cell is None:
            cell = self.windows[w] = _Acc()
        cell.add(lat, decision, truth)
        qcell = self.queries.get(query)
        if qcell is None:
            qcell = self.queries[query] = _Acc()
        qcell.add(lat, decision, truth)

    def timeline(self, lam: float = 2.0) -> List[Dict[str, float]]:
        """Same row schema as ``QueryReport.accuracy_timeline`` (windows
        with zero finished items never exist in the dict, so they are
        omitted exactly like the array path omits them)."""
        return [{"t_start": round(w * self.window_s, 3), "n": c.n,
                 "f2": round(c.f_score(lam), 4)}
                for w, c in sorted(self.windows.items())]


@dataclasses.dataclass
class QueryReport:
    scenario: str
    scheme: str
    latencies: np.ndarray                  # (n_items,) seconds, finish order
    decisions: np.ndarray                  # (n_items,) bool: "is query object"
    truths: np.ndarray                     # (n_items,) bool ground truth
    finish_times: np.ndarray               # (n_items,) absolute seconds
    uploaded_bytes: int                    # shipped over the WAN uplink
    lan_bytes: int                         # shipped edge-to-edge
    escalated: int                         # items sent for re-classification
    rerouted: int                          # raw batches shed / failed-over
    kernel_launches: int                   # batched triage_pallas calls
    ticks: int                             # scheduler intervals simulated
    queue_timeline: Dict[int, np.ndarray]  # node -> (ticks,) queue length
    per_node_busy: Dict[int, float]        # node -> total service seconds
    per_node_served: Dict[int, int]        # node -> items serviced
    # edge -> final (alpha, beta): per-edge Eqs. 8-9 state at end of run
    # (empty for the non-cascade schemes)
    thresholds: Dict[int, Tuple[float, float]] = \
        dataclasses.field(default_factory=dict)
    # stage -> wall-clock seconds: frontend stages (the pixel path reports
    # render_s / framediff_s / classify_s) plus the engine's triage_s —
    # where a frames-to-answers run actually spent its compute
    stage_timings: Dict[str, float] = dataclasses.field(default_factory=dict)
    # --- runtime query lifecycle ----------------------------------------------
    # per-item query id aligned with latencies/decisions/truths (all zeros
    # for implicit single-query runs)
    query_ids: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0, np.int64))
    # query -> lifecycle facts from the pipeline (train_scheme, train_s,
    # t_arrive_s, t_retire_s, deferred, live_edges, thresholds); empty for
    # implicit single-query runs
    queries: Dict[int, Dict] = dataclasses.field(default_factory=dict)
    cloud_train_s: float = 0.0             # total Fig. 5 fine-tune seconds
    #                                        charged on the cloud node
    # --- feedback loop (cloud -> edge online recalibration) -------------------
    downloaded_bytes: int = 0              # model updates over the downlink
    #                                        (real wire size: int8-quantized
    #                                        when Scenario.quantize_downlink)
    downlink_fp_bytes: int = 0             # fp-equivalent downlink cost —
    #                                        the differential reference the
    #                                        quantized bytes are gated
    #                                        against (== downloaded_bytes on
    #                                        the fp path)
    model_updates: int = 0                 # fused calibrate launches (one
    #                                        ops.calibrate_fleet per event)
    # --- speculative escalation (Scenario.speculative_escalation) -------------
    provisional: int = 0                   # verdicts served at upload start
    reconciled: int = 0                    # cloud answers reconciled against
    #                                        a served provisional verdict
    reconciliation_flips: int = 0          # reconciliations that changed
    #                                        the answer (fed back as labels)
    provisional_latency_sum: float = 0.0   # sum of arrival->provisional-serve
    #                                        latencies (seconds)
    # simulated seconds-on-the-wire per link family (transfer time belongs
    # to transport, never to the node latency estimators)
    wan_transfer_s: float = 0.0
    lan_transfer_s: float = 0.0
    # --- scan-superstep runtime (Scenario.superstep) --------------------------
    supersteps: int = 0                    # fused multi-tick device launches
    triaged_ticks: int = 0                 # ticks that had ready work (the
    #                                        per-tick driver pays one launch
    #                                        for each of these; the superstep
    #                                        driver pays one per boundary-free
    #                                        run — their ratio is the
    #                                        host-loop reduction factor)
    # streaming aggregates (Scenario.metrics_window_s): when set, the
    # per-item arrays above are EMPTY and every metric below reads the
    # O(window) cells instead — city-of-cameras runs must not hold (or
    # sort) per-item arrays at report time
    stream: Optional[StreamingWindows] = None
    # --- serving control plane (admission / tiers / alerts) -------------------
    # alert kind -> count: the run's alerts/# bus traffic (quota, backlog,
    # failover, shed_batch, queue_depth, threshold_drift), snapshotted
    # from the AlertStream; empty when nothing alerted
    alerts: Dict[str, int] = dataclasses.field(default_factory=dict)
    submitted_queries: int = 0             # QueryArrivals seen by admission
    #                                        (0 when admission is off)
    shed_queries: int = 0                  # submissions admission refused
    shed_items: int = 0                    # stream items dropped because
    #                                        their query was shed
    # tier -> {n, mean_latency_s, p99_latency_s, slo_s, slo_breaches}:
    # per-priority-tier latency cells (tiers declared only) — the
    # priority-inversion evidence: tier 0 must hold its SLO while lower
    # tiers queue and shed
    tier_latency: Dict[int, Dict[str, float]] = \
        dataclasses.field(default_factory=dict)
    # --- cross-camera track queries (QuerySpec.kind == "track") ---------------
    # all zero (and absent from summary()) on classify-only runs, so every
    # pre-track report row keeps its exact schema
    track_items: int = 0                   # embedded detections associated
    tracks_born: int = 0                   # registry track births
    track_matches: int = 0                 # crop -> live-track associations
    id_switches: int = 0                   # ground-truth object re-observed
    #                                        on a DIFFERENT registry track
    track_opportunities: int = 0           # ground-truth re-observations
    #                                        (the ID-switch denominator)
    track_handoffs: int = 0                # associations that crossed edges
    prewarms_shipped: int = 0              # predictive hand-off downlink
    #                                        shipments (Transport.ship_update)
    prewarm_hits: int = 0                  # matches only the pre-warmed
    #                                        (not naturally warm) floor
    #                                        accepted — the hand-off's win
    track_launches: int = 0                # fused ops.associate_tracks
    #                                        launches (<= 1 per tick)
    # edge -> AlertStream.health_snapshot(edge): per-edge alert counts +
    # recent alert payloads, the operator's health view (never in summary()
    # — it is a nested dict, not a flat metric column)
    edge_health: Dict[int, Dict] = dataclasses.field(default_factory=dict)

    @property
    def n_items(self) -> int:
        """Finished items, whichever accumulation path the run used."""
        return self.stream.n if self.stream is not None \
            else len(self.latencies)

    # --- accuracy -------------------------------------------------------------
    def f_score(self, lam: float = 2.0) -> float:
        """F_lambda (paper uses F2: recall-weighted)."""
        if self.stream is not None:
            return self.stream.total.f_score(lam)
        return _f_score(self.decisions, self.truths, lam)

    @property
    def true_positives(self) -> int:
        """Correctly answered query items — the denominator of the paper's
        bandwidth-efficiency view (uplink bytes spent per useful answer)."""
        if self.stream is not None:
            return self.stream.total.tp
        return int(np.count_nonzero(self.decisions & self.truths)) \
            if len(self.decisions) else 0

    # --- latency --------------------------------------------------------------
    @property
    def avg_latency(self) -> float:
        if self.stream is not None:
            return self.stream.total.mean if self.stream.n else 0.0
        return float(np.mean(self.latencies)) if len(self.latencies) else 0.0

    @property
    def p99_latency(self) -> float:
        """p99 finish latency; on the streaming path this is the histogram
        read-out (exact for single-sample cells, otherwise within one
        log-bucket of the sorted-array percentile)."""
        if self.stream is not None:
            return self.stream.total.percentile(0.99)
        return float(np.percentile(self.latencies, 99)) \
            if len(self.latencies) else 0.0

    @property
    def latency_var(self) -> float:
        if self.stream is not None:
            return self.stream.total.var
        return float(np.var(self.latencies)) if len(self.latencies) else 0.0

    def accuracy_timeline(self, window_s: float = 10.0,
                          lam: float = 2.0) -> List[Dict[str, float]]:
        """Windowed F_lambda over finish time: ``[{t_start, n, f2}, ...]``.

        This is how concept-drift recovery becomes visible: on
        ``drifting_city`` the open-loop ablation's windows slump after
        ``drift_at_s`` and stay down, while the closed loop's climb back
        once the first post-drift ``ModelUpdate`` delivers.  Windows with
        zero finished items are omitted (a NaN row would poison JSON
        artifact consumers).

        On the streaming path the window width was fixed when the run
        started (``Scenario.metrics_window_s``); ``window_s`` here is
        ignored — re-binning would need the per-item arrays the
        streaming path exists to avoid."""
        if self.stream is not None:
            return self.stream.timeline(lam)
        if not len(self.finish_times):
            return []
        out = []
        n_win = int(np.floor(float(self.finish_times.max()) / window_s)) + 1
        idx = np.minimum((self.finish_times // window_s).astype(int),
                         n_win - 1)
        for k in range(n_win):
            m = idx == k
            if not m.any():
                continue
            out.append({"t_start": round(k * window_s, 3),
                        "n": int(m.sum()),
                        "f2": round(_f_score(self.decisions[m],
                                             self.truths[m], lam), 4)})
        return out

    def per_query_summary(self, lam: float = 2.0) -> Dict[int, Dict]:
        """One row per query: accuracy/latency over ITS items, merged with
        the lifecycle facts the pipeline recorded (Fig. 5 train_scheme and
        train_s, arrival/retire instants, items deferred while its weights
        were training/in flight).

        This is where the Fig. 5 trade becomes legible at run time: an
        ``all_finetune`` query shows the largest ``train_s`` and the worst
        head-of-query latency (its early detections waited out the
        fine-tune), a ``no_finetune`` query shows ``train_s == 0`` but the
        lowest ``f2``."""
        if self.stream is not None:
            out: Dict[int, Dict] = {}
            known = set(self.queries) | set(self.stream.queries)
            for q in sorted(int(q) for q in known):
                c = self.stream.queries.get(q)
                row = {
                    "n_items": c.n if c else 0,
                    "f2": round(c.f_score(lam), 4) if c else 0.0,
                    "avg_latency_s": round(c.mean, 3) if c else 0.0,
                    "p99_latency_s": round(c.percentile(0.99), 3)
                    if c else 0.0,
                }
                row.update(self.queries.get(q, {}))
                out[q] = row
            return out
        qids = self.query_ids if len(self.query_ids) else \
            np.zeros(len(self.latencies), np.int64)
        out: Dict[int, Dict] = {}
        known = set(self.queries) | set(np.unique(qids[:len(self.latencies)])
                                        if len(self.latencies) else [])
        for q in sorted(int(q) for q in known):
            m = qids == q
            n = int(m.sum())
            row = {
                "n_items": n,
                "f2": round(_f_score(self.decisions[m], self.truths[m],
                                     lam), 4) if n else 0.0,
                "avg_latency_s": round(float(np.mean(self.latencies[m])), 3)
                if n else 0.0,
                "p99_latency_s": round(
                    float(np.percentile(self.latencies[m], 99)), 3)
                if n else 0.0,
            }
            row.update(self.queries.get(q, {}))
            out[q] = row
        return out

    def summary(self) -> Dict[str, float]:
        """Flat row with the Tables II-IV column schema (+ harness extras)."""
        return {
            "scheme": self.scheme,
            "accuracy_F2": round(self.f_score(2.0), 4),
            "avg_latency_s": round(self.avg_latency, 3),
            "p99_latency_s": round(self.p99_latency, 3),
            "latency_var": round(self.latency_var, 3),
            "bandwidth_MB": round(self.uploaded_bytes / 1e6, 2),
            "lan_MB": round(self.lan_bytes / 1e6, 2),
            "downloaded_MB": round(self.downloaded_bytes / 1e6, 3),
            # raw bytes too: the loader's updates-without-downlink gate
            # must not be fooled by MB rounding on tiny payloads
            "downloaded_bytes": self.downloaded_bytes,
            # fp-equivalent downlink cost: the quantized-shipping reduction
            # is downlink_fp_bytes / downloaded_bytes within ONE row (and
            # the gate rejects quantized > fp as a wire-accounting bug)
            "downlink_fp_MB": round(self.downlink_fp_bytes / 1e6, 3),
            "downlink_fp_bytes": self.downlink_fp_bytes,
            "model_updates": self.model_updates,
            # bandwidth efficiency: WAN upload spent per correct positive
            # answer (the paper's 7x-less-bandwidth headline, normalized)
            "uplink_bytes_per_TP": round(
                self.uploaded_bytes / max(self.true_positives, 1), 1),
            # speculative escalation: how often the edge's provisional
            # verdict disagreed with the cloud's, and how fast the edge
            # actually answered escalated items
            "reconciliation_flip_rate": round(
                self.reconciliation_flips / self.reconciled, 4)
            if self.reconciled else 0.0,
            "provisional_latency_s": round(
                self.provisional_latency_sum / self.provisional, 3)
            if self.provisional else 0.0,
            "provisional": self.provisional,
            "reconciled": self.reconciled,
            "escalated": self.escalated,
            "rerouted": self.rerouted,
            "kernel_launches": self.kernel_launches,
            "ticks": self.ticks,
            "launches_per_tick": round(
                self.kernel_launches / max(self.ticks, 1), 3),
            # scan-superstep runtime: 0 supersteps == per-tick driver; a
            # superstep run's triaged_ticks / supersteps ratio is the
            # host-loop reduction the fused scan bought
            "supersteps": self.supersteps,
            # multi-query runtime: the launch columns above NOT scaling
            # with n_queries is the fused-(Q, E, N)-launch proof
            "n_queries": max(1, len(self.queries)
                             or (len(self.stream.queries)
                                 if self.stream is not None
                                 else (len(np.unique(self.query_ids))
                                       if len(self.query_ids) else 1))),
            "cloud_train_s": round(self.cloud_train_s, 3),
            **self._control_plane_summary(),
            **self._track_summary(),
        }

    @property
    def track_continuity(self) -> float:
        """1 - id_switches / opportunities: fraction of ground-truth
        re-observations that kept their registry identity (1.0 when no
        opportunities — an empty run has nothing to switch)."""
        if not self.track_opportunities:
            return 1.0
        return 1.0 - self.id_switches / self.track_opportunities

    def _track_summary(self) -> Dict[str, float]:
        """Track columns — only emitted when a track query actually ran,
        so classify-only rows keep their exact schema."""
        if not self.track_items:
            return {}
        return {
            "track_items": self.track_items,
            "tracks_born": self.tracks_born,
            "track_matches": self.track_matches,
            "id_switches": self.id_switches,
            "track_continuity": round(self.track_continuity, 4),
            "track_handoffs": self.track_handoffs,
            "prewarms_shipped": self.prewarms_shipped,
            "prewarm_hits": self.prewarm_hits,
            # <= 1.0 by construction: the per-tick fused-launch budget
            "track_launches_per_tick": round(
                self.track_launches / max(self.ticks, 1), 3),
        }

    def _control_plane_summary(self) -> Dict[str, float]:
        """Admission/tier/alert columns — only emitted when the control
        plane actually ran (tiers declared or submissions seen), so
        pre-control-plane rows keep their exact schema."""
        out: Dict[str, float] = {}
        if self.submitted_queries or self.alerts:
            out["alerts_total"] = sum(self.alerts.values())
        if self.submitted_queries:
            out["submitted_queries"] = self.submitted_queries
            out["shed_queries"] = self.shed_queries
            out["shed_items"] = self.shed_items
            out["shed_rate"] = round(
                self.shed_queries / self.submitted_queries, 4)
        if self.tier_latency:
            top = min(self.tier_latency)
            out["slo_breach_top_tier"] = \
                self.tier_latency[top]["slo_breaches"]
            for k, row in sorted(self.tier_latency.items()):
                out[f"p99_latency_tier{k}"] = round(
                    row["p99_latency_s"], 3)
                out[f"slo_breach_tier{k}"] = row["slo_breaches"]
        return out


def merge_timelines(samples: List[Dict[int, int]]) -> Dict[int, np.ndarray]:
    """Per-tick {node: queue_len} samples -> {node: (ticks,) array}."""
    if not samples:
        return {}
    nodes = sorted(samples[0])
    return {n: np.asarray([s[n] for s in samples], dtype=np.int64)
            for n in nodes}
