"""Pixel-path frontend: rendered frames -> motion crops -> CQ scores -> Items.

The paper's query pipeline starts from pixels (§IV): frame differencing
(Eqs. 1-6) finds moving objects, their crops go through the fine-tuned CQ
classifier, and only the classifier's confidences enter the cascade.  This
module runs that path over the procedural camera fleet:

  1. render — every camera produces one synthetic frame triple per
     scheduler tick (``scenario.frame_schedule`` staggers captures within
     the tick), batched fleet-wide into one (C, 3, H, W, 3) array.
  2. framediff — the FUSED pixel cascade (ONE Pallas launch per tick:
     framediff + dilate + erode + foreground count, see
     ``kernels/pixel_cascade.py``) and the connected-component labeler
     (``repro.detection.pipeline.detect``) turn the tick's frames into
     filtered moving-object crops; the counts skip CCL on motionless
     ticks.  ``fused=False`` keeps the original staged three-launch
     chain as the differential reference.
  3. classify — all of the tick's crops, across every camera, are scored
     by the CQ classifier in ONE bucket-padded jit launch
     (``kernels.ops.score_crops``) — launches per tick stay O(1) in fleet
     size, exactly like the fused triage kernel downstream.

The output is the same ``Item`` stream the engine's event loop consumes,
so ``run_query(sc, frontend=PixelFrontend())`` is the paper's full
frames -> triage -> allocation -> metrics loop.  Ground truth comes from
the renderer: each detection is matched to the nearest planted sprite
(unmatched detections are disturbance and count as non-query).

Per-stage wall-clock (render/framediff/classify) is recorded and surfaces
in ``QueryReport.stage_timings`` next to the engine's triage timing.

By default the classifier is a freshly initialized (untrained) CQ edge
model — the full compute path with no training in the loop, for tests and
smoke runs.  Pass ``params=`` (e.g. from ``repro.serving.workload.
build_workload`` or ``repro.core.finetune``) to score with a fine-tuned
model and get paper-meaningful accuracy numbers.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.configs import get_config
from repro.core.cascade import confidence_from_logits
from repro.data import synthetic_video as SV
from repro.detection import pipeline as DP
from repro.detection.components import Box
from repro.kernels import ops
from repro.models import meta as M
from repro.models import transformer as T
from repro.serving.simulator import Item
from repro.system.frontend import Frontend
from repro.system.scenario import Scenario, frame_schedule, scenario_cameras


def match_truth(box: Box, truth: SV.FrameTruth,
                radius: float = SV.SPRITE) -> Optional[int]:
    """Class of the planted sprite a detection box corresponds to.

    Nearest truth object whose center lies within ``radius`` of the box
    center on both axes (the renderer's sprites are SPRITE x SPRITE);
    ``None`` when the detection matches nothing — disturbance/noise."""
    cy = (box.y0 + box.y1) / 2
    cx = (box.x0 + box.x1) / 2
    best, best_d = None, float("inf")
    for cls, (y, x) in zip(truth.classes, truth.boxes):
        dy = abs(cy - (y + SV.SPRITE / 2))
        dx = abs(cx - (x + SV.SPRITE / 2))
        if dy < radius and dx < radius and dy + dx < best_d:
            best, best_d = cls, dy + dx
    return best


def _conf_apply(cfg, params, tokens: jax.Array) -> jax.Array:
    """(N, T) patch tokens -> (N,) P(query object) under the CQ model."""
    h, _ = T.forward(cfg, params, tokens, remat=False)
    return confidence_from_logits(T.classify(cfg, params, h), 1)


class PixelFrontend(Frontend):
    """Frames-to-items frontend over the procedural camera fleet.

    One instance owns one CQ classifier (config + params) and caches the
    last scenario's stream, so sweeping the four schemes over one scenario
    renders and scores the fleet's frames once, not four times.
    """

    def __init__(self, *, arch: str = "surveiledge-cls",
                 params=None, seed: int = 0,
                 query_class: int = SV.QUERY_CLASS,
                 threshold: int = 40, crop: int = 32, min_area: int = 12,
                 use_pallas: bool = True, fused: bool = True,
                 cache: bool = True):
        super().__init__()
        assert crop % 8 == 0, "crop side must be patch-aligned (8 px)"
        full = get_config(arch)
        self.cfg = dataclasses.replace(
            full.edge_variant(), num_query_classes=2,
            vocab_size=full.vocab_size)
        self.params = params if params is not None \
            else M.init_params(self.cfg, jax.random.PRNGKey(seed))
        self.query_class = query_class
        self.threshold = threshold
        self.crop = crop
        self.min_area = min_area
        self.use_pallas = use_pallas
        self.fused = fused           # ONE fused pixel launch vs staged three
        self.launches = 0            # classifier launches (one per tick)
        self._conf_fn = jax.jit(functools.partial(_conf_apply, self.cfg))
        self._cache_enabled = cache
        self._cache: Optional[Tuple[tuple, List[Item], Dict[str, float]]] \
            = None

    # stream identity: every scenario field the rendered stream depends on
    # (scheme, links and topology speeds don't change what the cameras see)
    @staticmethod
    def _stream_key(sc: Scenario) -> tuple:
        return (sc.name, sc.seed, sc.num_cameras, sc.num_edges,
                sc.duration_s, sc.interval_s, sc.burst_boost, sc.burst_rate,
                sc.frame_hw, sc.track_query_ids, sc.embedding_dim)

    def stream(self, sc: Scenario) -> List[Item]:
        key = self._stream_key(sc)
        if self._cache is not None and self._cache[0] == key:
            _, items, timings = self._cache
            self._timings = dict(timings)
            return list(items)
        items, timings = self._build(sc)
        self._timings = dict(timings)
        if self._cache_enabled:
            self._cache = (key, list(items), timings)
        return items

    def _build(self, sc: Scenario) -> Tuple[List[Item], Dict[str, float]]:
        cams = scenario_cameras(sc)
        schedule = frame_schedule(sc)                        # (T, C)
        rng = np.random.default_rng(sc.seed + 31)
        t_render = t_framediff = t_classify = 0.0
        items: List[Item] = []
        for k in range(schedule.shape[0]):
            t0 = time.perf_counter()
            triples, truths = [], []
            for j, cam in enumerate(cams):
                frames, truth = SV.render_triple(cam, schedule[k, j], rng)
                triples.append(frames)
                truths.append(truth)
            batch = np.stack(triples)                # (C, 3, H, W, 3)
            t_render += time.perf_counter() - t0

            t0 = time.perf_counter()
            dets = DP.detect(batch, threshold=self.threshold, crop=self.crop,
                             min_area=self.min_area,
                             use_pallas=self.use_pallas, fused=self.fused)
            t_framediff += time.perf_counter() - t0

            flat = [(j, d) for j, per in enumerate(dets) for d in per]
            if not flat:
                continue
            t0 = time.perf_counter()
            tokens = SV.crops_to_tokens(
                np.stack([d.crop for _, d in flat]), self.cfg.vocab_size)
            conf = np.asarray(ops.score_crops(
                functools.partial(self._conf_fn, self.params), tokens))
            t_classify += time.perf_counter() - t0
            self.launches += 1

            nbytes = self.crop * self.crop * 3
            # track queries declared -> every detection carries a pixel-
            # derived re-ID embedding (appearance hash of the crop); no
            # trajectory ground truth on this path, so gt_track stays -1
            # (ID-switch accounting needs the synthetic-trajectory stream)
            embed = bool(sc.track_query_ids)
            for (j, det), cf in zip(flat, conf):
                cls = match_truth(det.box, truths[j])
                items.append(Item(
                    t_arrival=float(schedule[k, j]),
                    camera=cams[j].cam_id,
                    edge_device=cams[j].cam_id % sc.num_edges + 1,
                    conf=float(cf),
                    is_query=cls == self.query_class,
                    nbytes=nbytes,
                    emb=SV.crop_embedding(det.crop, sc.embedding_dim)
                    if embed else None))
        items.sort(key=lambda it: it.t_arrival)
        return items, {"render_s": t_render, "framediff_s": t_framediff,
                       "classify_s": t_classify}
