"""WAN/LAN transport layer for the query pipeline.

Two link families, matching the paper's deployment: a *shared* WAN uplink
(edge -> cloud) modelled as one FIFO — concurrent uploads serialize, which
is what makes cloud-only saturate (Table II) — and dedicated edge-to-edge
LAN links that never contend.  ``Transport`` owns both plus the byte
counters the ``QueryReport`` bandwidth columns are built from.
"""
from __future__ import annotations

from repro.serving.bus import FifoLink
from repro.system.scenario import Scenario


class Transport:
    """The pipeline's only gateway onto the wire."""

    def __init__(self, sc: Scenario):
        self._uplink = FifoLink(sc.uplink_MBps, sc.rtt_s)
        self._lan_MBps = sc.lan_MBps
        self._rtt_s = sc.rtt_s
        self.uploaded_bytes = 0     # shipped over the shared WAN uplink
        self.lan_bytes = 0          # shipped edge-to-edge

    def wan_send(self, t: float, nbytes: int) -> float:
        """Start an upload at ``t``; returns delivery time (FIFO-serialized)."""
        self.uploaded_bytes += nbytes
        return self._uplink.send(t, nbytes)

    def lan_send(self, t: float, nbytes: int) -> float:
        """Edge-to-edge transfer: dedicated link, non-contending."""
        self.lan_bytes += nbytes
        return t + nbytes / (self._lan_MBps * 1e6) + self._rtt_s

    def wan_backlog(self, t: float) -> float:
        """Seconds of queued WAN transfers ahead of a new upload at ``t``.

        Eq. 7 charges this to the cloud's cost (the paper folds transmission
        latency into t_0), and Eqs. 8-9 fold it into the escalation drain."""
        return self._uplink.backlog(t)
