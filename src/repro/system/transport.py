"""WAN/LAN transport layer for the query pipeline.

Three link families, matching the paper's deployment: a *shared* WAN
uplink (edge -> cloud) modelled as one FIFO — concurrent uploads
serialize, which is what makes cloud-only saturate (Table II) — a shared
WAN **downlink** (cloud -> edge) over which recalibrated CQ parameters
ship back to the fleet (the cloud's egress serializes the same way), and
dedicated edge-to-edge LAN links that never contend.  ``Transport`` owns
all three plus the byte counters the ``QueryReport`` bandwidth columns are
built from.

Transfer *time* is accounted here too (``wan_transfer_s`` /
``lan_transfer_s`` / ``downlink_transfer_s``): a task's time on the wire
belongs to the link, never to the serving node's latency estimator —
feeding it there would let one congestion burst permanently inflate a
node's Eq. 7 ``t_j`` while ``wan_backlog`` *also* charges the same
congestion, double-counting it.
"""
from __future__ import annotations

import numpy as np

from repro.distributed import quantize as QZ
from repro.serving.bus import FifoLink
from repro.system.scenario import Scenario


class Transport:
    """The pipeline's only gateway onto the wire."""

    def __init__(self, sc: Scenario):
        self._uplink = FifoLink(sc.uplink_MBps, sc.rtt_s)
        self._downlink = FifoLink(sc.downlink_MBps, sc.rtt_s)
        self._lan_MBps = sc.lan_MBps
        self._rtt_s = sc.rtt_s
        self._quantize = sc.quantize_downlink
        self.uploaded_bytes = 0     # shipped over the shared WAN uplink
        self.downloaded_bytes = 0   # shipped over the WAN downlink (updates)
        self.downlink_fp_bytes = 0  # fp-equivalent downlink cost (reference)
        self.lan_bytes = 0          # shipped edge-to-edge
        self.wan_transfer_s = 0.0   # cumulative uplink seconds-on-the-wire
        self.downlink_transfer_s = 0.0
        self.lan_transfer_s = 0.0

    def wan_send(self, t: float, nbytes: int) -> float:
        """Start an upload at ``t``; returns delivery time (FIFO-serialized)."""
        self.uploaded_bytes += nbytes
        done = self._uplink.send(t, nbytes)
        self.wan_transfer_s += done - t
        return done

    def wan_recv(self, t: float, nbytes: int) -> float:
        """Cloud -> edge shipment at ``t`` (model updates); returns delivery
        time.  The downlink is its own shared FIFO: a fleet-wide parameter
        push serializes edge by edge, so later edges see staler data."""
        self.downloaded_bytes += nbytes
        done = self._downlink.send(t, nbytes)
        self.downlink_transfer_s += done - t
        return done

    def ship_update(self, t: float, fp_nbytes: int, values=None):
        """Ship one ModelUpdate artifact cloud -> edge at ``t``.

        Returns ``(delivery_time, values_as_delivered)``.  Under
        ``Scenario.quantize_downlink`` the link is charged the exact int8
        wire size (``quantize.quantized_wire_nbytes`` — values + per-channel
        scale/zero + framing) and any materialized ``values`` round-trip
        encode->decode, so the edge applies the parameters it actually
        received, quantization error included.  ``downlink_fp_bytes``
        always accumulates the full-width cost: it is the differential
        reference the report gate compares the charged bytes against."""
        self.downlink_fp_bytes += fp_nbytes
        if self._quantize:
            nbytes = QZ.quantized_wire_nbytes(fp_nbytes)
            if values is not None:
                values = QZ.decode_wire(QZ.encode_wire(np.asarray(values)))
        else:
            nbytes = fp_nbytes
        return self.wan_recv(t, nbytes), values

    def lan_send(self, t: float, nbytes: int) -> float:
        """Edge-to-edge transfer: dedicated link, non-contending."""
        self.lan_bytes += nbytes
        done = t + nbytes / (self._lan_MBps * 1e6) + self._rtt_s
        self.lan_transfer_s += done - t
        return done

    def wan_backlog(self, t: float) -> float:
        """Seconds of queued WAN transfers ahead of a new upload at ``t``.

        Eq. 7 charges this to the cloud's cost (the paper folds transmission
        latency into t_0), and Eqs. 8-9 fold it into the escalation drain.
        It is the *sole* congestion charge — completion times feed the node
        estimators net of transfer, so congestion is never counted twice."""
        return self._uplink.backlog(t)
