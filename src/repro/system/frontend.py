"""Frontend seam: how detections enter the query pipeline.

A ``Frontend`` turns a scenario into the per-item detection stream the
event loop consumes.  Two implementations exist:

- ``ConfidenceStreamFrontend`` — pre-scored confidences: either a
  model-free synthetic stream from the scenario's camera fleet or an
  injected pre-scored stream (the CQ-model-scored benchmark workload)
  re-homed onto the scenario's topology.
- ``PixelFrontend`` (``repro.system.pixel_frontend``) — the paper's actual
  pixel path: rendered frames -> Pallas framediff/morphology -> moving
  object crops -> CQ-classifier confidences.

Frontends may record per-stage wall-clock seconds in ``self._timings``
while building the stream; ``run_query`` merges ``Frontend.timings`` into
``QueryReport.stage_timings`` next to the engine's own triage timing, so a
report shows where a frames-to-answers run actually spent its time.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Dict, List, Optional, Sequence

from repro.serving.simulator import Item
from repro.system.scenario import Scenario, synthetic_confidence_stream


def rehome(items: Sequence[Item], sc: Scenario) -> List[Item]:
    """Map a stream's edge ids onto ``sc``'s edges 1..E, sorted by arrival."""
    E = sc.num_edges
    stream = [dataclasses.replace(
        it, edge_device=(it.edge_device - 1) % E + 1)
        for it in items]
    stream.sort(key=lambda it: it.t_arrival)
    return stream


class Frontend(abc.ABC):
    """Produces the detection stream one scenario's run consumes."""

    def __init__(self):
        # per-instance so one frontend's stage timings can never bleed into
        # another's; subclasses fill this during stream()
        self._timings: Dict[str, float] = {}

    @abc.abstractmethod
    def stream(self, sc: Scenario) -> List[Item]:
        """Items sorted by arrival time, homed onto ``sc``'s edges."""

    @property
    def timings(self) -> Dict[str, float]:
        """Wall-clock seconds per frontend stage for the LAST ``stream()``
        call (empty for frontends with no model in the loop)."""
        return dict(self._timings)


class ConfidenceStreamFrontend(Frontend):
    """Pre-scored confidences: injected items, or a synthetic model-free
    stream (class-conditional Beta confidences) from the camera fleet."""

    def __init__(self, items: Optional[Sequence[Item]] = None):
        super().__init__()
        self._items = items

    def stream(self, sc: Scenario) -> List[Item]:
        if self._items is None:
            return synthetic_confidence_stream(sc)
        return rehome(self._items, sc)
