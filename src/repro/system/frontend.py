"""Frontend seam: how detections enter the query pipeline.

A ``Frontend`` turns a scenario into the per-item detection stream the
event loop consumes.  Today there is one implementation — the
confidence-stream frontend, which either synthesizes a model-free stream
from the scenario's camera fleet or re-homes an injected pre-scored stream
(the CQ-model-scored benchmark workload) onto the scenario's topology.

The seam exists so the pixel path can slot in next: a CNN frontend that
runs frame differencing + morphology + the CQ classifier over rendered
frames (``repro.detection``) plugs in here without touching the engine.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import List, Optional, Sequence

from repro.serving.simulator import Item
from repro.system.scenario import Scenario, synthetic_confidence_stream


class Frontend(abc.ABC):
    """Produces the detection stream one scenario's run consumes."""

    @abc.abstractmethod
    def stream(self, sc: Scenario) -> List[Item]:
        """Items sorted by arrival time, homed onto ``sc``'s edges."""


class ConfidenceStreamFrontend(Frontend):
    """Pre-scored confidences: injected items, or a synthetic model-free
    stream (class-conditional Beta confidences) from the camera fleet."""

    def __init__(self, items: Optional[Sequence[Item]] = None):
        self._items = items

    def stream(self, sc: Scenario) -> List[Item]:
        if self._items is None:
            return synthetic_confidence_stream(sc)
        E = sc.num_edges
        stream = [dataclasses.replace(
            it, edge_device=(it.edge_device - 1) % E + 1)
            for it in self._items]
        stream.sort(key=lambda it: it.t_arrival)
        return stream
