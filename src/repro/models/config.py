"""Model configuration for every architecture family the framework supports.

A single ``ModelConfig`` dataclass describes dense / MoE / SSM / hybrid /
encoder-decoder (audio) / VLM backbones.  Architecture files under
``repro.configs`` instantiate it with the exact assigned values and register
themselves in the global registry.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

Family = str  # 'dense' | 'moe' | 'ssm' | 'hybrid' | 'audio' | 'vlm'


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    # --- identity -----------------------------------------------------------
    name: str
    family: Family
    source: str = ""                    # citation (hf:/arXiv: per assignment)

    # --- transformer trunk --------------------------------------------------
    num_layers: int = 2
    d_model: int = 256
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 0                   # 0 -> d_model // num_heads
    d_ff: int = 1024                    # per-expert width for MoE
    vocab_size: int = 32000
    max_seq_len: int = 1 << 20

    # --- attention flavour --------------------------------------------------
    attn_bias: bool = False             # QKV bias (qwen1.5, chatglm, whisper)
    qk_norm: bool = False               # per-head RMSNorm on q,k (qwen3)
    rope_style: str = "neox"            # 'neox' | '2d' (chatglm half-dim) | 'none'
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None  # None -> full causal
    parallel_block: bool = False        # attn & mlp in parallel (command-r)
    logit_softcap: float = 0.0

    # --- norms / act ---------------------------------------------------------
    norm_type: str = "rmsnorm"          # 'rmsnorm' | 'layernorm'
    norm_eps: float = 1e-5
    mlp_act: str = "silu"               # 'silu' (gated) | 'gelu' (non-gated)
    tie_embeddings: bool = False

    # --- MoE -----------------------------------------------------------------
    num_experts: int = 0                # 0 -> dense MLP
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # --- SSM (mamba2 / SSD) --------------------------------------------------
    ssm_state: int = 0                  # 0 -> no ssm path
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_ngroups: int = 1

    # --- encoder-decoder (audio) --------------------------------------------
    num_enc_layers: int = 0             # >0 -> enc-dec model (whisper)
    enc_seq: int = 1500                 # fixed encoder frame count (stub frontend)

    # --- VLM -----------------------------------------------------------------
    num_img_tokens: int = 0             # >0 -> image-embedding prefix (stub ViT)

    # --- serving ------------------------------------------------------------
    kv_cache_dtype: str = "model"       # 'model' (= activations) | 'int8'
    attn_impl: str = "chunked"          # 'chunked' (pure-XLA) | 'flash'
                                        # (Pallas fused kernel; TPU target)

    # --- cascade (SurveilEdge) head -----------------------------------------
    num_query_classes: int = 2          # CQ-specific classifier head width

    # ------------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        if self.num_heads and self.num_heads % self.num_kv_heads != 0:
            raise ValueError(f"{self.name}: num_heads % num_kv_heads != 0")

    # derived ----------------------------------------------------------------
    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def has_attn(self) -> bool:
        return self.family != "ssm"

    @property
    def is_encdec(self) -> bool:
        return self.num_enc_layers > 0

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    # parameter counting (analytic; for roofline MODEL_FLOPS = 6 N D) --------
    def param_count(self, active_only: bool = False) -> int:
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.num_layers
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        n = V * D                                   # embed
        if not self.tie_embeddings:
            n += D * V                              # lm head
        per_layer = 0
        if self.has_attn:
            per_layer += D * H * hd + 2 * D * KV * hd + H * hd * D
        if self.has_ssm:
            d_in = self.ssm_d_inner
            conv_ch = d_in + 2 * self.ssm_ngroups * self.ssm_state
            per_layer += D * (2 * d_in + 2 * self.ssm_ngroups * self.ssm_state
                              + self.ssm_heads)     # in_proj
            per_layer += self.ssm_conv * conv_ch    # conv
            per_layer += d_in * D                   # out_proj
        if self.is_moe:
            e = self.top_k if active_only else self.num_experts
            gate = 3 if self.mlp_act == "silu" else 2
            per_layer += e * gate * D * F + D * self.num_experts
        elif F > 0:
            gate = 3 if self.mlp_act == "silu" else 2
            per_layer += gate * D * F
        n += L * per_layer
        if self.is_encdec:                          # encoder stack + cross attn
            enc_layer = D * H * hd * 4 + (3 if self.mlp_act == "silu" else 2) * D * F
            cross = D * H * hd * 4
            n += self.num_enc_layers * enc_layer + L * cross
        return n

    # reduced variants --------------------------------------------------------
    def reduced(self) -> "ModelConfig":
        """Smoke-test variant: <=2 layers, d_model<=512, <=4 experts."""
        if self.num_heads:
            H = min(self.num_heads, 4)
            KV = max(1, min(self.num_kv_heads, H))
            while H % KV:
                KV -= 1
            d = min(self.d_model, 256)
            hd = max(8, d // H)
            d = H * hd
        else:  # attention-free (ssm)
            H, KV, hd = 0, 1, 0
            d = min(self.d_model, 256)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            num_layers=2,
            num_enc_layers=min(self.num_enc_layers, 2),
            d_model=d,
            num_heads=H,
            num_kv_heads=KV,
            head_dim=hd,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            ssm_headdim=min(self.ssm_headdim, 32) if self.has_ssm else self.ssm_headdim,
            ssm_state=min(self.ssm_state, 32) if self.has_ssm else 0,
            ssm_chunk=32,
            enc_seq=min(self.enc_seq, 24),
            num_img_tokens=min(self.num_img_tokens, 8),
        )

    def edge_variant(self) -> "ModelConfig":
        """CQ-specific ('edge') variant: the lightweight cascade front model.

        Plays MobileNet-v2's role from the paper: same family, 2 layers,
        narrow width, fine-tuned per (cluster x query).
        """
        cfg = self.reduced()
        return dataclasses.replace(cfg, name=self.name + "-edge")
