"""Trunk assembly: decoder blocks, scan-over-layers, prefill/decode caches.

One ``decoder_block`` covers all six architecture families via config flags:
  dense       attn + MLP                       (qwen1.5, command-r, chatglm3, qwen3)
  moe         attn + sort-based MoE            (phi3.5-moe, granite-moe)
  ssm         Mamba-2 mixer only               (mamba2)
  hybrid      parallel attn + SSM heads + MLP  (hymba)
  audio       enc-dec w/ cross-attn            (whisper; conv frontend stubbed)
  vlm         dense + image-embedding prefix   (internvl2; ViT stubbed)

The trunk is evaluated with a single ``lax.scan`` over stacked layer params so
HLO size is depth-independent (compile-time requirement for the dry-run).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _cf(ctx) -> Callable[[jax.Array, str], jax.Array]:
    return ctx if ctx is not None else (lambda x, name: x)


def maybe_dequant(tree, dtype=jnp.bfloat16):
    """Dequantize int8-served weights ({'q','s'} leaves) on the fly.

    Called inside scan bodies so only one layer's weights are ever resident
    in bf16 (see distributed/quantize.py).  No-op for fp params.
    """
    from repro.distributed import quantize as QZ
    return QZ.dequant_tree(tree, dtype)


# --- embeddings / positions -----------------------------------------------------

def embed_tokens(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    emb = params["embed"]
    if isinstance(emb, dict):        # int8-served: gather rows, then scale
        rows = jnp.take(emb["q"], tokens, axis=0).astype(jnp.float32)
        return (rows * emb["s"]).astype(jnp.bfloat16)
    return jnp.take(emb, tokens, axis=0)


def sinusoid_pos(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --- single decoder block --------------------------------------------------------

def decoder_block(cfg: ModelConfig, lp: Params, x: jax.Array, *,
                  q_pos: jax.Array,
                  k_pos: Optional[jax.Array] = None,
                  cache: Optional[Cache] = None,
                  decode: bool = False,
                  window: Optional[int] = None,
                  enc_out: Optional[jax.Array] = None,
                  ctx=None) -> Tuple[jax.Array, Optional[Cache], jax.Array]:
    """Apply one layer.  Returns (x, new_cache, aux_loss)."""
    c = _cf(ctx)
    aux = jnp.zeros((), jnp.float32)
    new_cache: Cache = {}
    h = L.norm_apply(cfg, lp["norm1"], x)

    mix = jnp.zeros_like(x)
    if cfg.has_attn:
        q, k, v = L.qkv_project(cfg, lp["attn"], h)
        q = c(q, "act_q")
        cos_q, sin_q = L.rope_freqs(cfg, q_pos)
        q = L.apply_rope(cfg, q, cos_q, sin_q)
        int8_kv = cfg.kv_cache_dtype == "int8"
        if decode:
            assert cache is not None
            kc, vc = cache["k"], cache["v"]          # (B,W,KV,hd) — attn layout
            B = kc.shape[0]
            W = kc.shape[1]
            rows = jnp.arange(B)
            slot = q_pos[:, 0] % W                   # per-sequence positions
            cos_k, sin_k = L.rope_freqs(cfg, q_pos)
            k = L.apply_rope(cfg, k, cos_k, sin_k)
            if int8_kv:
                kq, ks = L.quantize_kv(k)
                vq, vs = L.quantize_kv(v)
                kc = kc.at[rows, slot].set(kq[:, 0])
                vc = vc.at[rows, slot].set(vq[:, 0])
                ksc = cache["k_scale"].at[rows, slot].set(ks[:, 0])
                vsc = cache["v_scale"].at[rows, slot].set(vs[:, 0])
                new_cache.update(k=kc, v=vc, k_scale=ksc, v_scale=vsc)
                k_read = L.dequantize_kv(kc, ksc, q.dtype)
                v_read = L.dequantize_kv(vc, vsc, q.dtype)
            else:
                kc = kc.at[rows, slot].set(k[:, 0])
                vc = vc.at[rows, slot].set(v[:, 0])
                new_cache["k"], new_cache["v"] = kc, vc
                k_read, v_read = kc, vc
            o = L.attention(cfg, q, k_read, v_read, q_pos, k_pos, causal=True,
                            window=window)
        else:
            cos_k, sin_k = L.rope_freqs(cfg, q_pos)
            k = L.apply_rope(cfg, k, cos_k, sin_k)
            if cache is not None:                    # prefill: write cache
                if int8_kv:
                    kq, ks = L.quantize_kv(k)
                    vq, vs = L.quantize_kv(v)
                    new_cache["k"] = jax.lax.dynamic_update_slice(
                        cache["k"], kq, (0, 0, 0, 0))
                    new_cache["v"] = jax.lax.dynamic_update_slice(
                        cache["v"], vq, (0, 0, 0, 0))
                    new_cache["k_scale"] = jax.lax.dynamic_update_slice(
                        cache["k_scale"], ks, (0, 0, 0))
                    new_cache["v_scale"] = jax.lax.dynamic_update_slice(
                        cache["v_scale"], vs, (0, 0, 0))
                else:
                    new_cache["k"] = jax.lax.dynamic_update_slice(
                        cache["k"], k, (0, 0, 0, 0))
                    new_cache["v"] = jax.lax.dynamic_update_slice(
                        cache["v"], v, (0, 0, 0, 0))
            o = L.attention(cfg, q, k, v, q_pos, q_pos, causal=True, window=window)
        mix = mix + L.attn_out(lp["attn"], c(o, "act_q"))

    if cfg.has_ssm:
        conv_cache = cache.get("conv") if cache else None
        ssd_state = cache.get("ssd") if cache else None
        y, (ncv, nst) = S.ssm_block(cfg, lp["ssm"], h, conv_cache=conv_cache,
                                    ssd_state=ssd_state, decode=decode)
        if cache is not None:
            new_cache["conv"], new_cache["ssd"] = ncv, nst
        if cfg.has_attn:                             # hymba: fuse parallel heads
            mix = 0.5 * (mix + y)
        else:
            mix = y

    if cfg.parallel_block and cfg.d_ff > 0:          # command-r style
        mlp_y = L.mlp_apply(cfg, lp["mlp"], h)
        return x + mix + mlp_y, (new_cache or None), aux

    x = x + mix

    if cfg.is_encdec:                                # cross attention
        hc = L.norm_apply(cfg, lp["norm_cross"], x)
        cp = lp["cross"]
        q = jnp.einsum("bsd,dhk->bshk", hc, cp["wq"])
        if cfg.attn_bias:
            q = q + cp["bq"]
        if decode or enc_out is None:
            ck_t, cv_t = cache["cross_k"], cache["cross_v"]   # (B,Se,KV,hd)
            new_cache["cross_k"], new_cache["cross_v"] = ck_t, cv_t
        else:
            ck_t = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wk"])
            cv_t = jnp.einsum("bsd,dhk->bshk", enc_out, cp["wv"])
            if cfg.attn_bias:
                ck_t = ck_t + cp["bk"]
                cv_t = cv_t + cp["bv"]
            if cache is not None:
                new_cache["cross_k"] = ck_t
                new_cache["cross_v"] = cv_t
        e_pos = jnp.arange(ck_t.shape[1], dtype=jnp.int32)
        o = L.attention(cfg, q, ck_t, cv_t, q_pos, e_pos, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", o, cp["wo"])

    if cfg.d_ff > 0:
        h2 = L.norm_apply(cfg, lp["norm2"], x)
        if cfg.is_moe:
            y, a = L.moe_apply(cfg, lp["moe"], h2, ctx=ctx)
            aux = aux + a
        else:
            y = L.mlp_apply(cfg, lp["mlp"], c(h2, "resid"))
        x = x + y

    return c(x, "resid"), (new_cache or None), aux


# --- encoder (whisper) ------------------------------------------------------------

def encoder_block(cfg: ModelConfig, lp: Params, x: jax.Array, ctx=None) -> jax.Array:
    c = _cf(ctx)
    h = L.norm_apply(cfg, lp["norm1"], x)
    pos = jnp.arange(x.shape[1], dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"])
    if cfg.attn_bias:
        q = q + lp["attn"]["bq"]
        k = k + lp["attn"]["bk"]
        v = v + lp["attn"]["bv"]
    o = L.attention(cfg, q, k, v, pos, pos, causal=False)
    x = x + L.attn_out(lp["attn"], o)
    h2 = L.norm_apply(cfg, lp["norm2"], x)
    gelu_cfg = cfg  # whisper mlp: gelu non-gated handled by cfg.mlp_act
    x = x + L.mlp_apply(gelu_cfg, lp["mlp"], h2)
    return c(x, "resid")


def encode(cfg: ModelConfig, params: Params, frames: jax.Array,
           remat: bool = False, ctx=None) -> jax.Array:
    """frames: (B, enc_seq, D) stubbed conv-frontend output."""
    pos = sinusoid_pos(jnp.arange(frames.shape[1], dtype=jnp.int32), cfg.d_model)
    x = frames + pos[None].astype(frames.dtype)

    def body(x, lp):
        return encoder_block(cfg, maybe_dequant(lp, x.dtype), x, ctx=ctx), None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.norm_apply(cfg, params["enc_norm"], x)


# --- full trunk --------------------------------------------------------------------

def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            img_embeds: Optional[jax.Array] = None,
            audio_frames: Optional[jax.Array] = None,
            window: Optional[int] = None,
            remat: bool = False,
            remat_policy: Optional[str] = None,
            ctx=None) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward (train / no-cache prefill).

    Returns (hidden (B,S,D), aux_loss).
    """
    c = _cf(ctx)
    x = embed_tokens(cfg, params, tokens)
    if cfg.num_img_tokens > 0:
        assert img_embeds is not None
        pe = jnp.einsum("bnv,vd->bnd", img_embeds,
                maybe_dequant(params["img_proj"], x.dtype)).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    enc_out = None
    if cfg.is_encdec:
        assert audio_frames is not None
        enc_out = encode(cfg, params, audio_frames, remat=remat, ctx=ctx)
        x = x + sinusoid_pos(jnp.arange(x.shape[1], dtype=jnp.int32),
                             cfg.d_model)[None].astype(x.dtype)
    x = c(x, "resid")
    q_pos = jnp.arange(x.shape[1], dtype=jnp.int32)

    def body(carry, lp):
        x, aux = carry
        lp = maybe_dequant(lp, x.dtype)
        x, _, a = decoder_block(cfg, lp, x, q_pos=q_pos, window=window,
                                enc_out=enc_out, ctx=ctx)
        return (x, aux + a), None

    if remat:
        policy = None
        if remat_policy == "dots":
            policy = jax.checkpoint_policies.checkpoint_dots
        elif remat_policy == "dots_no_batch":
            policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        body = jax.checkpoint(body, prevent_cse=False, policy=policy)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    x = L.norm_apply(cfg, params["final_norm"], x)
    return x, aux


def lm_logits(cfg: ModelConfig, params: Params, hidden: jax.Array, ctx=None) -> jax.Array:
    c = _cf(ctx)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    if isinstance(head, dict):
        head = maybe_dequant(head, hidden.dtype)
    if cfg.tie_embeddings:
        head = head.T
    return c(jnp.einsum("bsd,dv->bsv", hidden, head), "logits")


def classify(cfg: ModelConfig, params: Params, hidden: jax.Array) -> jax.Array:
    """CQ-specific classifier head (SurveilEdge cascade): mean-pool -> linear."""
    pooled = jnp.mean(hidden.astype(jnp.float32), axis=1)
    head = maybe_dequant(params["cls_head"], jnp.float32)
    w, b = head["w"], head["b"]
    return pooled @ w.astype(jnp.float32) + b.astype(jnp.float32)


# --- caches -------------------------------------------------------------------------

def make_cache(cfg: ModelConfig, batch: int, cache_len: int,
               dtype=jnp.float32, abstract: bool = False) -> Cache:
    """Build a (layer-stacked) decode cache; ``abstract`` -> ShapeDtypeStructs."""
    Lc, KV, hd = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim

    def mk(shape, dt):
        if abstract:
            return jax.ShapeDtypeStruct(shape, dt)
        return jnp.zeros(shape, dt)

    # per-sequence positions/validity: continuous batching admits sequences
    # with different prefix lengths into one decode batch
    cache: Cache = {"pos": mk((batch,), jnp.int32),
                    "kpos": mk((batch, cache_len), jnp.int32)}
    per: Cache = {}
    if cfg.has_attn:
        # attention-native layout (B,S,KV,hd): no transposes on the hot path
        kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dtype
        per["k"] = mk((Lc, batch, cache_len, KV, hd), kv_dt)
        per["v"] = mk((Lc, batch, cache_len, KV, hd), kv_dt)
        if cfg.kv_cache_dtype == "int8":
            # per-(token, kv-head) dynamic scales
            per["k_scale"] = mk((Lc, batch, cache_len, KV), jnp.float32)
            per["v_scale"] = mk((Lc, batch, cache_len, KV), jnp.float32)
    if cfg.has_ssm:
        W, d_in = cfg.ssm_conv, cfg.ssm_d_inner
        GN = cfg.ssm_ngroups * cfg.ssm_state
        per["conv"] = {
            "x": mk((Lc, batch, W - 1, d_in), dtype),
            "b": mk((Lc, batch, W - 1, GN), dtype),
            "c": mk((Lc, batch, W - 1, GN), dtype),
        }
        per["ssd"] = mk((Lc, batch, cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state),
                        jnp.float32)
    if cfg.is_encdec:
        per["cross_k"] = mk((Lc, batch, cfg.enc_seq, KV, hd), dtype)
        per["cross_v"] = mk((Lc, batch, cfg.enc_seq, KV, hd), dtype)
    cache["layers"] = per
    if not abstract and cfg.has_attn:
        cache["kpos"] = jnp.full((batch, cache_len), -1, jnp.int32)
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Cache,
                token: jax.Array, *, window: Optional[int] = None,
                ctx=None) -> Tuple[jax.Array, Cache]:
    """One-token decode.  token: (B,) int32.  Returns (logits (B,V), new cache).

    ``cache['pos']`` is per-sequence (B,): slots may sit at different
    positions (continuous batching)."""
    c = _cf(ctx)
    pos = cache["pos"]                                   # (B,)
    B = token.shape[0]
    x = embed_tokens(cfg, params, token[:, None])
    if cfg.is_encdec:
        x = x + sinusoid_pos(pos.astype(jnp.int32),
                             cfg.d_model)[:, None].astype(x.dtype)
    x = c(x, "resid")
    q_pos = pos[:, None].astype(jnp.int32)               # (B,1)

    kpos = cache["kpos"]                                 # (B, W)
    if cfg.has_attn:
        cache_len = kpos.shape[1]
        kpos = kpos.at[jnp.arange(B), pos % cache_len].set(pos)

    def body(x, xs):
        lp, cslice = xs
        lp = maybe_dequant(lp, x.dtype)
        x, ncache, _ = decoder_block(cfg, lp, x, q_pos=q_pos, k_pos=kpos,
                                     cache=cslice, decode=True, window=window,
                                     ctx=ctx)
        return x, ncache

    x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    x = L.norm_apply(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x, ctx=ctx)[:, 0]
    new_cache = {"pos": pos + 1, "kpos": kpos, "layers": new_layer_cache}
    return logits, new_cache


def prefill(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            cache_len: Optional[int] = None,
            audio_frames: Optional[jax.Array] = None,
            img_embeds: Optional[jax.Array] = None,
            window: Optional[int] = None,
            ctx=None) -> Tuple[jax.Array, Cache]:
    """Full-sequence forward that also writes the decode cache.

    Returns (last-position logits (B,V), cache ready for decode_step).
    """
    c = _cf(ctx)
    B, Sq = tokens.shape
    x = embed_tokens(cfg, params, tokens)
    if cfg.num_img_tokens > 0:
        pe = jnp.einsum("bnv,vd->bnd", img_embeds,
                maybe_dequant(params["img_proj"], x.dtype)).astype(x.dtype)
        x = jnp.concatenate([pe, x], axis=1)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(cfg, params, audio_frames, ctx=ctx)
        x = x + sinusoid_pos(jnp.arange(x.shape[1], dtype=jnp.int32),
                             cfg.d_model)[None].astype(x.dtype)
    S_tot = x.shape[1]
    # the cache must hold the full prefix incl. any image-token prefix
    # (callers specify cache_len in text positions)
    if cache_len is not None and cfg.num_img_tokens:
        cache_len += cfg.num_img_tokens
    cache_len = max(cache_len or S_tot, S_tot)
    q_pos = jnp.arange(S_tot, dtype=jnp.int32)
    cache = make_cache(cfg, B, cache_len, dtype=x.dtype)
    kpos = jnp.broadcast_to(
        jnp.where(jnp.arange(cache_len) < S_tot,
                  jnp.arange(cache_len, dtype=jnp.int32), -1),
        (B, cache_len))

    def body(x, xs):
        lp, cslice = xs
        lp = maybe_dequant(lp, x.dtype)
        x, ncache, _ = decoder_block(cfg, lp, x, q_pos=q_pos, cache=cslice,
                                     decode=False, window=window,
                                     enc_out=enc_out, ctx=ctx)
        return x, ncache

    x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
    x = L.norm_apply(cfg, params["final_norm"], x)
    logits = lm_logits(cfg, params, x[:, -1:], ctx=ctx)[:, 0]
    return logits, {"pos": jnp.full((B,), S_tot, jnp.int32), "kpos": kpos,
                    "layers": new_layer_cache}
