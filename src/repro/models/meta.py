"""Parameter metadata: one source of truth for shapes, init, and sharding.

Every parameter leaf is declared once as a :class:`ParamMeta` carrying its
shape, *logical axis names*, and init rule.  ``init_params`` materializes
arrays from the metadata; ``repro.distributed.sharding`` maps logical axes to
mesh axes.  This mirrors the MaxText "logical axis rules" design and
guarantees the init tree and the sharding tree can never drift apart.

Layer-stack parameters carry a leading ``stack`` axis of size ``num_layers``
so the trunk can be evaluated with one ``lax.scan`` regardless of depth.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig

# Logical axis vocabulary -----------------------------------------------------
# vocab, embed, heads, kv_heads, head_dim, mlp, experts, ssm_inner, ssm_heads,
# ssm_state, groups, conv_w, stack, classes, vit
STACK = "stack"


@dataclasses.dataclass(frozen=True)
class ParamMeta:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | scaled | zeros | ones | a_log | dt_bias
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


Tree = Dict[str, object]


def _attn_meta(cfg: ModelConfig, stacked: int, cross: bool = False) -> Tree:
    D, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    pre = (stacked,) if stacked else ()
    preax = (STACK,) if stacked else ()
    out_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    t: Tree = {
        "wq": ParamMeta(pre + (D, H, hd), preax + ("embed", "heads", "head_dim")),
        "wk": ParamMeta(pre + (D, KV, hd), preax + ("embed", "kv_heads", "head_dim")),
        "wv": ParamMeta(pre + (D, KV, hd), preax + ("embed", "kv_heads", "head_dim")),
        "wo": ParamMeta(pre + (H, hd, D), preax + ("heads", "head_dim", "embed"),
                        init="normal", scale=out_scale),
    }
    if cfg.attn_bias:
        t["bq"] = ParamMeta(pre + (H, hd), preax + ("heads", "head_dim"), init="zeros")
        t["bk"] = ParamMeta(pre + (KV, hd), preax + ("kv_heads", "head_dim"), init="zeros")
        t["bv"] = ParamMeta(pre + (KV, hd), preax + ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm and not cross:
        t["q_norm"] = ParamMeta(pre + (hd,), preax + ("head_dim",), init="ones")
        t["k_norm"] = ParamMeta(pre + (hd,), preax + ("head_dim",), init="ones")
    return t


def _norm_meta(cfg: ModelConfig, stacked: int, dim: Optional[int] = None) -> Tree:
    D = dim or cfg.d_model
    pre = (stacked,) if stacked else ()
    preax = (STACK,) if stacked else ()
    t: Tree = {"scale": ParamMeta(pre + (D,), preax + ("embed",), init="ones")}
    if cfg.norm_type == "layernorm":
        t["bias"] = ParamMeta(pre + (D,), preax + ("embed",), init="zeros")
    return t


def _mlp_meta(cfg: ModelConfig, stacked: int) -> Tree:
    D, F = cfg.d_model, cfg.d_ff
    pre = (stacked,) if stacked else ()
    preax = (STACK,) if stacked else ()
    out_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    t: Tree = {
        "wi": ParamMeta(pre + (D, F), preax + ("embed", "mlp")),
        "wo": ParamMeta(pre + (F, D), preax + ("mlp", "embed"), scale=out_scale),
    }
    if cfg.mlp_act == "silu":
        t["wg"] = ParamMeta(pre + (D, F), preax + ("embed", "mlp"))
    return t


def _moe_meta(cfg: ModelConfig, stacked: int) -> Tree:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    pre = (stacked,) if stacked else ()
    preax = (STACK,) if stacked else ()
    out_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    t: Tree = {
        "router": ParamMeta(pre + (D, E), preax + ("embed", None)),
        "wi": ParamMeta(pre + (E, D, F), preax + ("experts", "embed", "mlp")),
        "wo": ParamMeta(pre + (E, F, D), preax + ("experts", "mlp", "embed"),
                        scale=out_scale),
    }
    if cfg.mlp_act == "silu":
        t["wg"] = ParamMeta(pre + (E, D, F), preax + ("experts", "embed", "mlp"))
    return t


def _ssm_meta(cfg: ModelConfig, stacked: int) -> Tree:
    D = cfg.d_model
    d_in = cfg.ssm_d_inner
    nh, G, N, W = cfg.ssm_heads, cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_conv
    pre = (stacked,) if stacked else ()
    preax = (STACK,) if stacked else ()
    out_scale = 0.02 / math.sqrt(2 * max(cfg.num_layers, 1))
    return {
        "wz": ParamMeta(pre + (D, d_in), preax + ("embed", "ssm_inner")),
        "wx": ParamMeta(pre + (D, d_in), preax + ("embed", "ssm_inner")),
        "wb": ParamMeta(pre + (D, G, N), preax + ("embed", "groups", "ssm_state")),
        "wc": ParamMeta(pre + (D, G, N), preax + ("embed", "groups", "ssm_state")),
        "wdt": ParamMeta(pre + (D, nh), preax + ("embed", "ssm_heads")),
        "conv_x": ParamMeta(pre + (W, d_in), preax + ("conv_w", "ssm_inner")),
        "conv_b": ParamMeta(pre + (W, G * N), preax + ("conv_w", None)),
        "conv_c": ParamMeta(pre + (W, G * N), preax + ("conv_w", None)),
        "a_log": ParamMeta(pre + (nh,), preax + ("ssm_heads",), init="a_log"),
        "d_skip": ParamMeta(pre + (nh,), preax + ("ssm_heads",), init="ones"),
        "dt_bias": ParamMeta(pre + (nh,), preax + ("ssm_heads",), init="dt_bias"),
        "gate_norm": ParamMeta(pre + (d_in,), preax + ("ssm_inner",), init="ones"),
        "wo": ParamMeta(pre + (d_in, D), preax + ("ssm_inner", "embed"),
                        scale=out_scale),
    }


def layer_meta(cfg: ModelConfig) -> Tree:
    """Metadata for the (stacked) decoder trunk layer."""
    L = cfg.num_layers
    t: Tree = {"norm1": _norm_meta(cfg, L)}
    if cfg.has_attn:
        t["attn"] = _attn_meta(cfg, L)
    if cfg.has_ssm:
        t["ssm"] = _ssm_meta(cfg, L)
    if cfg.is_encdec:  # cross attention in decoder layers
        t["cross"] = _attn_meta(cfg, L, cross=True)
        t["norm_cross"] = _norm_meta(cfg, L)
    if cfg.d_ff > 0:
        t["norm2"] = _norm_meta(cfg, L)
        t["moe" if cfg.is_moe else "mlp"] = (
            _moe_meta(cfg, L) if cfg.is_moe else _mlp_meta(cfg, L))
    return t


def encoder_layer_meta(cfg: ModelConfig) -> Tree:
    L = cfg.num_enc_layers
    return {
        "norm1": _norm_meta(cfg, L),
        "attn": _attn_meta(cfg, L),
        "norm2": _norm_meta(cfg, L),
        "mlp": _mlp_meta(cfg, L),
    }


def model_meta(cfg: ModelConfig) -> Tree:
    """Full parameter tree metadata for one model."""
    D, V = cfg.d_model, cfg.vocab_size
    t: Tree = {
        "embed": ParamMeta((V, D), ("vocab", "embed"), scale=1.0 / math.sqrt(D)),
        "layers": layer_meta(cfg),
        "final_norm": _norm_meta(cfg, 0),
        "cls_head": {
            "w": ParamMeta((D, cfg.num_query_classes), ("embed", None)),
            "b": ParamMeta((cfg.num_query_classes,), (None,), init="zeros"),
        },
    }
    if not cfg.tie_embeddings:
        t["lm_head"] = ParamMeta((D, V), ("embed", "vocab"))
    if cfg.is_encdec:
        t["enc_layers"] = encoder_layer_meta(cfg)
        t["enc_norm"] = _norm_meta(cfg, 0)
    if cfg.num_img_tokens > 0:
        t["img_proj"] = ParamMeta((1024, D), ("vit", "embed"))
    return t


# --- materialization ---------------------------------------------------------

def _init_leaf(meta: ParamMeta, key: jax.Array, dtype) -> jax.Array:
    if meta.init == "zeros":
        return jnp.zeros(meta.shape, dtype)
    if meta.init == "ones":
        return jnp.ones(meta.shape, dtype)
    if meta.init == "a_log":
        # A in [1, 16) -> a_log = log(A); S4/Mamba convention A = -exp(a_log)
        u = jax.random.uniform(key, meta.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if meta.init == "dt_bias":
        # dt ~ logU[1e-3, 1e-1]; bias = softplus^{-1}(dt)
        u = jax.random.uniform(key, meta.shape, jnp.float32)
        dt = jnp.exp(u * (math.log(1e-1) - math.log(1e-3)) + math.log(1e-3))
        return jnp.log(jnp.expm1(dt)).astype(dtype)
    return (jax.random.normal(key, meta.shape, jnp.float32) * meta.scale).astype(dtype)


def init_params(cfg: ModelConfig, key: jax.Array, dtype=jnp.float32) -> Tree:
    metas, treedef = jax.tree.flatten(
        model_meta(cfg), is_leaf=lambda x: isinstance(x, ParamMeta))
    keys = jax.random.split(key, len(metas))
    leaves = [_init_leaf(m, k, dtype) for m, k in zip(metas, keys)]
    return jax.tree.unflatten(treedef, leaves)


def abstract_params(cfg: ModelConfig, dtype=jnp.float32) -> Tree:
    """ShapeDtypeStruct tree (no allocation) — used by the dry-run."""
    return jax.tree.map(
        lambda m: jax.ShapeDtypeStruct(m.shape, dtype),
        model_meta(cfg), is_leaf=lambda x: isinstance(x, ParamMeta))


def param_count(cfg: ModelConfig) -> int:
    metas = jax.tree.leaves(model_meta(cfg),
                            is_leaf=lambda x: isinstance(x, ParamMeta))
    return int(sum(np.prod(m.shape) for m in metas))
