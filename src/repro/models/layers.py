"""Core transformer layers: norms, RoPE, GQA attention, MLP, MoE.

All functions are pure and shape-polymorphic; they never allocate parameters
(see ``repro.models.meta``).  Attention is computed in query chunks so the
S x S score matrix is never materialized — a requirement for the 32k-prefill
input shape on the production mesh.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

NEG_INF = -1e30


# --- norms --------------------------------------------------------------------

def norm_apply(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    """RMSNorm or LayerNorm depending on config; computed in f32."""
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# --- rotary embeddings ----------------------------------------------------------

def rope_freqs(cfg: ModelConfig, positions: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables, shape (..., rot_dim/2).  positions: int32 (...,)."""
    rot = cfg.head_dim if cfg.rope_style == "neox" else cfg.head_dim // 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., rot/2)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(cfg: ModelConfig, x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B,S,H,hd); cos/sin: (B?,S,rot/2) broadcast over heads.

    'neox'  — rotate the full head_dim, half-split layout.
    '2d'    — (chatglm) rotate only the first half of head_dim, interleaved
              pair layout; second half passes through.
    """
    if cfg.rope_style == "none":
        return x
    cos = cos[..., None, :]  # (B?,S,1,rot/2)
    sin = sin[..., None, :]
    if cfg.rope_style == "neox":
        half = x.shape[-1] // 2
        x1, x2 = x[..., :half], x[..., half:]
        r1 = x1 * cos - x2 * sin
        r2 = x2 * cos + x1 * sin
        return jnp.concatenate([r1, r2], axis=-1).astype(x.dtype)
    # '2d': interleaved pairs over the first half
    rot = x.shape[-1] // 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1 = xr[..., 0::2]
    x2 = xr[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    inter = jnp.stack([r1, r2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([inter, xp], axis=-1).astype(x.dtype)


# --- attention -----------------------------------------------------------------

def qkv_project(cfg: ModelConfig, p, x: jax.Array):
    """x (B,S,D) -> q (B,S,H,hd), k,v (B,S,KV,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.attn_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if cfg.qk_norm and "q_norm" in p:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def _scores_to_probs(scores: jax.Array, softcap: float) -> jax.Array:
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(m))
    return e / jnp.sum(e, axis=-1, keepdims=True)


def attention(cfg: ModelConfig,
              q: jax.Array, k: jax.Array, v: jax.Array,
              q_pos: jax.Array, k_pos: jax.Array,
              causal: bool = True,
              window: Optional[int] = None,
              chunk: int = 512) -> jax.Array:
    """Chunked GQA attention.

    q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd); q_pos (Sq,) or (B,Sq), k_pos (Sk,)
    or (B,Sk) absolute positions (k_pos may contain -1 for unwritten cache
    slots; per-batch positions support continuous-batching decode).
    Returns (B,Sq,H,hd).  Scans over query chunks so peak memory is
    O(B*H*chunk*Sk) instead of O(B*H*Sq*Sk).
    """
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / math.sqrt(hd)
    kg = k  # (B,Sk,KV,hd)
    vg = v
    if q_pos.ndim == 1:
        q_pos = jnp.broadcast_to(q_pos[None], (B, Sq))
    if k_pos.ndim == 1:
        k_pos = jnp.broadcast_to(k_pos[None], (B, Sk))

    # fused Pallas path (TPU target): full-causal multi-token attention with
    # contiguous positions; everything else uses the chunked XLA path.
    if (cfg.attn_impl == "flash" and Sq > 1 and causal and window is None
            and Sq == k.shape[1]):
        from repro.kernels import ops as KOPS
        o = KOPS.flash_attention(q.transpose(0, 2, 1, 3),
                                 k.transpose(0, 2, 1, 3),
                                 v.transpose(0, 2, 1, 3), causal=True)
        return o.transpose(0, 2, 1, 3)

    # f32 accumulation for multi-token passes (MXU-native on TPU).  For
    # single-token decode the XLA-CPU lowering would materialize a full f32
    # convert of the KV cache per layer; dot in the cache dtype there and
    # do the softmax in f32 (scores are cache-length, not cache-sized).
    acc = jnp.float32 if Sq > 1 else q.dtype

    def block(qc: jax.Array, qp: jax.Array) -> jax.Array:
        # qc: (B,c,H,hd) -> (B,c,KV,G,hd); qp: (B,c)
        c = qc.shape[1]
        qr = qc.reshape(B, c, KV, G, hd)
        s = jnp.einsum("bckgh,bskh->bckgs", qr, kg,
                       preferred_element_type=acc).astype(jnp.float32) * scale
        mask = (k_pos[:, None, :] >= 0)                  # (B,1,Sk)
        if causal:
            mask = mask & (k_pos[:, None, :] <= qp[:, :, None])
        if window is not None:
            mask = mask & (k_pos[:, None, :] > qp[:, :, None] - window)
        s = jnp.where(mask[:, :, None, None, :], s, NEG_INF)
        pr = _scores_to_probs(s, cfg.logit_softcap).astype(q.dtype)
        o = jnp.einsum("bckgs,bskh->bckgh", pr, vg,
                       preferred_element_type=acc)
        return o.reshape(B, c, H, hd).astype(q.dtype)

    if Sq <= chunk:
        return block(q, q_pos)
    if Sq % chunk:  # pick the largest divisor of Sq not exceeding `chunk`
        chunk = max(d for d in range(1, chunk + 1) if Sq % d == 0)
        if chunk == 1:
            return block(q, q_pos)
    nc = Sq // chunk
    qs = q.reshape(B, nc, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    ps = q_pos.reshape(B, nc, chunk).transpose(1, 0, 2)
    out = jax.lax.map(lambda args: block(*args), (qs, ps))
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Sq, H, hd)


def attn_out(p, o: jax.Array) -> jax.Array:
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# --- int8 KV-cache quantization ---------------------------------------------------

def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """(B,S,KV,hd) -> (int8 values, (B,S,KV) f32 scales). Symmetric per-token
    per-kv-head quantization; halves decode HBM cache traffic on TPU."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None].astype(jnp.float32)
            ).astype(dtype)


# --- MLP -----------------------------------------------------------------------

def mlp_apply(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_act == "silu":
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# --- MoE (sort-based, dropped-token, expert-parallel friendly) ------------------

def moe_apply(cfg: ModelConfig, p, x: jax.Array,
              ctx=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (y, aux_loss).

    Batch-grouped sort-based dispatch with per-expert capacity: every batch
    row dispatches its own tokens (argsort and scatter stay *local* to the
    data shard — no global sort), then the grouped expert einsum contracts
    data-sharded token buffers against model-sharded expert weights, which
    is where the all-to-all happens.  Compute is O(topk * T * D * F)
    (active params only) — faithful to deployed MoE serving.
    """
    c = ctx if ctx is not None else (lambda a, n: a)
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.top_k
    TK = S * K

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(probs, K)                   # (B,S,K)
    topw = topw / jnp.sum(topw, axis=-1, keepdims=True)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=(0, 1))                      # (E,)
    ce = jnp.mean(jnp.sum(jax.nn.one_hot(topi, E, dtype=jnp.float32), axis=2),
                  axis=(0, 1))
    aux = E * jnp.sum(me * ce) / K

    cap = int(math.ceil(cfg.capacity_factor * TK / E))
    cap = max(8, -(-cap // 8) * 8)                         # round up to 8

    eflat = topi.reshape(B, TK)                            # per-row dispatch
    wflat = topw.reshape(B, TK)
    tflat = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S, dtype=jnp.int32), K)[None], (B, TK))
    order = jnp.argsort(eflat, axis=1)
    es = jnp.take_along_axis(eflat, order, axis=1)
    ws = jnp.take_along_axis(wflat, order, axis=1)
    ts = jnp.take_along_axis(tflat, order, axis=1)
    # position within expert group = idx - first occurrence of that expert id
    first = jax.vmap(lambda a: jnp.searchsorted(a, a, side="left"))(es)
    pos = jnp.arange(TK, dtype=jnp.int32)[None] - first.astype(jnp.int32)
    keep = pos < cap
    dest = jnp.where(keep, es * cap + pos, E * cap)        # drop slot

    # Index-based dispatch (perf-iteration result, EXPERIMENTS.md §Perf):
    # scatter s32 slot->token maps (tiny) and gather the activations ONCE at
    # the destination.  Avoids materializing or shipping the K-duplicated
    # (B, S*K, D) flat tensors — each token's D-vector crosses the expert
    # boundary once instead of top_k times.
    SENT = S                                               # drop sentinel
    slot_token = jax.vmap(
        lambda d, t: jnp.full((E * cap,), SENT, jnp.int32
                              ).at[d].set(t, mode="drop"))(dest, ts)
    slot_w = jax.vmap(
        lambda d, w: jnp.zeros((E * cap,), jnp.float32
                               ).at[d].set(w, mode="drop"))(
        dest, jnp.where(keep, ws, 0.0))
    valid = (slot_token < SENT)[..., None]
    eb = jax.vmap(lambda xr, st: jnp.take(xr, jnp.minimum(st, S - 1), axis=0)
                  )(x, slot_token)
    eb = jnp.where(valid, eb, 0)
    eb = c(eb.reshape(B, E, cap, D), "moe_buf")

    h = jnp.einsum("becd,edf->becf", eb, p["wi"])
    if cfg.mlp_act == "silu":
        g = jnp.einsum("becd,edf->becf", eb, p["wg"])
        h = jax.nn.silu(g) * h
    else:
        h = jax.nn.gelu(h)
    ob = c(jnp.einsum("becf,efd->becd", h, p["wo"]), "moe_buf")
    ob = ob.reshape(B, E * cap, D)

    # combine: weighted scatter-add straight from the expert buffers
    y = jax.vmap(
        lambda obr, st, wr: jnp.zeros((S, D), x.dtype).at[st].add(
            (obr * wr[:, None]).astype(x.dtype), mode="drop"))(
        ob, slot_token, slot_w)
    return y, aux.astype(jnp.float32)
