"""Mamba-2 (SSD — state-space duality) block, chunked for TPU.

Implements the chunked SSD algorithm of arXiv:2405.21060: within a chunk the
quadratic ("attention-like") dual form runs on the MXU; across chunks a short
`lax.scan` carries the (heads, head_dim, state) recurrent state.  Decode is a
single O(1) state update.

Shapes (per layer):
  x   (B, S, nh, hd)    inputs after in-proj + causal conv + SiLU
  dt  (B, S, nh)        softplus(dt_raw + dt_bias)
  A   (nh,)             negative reals, A = -exp(a_log)
  Bm  (B, S, G, N)      input matrix  (G groups, N = ssm_state)
  Cm  (B, S, G, N)      output matrix
State: (B, nh, hd, N)
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rms_norm


def _segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} a[..., k], -inf for j>i.

    a: (..., Q) log-decays.  Returns (..., Q, Q).
    """
    Q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)                      # (..., Q)
    diff = cs[..., :, None] - cs[..., None, :]        # cum_i - cum_j
    ii = jnp.arange(Q)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(cfg: ModelConfig,
                x: jax.Array, dt: jax.Array, A: jax.Array,
                Bm: jax.Array, Cm: jax.Array, D_skip: jax.Array,
                init_state: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,nh,hd), final_state (B,nh,hd,N))."""
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    Q = min(cfg.ssm_chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    rep = nh // G                                        # heads per group

    f32 = jnp.float32
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    a = dtf * A.astype(f32)[None, None, :]               # (B,S,nh) log-decay <= 0

    # chunk views
    xc = xf.reshape(B, nc, Q, nh, hd)
    dc = dtf.reshape(B, nc, Q, nh)
    ac = a.reshape(B, nc, Q, nh)
    Bc = Bm.astype(f32).reshape(B, nc, Q, G, N)
    Cc = Cm.astype(f32).reshape(B, nc, Q, G, N)
    Bh = jnp.repeat(Bc, rep, axis=3)                     # (B,nc,Q,nh,N)
    Ch = jnp.repeat(Cc, rep, axis=3)

    # ---- intra-chunk (quadratic dual form) ----------------------------------
    # L[i,j] = exp(sum_{j<k<=i} a_k); scores = (C_i . B_j) L_ij dt_j
    seg = _segsum(ac.transpose(0, 1, 3, 2))              # (B,nc,nh,Q,Q)
    L = jnp.exp(seg)
    cb = jnp.einsum("bnqhs,bnkhs->bnhqk", Ch, Bh)        # (B,nc,nh,Q,Q)
    W = cb * L * dc.transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bnhqk,bnkhd->bnqhd", W, xc)

    # ---- chunk-state contributions -------------------------------------------
    cum = jnp.cumsum(ac, axis=2)                         # (B,nc,Q,nh)
    last = cum[:, :, -1:, :]
    decay_to_end = jnp.exp(last - cum)                   # exp(sum_{k>j} a_k)
    # state_c = sum_j decay_to_end_j * dt_j * B_j (x) x_j   -> (B,nc,nh,hd,N)
    contrib = jnp.einsum("bnqh,bnqh,bnqhs,bnqhd->bnhds",
                         decay_to_end, dc, Bh, xc)
    chunk_decay = jnp.exp(jnp.sum(ac, axis=2))           # (B,nc,nh)

    # ---- inter-chunk recurrence (short sequential scan over nc) -------------
    s0 = (jnp.zeros((B, nh, hd, N), f32) if init_state is None
          else init_state.astype(f32))

    def step(s, inp):
        dec, con = inp                                   # (B,nh), (B,nh,hd,N)
        s_in = s
        s = s * dec[:, :, None, None] + con
        return s, s_in

    (s_fin, s_ins) = jax.lax.scan(
        step, s0, (chunk_decay.transpose(1, 0, 2), contrib.transpose(1, 0, 2, 3, 4)))
    states_in = s_ins.transpose(1, 0, 2, 3, 4)           # (B,nc,nh,hd,N) state at chunk start

    # ---- inter-chunk output: y_i += C_i . (exp(cum_i) * state_in) ------------
    y_inter = jnp.einsum("bnqhs,bnhds,bnqh->bnqhd",
                         Ch, states_in, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, nh, hd)
    y = y + xf * D_skip.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), s_fin


def ssd_reference(cfg: ModelConfig,
                  x: jax.Array, dt: jax.Array, A: jax.Array,
                  Bm: jax.Array, Cm: jax.Array, D_skip: jax.Array,
                  init_state: Optional[jax.Array] = None,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Naive per-step recurrence oracle (h' = h*exp(dt*A) + dt*B(x)x)."""
    B, S, nh, hd = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = nh // G
    f32 = jnp.float32
    s = (jnp.zeros((B, nh, hd, N), f32) if init_state is None
         else init_state.astype(f32))

    def step(s, t):
        xt = x[:, t].astype(f32)                         # (B,nh,hd)
        dtt = dt[:, t].astype(f32)                       # (B,nh)
        Bt = jnp.repeat(Bm[:, t].astype(f32), rep, axis=1)  # (B,nh,N)
        Ct = jnp.repeat(Cm[:, t].astype(f32), rep, axis=1)
        dec = jnp.exp(dtt * A.astype(f32)[None, :])
        s = s * dec[:, :, None, None] + jnp.einsum(
            "bh,bhn,bhd->bhdn", dtt, Bt, xt)
        y = jnp.einsum("bhn,bhdn->bhd", Ct, s)
        y = y + xt * D_skip.astype(f32)[None, :, None]
        return s, y

    s, ys = jax.lax.scan(step, s, jnp.arange(S))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), s


def ssd_decode_step(cfg: ModelConfig,
                    state: jax.Array,
                    x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, D_skip: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token update.  x (B,nh,hd), dt (B,nh), Bm/Cm (B,G,N), state (B,nh,hd,N)."""
    nh = x.shape[1]
    rep = nh // Bm.shape[1]
    f32 = jnp.float32
    Bt = jnp.repeat(Bm.astype(f32), rep, axis=1)
    Ct = jnp.repeat(Cm.astype(f32), rep, axis=1)
    dec = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])
    state = state.astype(f32) * dec[:, :, None, None] + jnp.einsum(
        "bh,bhn,bhd->bhdn", dt.astype(f32), Bt, x.astype(f32))
    y = jnp.einsum("bhn,bhdn->bhd", Ct, state)
    y = y + x.astype(f32) * D_skip.astype(f32)[None, :, None]
    return y.astype(x.dtype), state


# --- causal depthwise conv ------------------------------------------------------

def causal_conv(x: jax.Array, w: jax.Array,
                cache: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d.

    x: (B,S,C), w: (W,C).  cache: (B,W-1,C) previous context or None (zeros).
    Returns (y (B,S,C), new_cache (B,W-1,C)).
    """
    B, S, C = x.shape
    W = w.shape[0]
    if cache is None:
        cache = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([cache, x], axis=1)             # (B, S+W-1, C)
    y = jnp.zeros((B, S, C), jnp.float32)
    for i in range(W):                                   # W<=4: unrolled shifts
        y = y + xp[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_cache = xp[:, S:, :] if S >= W - 1 else jnp.concatenate(
        [cache[:, S:], x], axis=1)
    return y.astype(x.dtype), new_cache


def ssm_block(cfg: ModelConfig, p, x: jax.Array,
              conv_cache=None, ssd_state=None, decode: bool = False):
    """Full Mamba-2 mixer: in-proj -> conv -> SSD -> gated RMSNorm -> out-proj.

    x: (B,S,D).  Returns (y (B,S,D), (new_conv_cache, new_ssd_state)).
    conv_cache: dict(x=,b=,c=) each (B,W-1,*) or None; ssd_state (B,nh,hd,N) or None.
    """
    B, S, D = x.shape
    nh, hd, G, N = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_ngroups, cfg.ssm_state
    d_in = cfg.ssm_d_inner

    z = jnp.einsum("bsd,de->bse", x, p["wz"])
    xin = jnp.einsum("bsd,de->bse", x, p["wx"])
    bin_ = jnp.einsum("bsd,dgn->bsgn", x, p["wb"]).reshape(B, S, G * N)
    cin = jnp.einsum("bsd,dgn->bsgn", x, p["wc"]).reshape(B, S, G * N)
    dt_raw = jnp.einsum("bsd,dh->bsh", x, p["wdt"])

    cc = conv_cache or {}
    xc, ncx = causal_conv(xin, p["conv_x"], cc.get("x"))
    bc, ncb = causal_conv(bin_, p["conv_b"], cc.get("b"))
    cc_, ncc = causal_conv(cin, p["conv_c"], cc.get("c"))
    xc = jax.nn.silu(xc)
    bc = jax.nn.silu(bc)
    cc_ = jax.nn.silu(cc_)

    xh = xc.reshape(B, S, nh, hd)
    Bm = bc.reshape(B, S, G, N)
    Cm = cc_.reshape(B, S, G, N)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    if decode:
        y1, new_state = ssd_decode_step(
            cfg, ssd_state, xh[:, 0], dt[:, 0], A, Bm[:, 0], Cm[:, 0], p["d_skip"])
        y = y1[:, None]
    else:
        y, new_state = ssd_chunked(cfg, xh, dt.astype(xh.dtype), A, Bm, Cm,
                                   p["d_skip"], init_state=ssd_state)
    y = y.reshape(B, S, d_in)
    # gated RMSNorm (mamba2): norm(y) * silu(z)
    yn = rms_norm(y, p["gate_norm"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", yn, p["wo"])
    return out, ({"x": ncx, "b": ncb, "c": ncc}, new_state)
