"""Connected-component labeling + bounding boxes (TPU-native contour substitute).

The paper retrieves contours with Suzuki border-following — sequential
pointer-chasing with no TPU analogue.  We use iterative min-label propagation
(a data-parallel fixpoint: every foreground pixel takes the min label of its
8-neighbourhood until convergence), which yields identical bounding boxes for
the pipeline's purpose.  See DESIGN.md §2.
"""
from __future__ import annotations

import dataclasses
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

BIG = jnp.int32(1 << 30)


def label_components(mask: jax.Array, max_iters: int = 256) -> jax.Array:
    """mask (B,H,W) {0, nonzero} -> labels (B,H,W) int32 (-1 background).

    Label of a component = min linear index of its pixels.
    """
    B, H, W = mask.shape
    fg = mask > 0
    init = jnp.where(fg, jnp.arange(H * W, dtype=jnp.int32).reshape(1, H, W),
                     BIG)

    def nb_min(lab):
        m = lab
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                if dy == 0 and dx == 0:
                    continue
                sh = jnp.roll(lab, (dy, dx), axis=(1, 2))
                if dy > 0:
                    sh = sh.at[:, :dy, :].set(BIG)
                elif dy < 0:
                    sh = sh.at[:, dy:, :].set(BIG)
                if dx > 0:
                    sh = sh.at[:, :, :dx].set(BIG)
                elif dx < 0:
                    sh = sh.at[:, :, dx:].set(BIG)
                m = jnp.minimum(m, sh)
        return jnp.where(fg, m, BIG)

    def cond(state):
        lab, changed, it = state
        return changed & (it < max_iters)

    def body(state):
        lab, _, it = state
        new = nb_min(lab)
        return new, jnp.any(new != lab), it + 1

    lab, _, _ = jax.lax.while_loop(cond, body, (init, jnp.bool_(True), 0))
    return jnp.where(fg, lab, -1)


@dataclasses.dataclass(frozen=True)
class Box:
    y0: int
    x0: int
    y1: int
    x1: int
    area: int

    @property
    def h(self) -> int:
        return self.y1 - self.y0 + 1

    @property
    def w(self) -> int:
        return self.x1 - self.x0 + 1


def extract_boxes(labels: np.ndarray, *, min_area: int = 12,
                  max_aspect: float = 6.0) -> List[Box]:
    """Host-side bbox extraction + the paper's size/aspect filtering.

    Discards detections that are too small or too elongated (disturbance /
    noise), per §IV-C.
    """
    out: List[Box] = []
    lab = np.asarray(labels)
    fg = lab >= 0
    if not fg.any():
        return out
    for lid in np.unique(lab[fg]):
        ys, xs = np.nonzero(lab == lid)
        b = Box(int(ys.min()), int(xs.min()), int(ys.max()), int(xs.max()),
                int(len(ys)))
        if b.area < min_area:
            continue
        aspect = max(b.h, b.w) / max(min(b.h, b.w), 1)
        if aspect > max_aspect:
            continue
        out.append(b)
    return out
