"""The full moving-object detection stage (paper §IV-C), end to end.

frames -> fused pixel cascade (ONE Pallas launch: framediff + dilate +
erode + foreground count) -> CCL -> filtered bounding boxes -> crops
ready for the cascade classifier.

``fused=False`` keeps the original staged chain — three separate Pallas
launches — as the differential reference; ``use_pallas=False`` drops to
the jnp oracle.  The fused path's per-camera foreground counts let
``detect`` skip the CCL fixpoint entirely on motionless ticks and skip
box extraction for motionless cameras without re-reducing the mask.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.detection import components
from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class Detection:
    box: components.Box
    crop: np.ndarray          # (ch, cw, 3) uint8-valued


def motion_mask(f0: jax.Array, f1: jax.Array, f2: jax.Array, *,
                threshold: int = 40, use_pallas: bool = True,
                fused: bool = True) -> jax.Array:
    """Eqs. 1-6: framediff + dilate + erode.  (B,H,W,3)x3 -> (B,H,W)."""
    mask, _ = ops.pixel_cascade(f0, f1, f2, threshold=threshold,
                                use_pallas=use_pallas, fused=fused)
    return mask


def detect(frames: np.ndarray, *, threshold: int = 40, crop: int = 32,
           min_area: int = 12, use_pallas: bool = True, fused: bool = True
           ) -> List[List[Detection]]:
    """frames: (3, H, W, 3) consecutive triple (or (B,3,H,W,3)).

    Returns, per batch item, the filtered detections of the middle frame.
    """
    arr = np.asarray(frames)
    if arr.ndim == 4:
        arr = arr[None]
    B = arr.shape[0]
    f0, f1, f2 = (jnp.asarray(arr[:, i]) for i in range(3))
    mask, counts = ops.pixel_cascade(f0, f1, f2, threshold=threshold,
                                     use_pallas=use_pallas, fused=fused)
    counts_np = np.asarray(counts)
    if not counts_np.any():
        # motionless tick: no foreground anywhere — skip the CCL fixpoint
        return [[] for _ in range(B)]
    labels = components.label_components(mask)
    labels_np = np.asarray(labels)
    out: List[List[Detection]] = []
    for b in range(B):
        if counts_np[b] == 0:
            out.append([])        # motionless camera: no boxes to extract
            continue
        boxes = components.extract_boxes(labels_np[b], min_area=min_area)
        dets = []
        for box in boxes:
            cy = (box.y0 + box.y1) // 2
            cx = (box.x0 + box.x1) // 2
            half = crop // 2
            y0 = np.clip(cy - half, 0, arr.shape[2] - crop)
            x0 = np.clip(cx - half, 0, arr.shape[3] - crop)
            dets.append(Detection(
                box, arr[b, 1, y0:y0 + crop, x0:x0 + crop]))
        out.append(dets)
    return out
