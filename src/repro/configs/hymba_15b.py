"""Hymba-1.5B: hybrid — parallel attention + mamba heads in every layer.
[arXiv:2411.13676]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    tie_embeddings=True,
    ssm_state=16,
    ssm_expand=2,
    ssm_headdim=64,            # 50 ssm heads
    ssm_conv=4,
    ssm_chunk=256,
    ssm_ngroups=1,
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
)
