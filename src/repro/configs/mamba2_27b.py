"""Mamba2-2.7B: attention-free SSD (state-space duality). [arXiv:2405.21060]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=64,
    d_model=2560,
    num_heads=0,               # attention-free
    num_kv_heads=1,
    head_dim=0,
    d_ff=0,                    # no MLP: mamba2 blocks only
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,            # 80 heads
    ssm_conv=4,
    ssm_chunk=256,
    ssm_ngroups=1,
    norm_type="rmsnorm",
    rope_style="none",
)
