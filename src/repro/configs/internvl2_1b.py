"""InternVL2-1B: InternViT (STUBBED — input_specs provides patch embeddings)
+ Qwen2-0.5B-family language backbone. [arXiv:2404.16821]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab_size=151655,
    num_img_tokens=256,        # stub ViT patch embeddings, projected
    attn_bias=True,
    tie_embeddings=True,
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
    rope_theta=1000000.0,
)
