"""The paper's own cascade pair, transliterated to this framework.

The paper deploys MobileNet-v2 (edge, CQ-specific) + ResNet-152 (cloud).  In
this framework the 'cloud' high-accuracy classifier is a small dense
transformer over patch tokens and the 'edge' model is its `edge_variant()` —
the cascade machinery (core/) is identical.  Used by examples and the
paper-table benchmarks; NOT part of the assigned-architecture pool.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="surveiledge-cls",
    family="dense",
    source="paper:SurveilEdge (MobileNet-v2 / ResNet-152 cascade analogue)",
    num_layers=8,
    d_model=256,
    num_heads=8,
    num_kv_heads=8,
    head_dim=32,
    d_ff=1024,
    vocab_size=4096,           # patch-token codebook
    num_query_classes=12,      # object classes (car, person, moped, ...)
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
)
