"""Whisper-large-v3: enc-dec audio transformer; conv/mel frontend STUBBED
(input_specs provides precomputed frame embeddings). [arXiv:2212.04356]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=32,             # decoder
    num_enc_layers=32,         # encoder
    enc_seq=1500,              # 30s of audio after conv frontend (stub)
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    attn_bias=True,
    tie_embeddings=True,
    mlp_act="gelu",            # non-gated GELU MLP
    norm_type="layernorm",
    rope_style="none",         # sinusoidal absolute positions
)
