"""Qwen1.5-0.5B: dense, QKV bias, MHA (kv=16). [hf:Qwen/Qwen1.5-0.5B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151936,
    attn_bias=True,            # QKV bias
    tie_embeddings=True,
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
    rope_theta=1000000.0,
)
