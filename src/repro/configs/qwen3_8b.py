"""Qwen3-8B: dense, qk_norm (per-head RMSNorm on q,k), GQA kv=8. [hf:Qwen/Qwen3-8B]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    source="hf:Qwen/Qwen3-8B",
    num_layers=36,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=12288,
    vocab_size=151936,
    qk_norm=True,
    attn_bias=False,
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
    rope_theta=1000000.0,
)
