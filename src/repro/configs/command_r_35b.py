"""c4ai-command-r-v01: 35B dense, GQA kv=8, no-bias, parallel block, LayerNorm.
[hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    source="hf:CohereForAI/c4ai-command-r-v01",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    attn_bias=False,
    parallel_block=True,       # attn & mlp computed in parallel from one norm
    tie_embeddings=True,       # command-r ties input/output embeddings
    mlp_act="silu",
    norm_type="layernorm",
    rope_style="neox",
    rope_theta=8000000.0,
)
