"""Granite-3.0-1B-A400M: 32 experts top-8 MoE.
[hf:ibm-granite/granite-3.0-1b-a400m-base]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,                  # per-expert
    vocab_size=49155,
    num_experts=32,
    top_k=8,
    tie_embeddings=True,
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="neox",
    rope_theta=10000.0,
)
