"""Assigned input shapes and ShapeDtypeStruct builders for the dry-run."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                  # 'train' | 'prefill' | 'decode'
    # decode shapes: seq_len is the KV-cache/context length, one new token.


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

# long-context decode for attention archs uses a sliding window (sub-quadratic
# requirement; see DESIGN.md §3).  SSM archs need no window.
LONG_CONTEXT_WINDOW = 8192


def shape_for(name: str) -> InputShape:
    return INPUT_SHAPES[name]


def decode_window(cfg: ModelConfig, shape: InputShape) -> Optional[int]:
    """Effective attention window for a (cfg, shape) pair."""
    if shape.name == "long_500k" and cfg.has_attn:
        return LONG_CONTEXT_WINDOW
    return cfg.sliding_window


def attn_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    w = decode_window(cfg, shape)
    if w is not None:
        return min(w, shape.seq_len)
    return shape.seq_len


def input_specs(cfg: ModelConfig, shape: InputShape,
                dtype=jnp.bfloat16) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    train:   tokens + labels (+ stub frontend embeddings)
    prefill: tokens (+ stub frontend embeddings)
    decode:  token + cache (built separately via make_cache(abstract=True))
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    s_text = S
    if cfg.num_img_tokens > 0 and shape.kind != "decode":
        s_text = S - cfg.num_img_tokens
        specs["img_embeds"] = jax.ShapeDtypeStruct((B, cfg.num_img_tokens, 1024), dtype)
    if cfg.is_encdec and shape.kind != "decode":
        specs["audio_frames"] = jax.ShapeDtypeStruct((B, cfg.enc_seq, cfg.d_model), dtype)
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
    else:  # decode
        specs["token"] = jax.ShapeDtypeStruct((B,), i32)
    return specs
