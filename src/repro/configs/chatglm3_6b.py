"""ChatGLM3-6B: dense, RoPE-2d (half-dim interleaved), extreme GQA kv=2.
[arXiv:2406.12793]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    source="arXiv:2406.12793",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    attn_bias=True,            # chatglm uses QKV bias
    mlp_act="silu",
    norm_type="rmsnorm",
    rope_style="2d",           # rotary applied to half of head_dim, interleaved
    rope_theta=10000.0,
)
