"""Architecture registry: one module per assigned architecture.

Every config cites its source (hf:/arXiv:) and is selectable by id via
``--arch <id>`` in the launchers.
"""
from __future__ import annotations

from typing import Dict, List

from repro.models.config import ModelConfig

from repro.configs.phi35_moe_42b import CONFIG as _phi35
from repro.configs.qwen15_05b import CONFIG as _qwen15
from repro.configs.mamba2_27b import CONFIG as _mamba2
from repro.configs.command_r_35b import CONFIG as _command_r
from repro.configs.whisper_large_v3 import CONFIG as _whisper
from repro.configs.hymba_15b import CONFIG as _hymba
from repro.configs.chatglm3_6b import CONFIG as _chatglm3
from repro.configs.granite_moe_1b import CONFIG as _granite
from repro.configs.qwen3_8b import CONFIG as _qwen3
from repro.configs.internvl2_1b import CONFIG as _internvl2
from repro.configs.surveiledge_cnn import CONFIG as _surveiledge

REGISTRY: Dict[str, ModelConfig] = {
    c.name: c for c in [
        _phi35, _qwen15, _mamba2, _command_r, _whisper, _hymba,
        _chatglm3, _granite, _qwen3, _internvl2, _surveiledge,
    ]
}

ASSIGNED: List[str] = [
    "phi3.5-moe-42b-a6.6b",
    "qwen1.5-0.5b",
    "mamba2-2.7b",
    "command-r-35b",
    "whisper-large-v3",
    "hymba-1.5b",
    "chatglm3-6b",
    "granite-moe-1b-a400m",
    "qwen3-8b",
    "internvl2-1b",
]


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_archs() -> List[str]:
    return list(ASSIGNED)
