"""Step factories: train_step / prefill_step / decode_step closures.

These are the functions the launcher jits with explicit in/out shardings; the
dry-run lowers exactly the same closures with ShapeDtypeStructs.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean next-token xent; logits (B,S,V) f32-cast, labels (B,S) int32."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def make_loss_fn(cfg: ModelConfig, *, remat: bool = True,
                 remat_policy=None, ctx=None):
    def loss_fn(params, batch: Dict[str, jax.Array]):
        h, aux = T.forward(
            cfg, params, batch["tokens"],
            img_embeds=batch.get("img_embeds"),
            audio_frames=batch.get("audio_frames"),
            remat=remat, remat_policy=remat_policy, ctx=ctx)
        if cfg.num_img_tokens > 0:          # loss only over text positions
            h = h[:, cfg.num_img_tokens:]
        logits = T.lm_logits(cfg, params, h, ctx=ctx)
        loss = cross_entropy(logits, batch["labels"])
        if cfg.is_moe:
            loss = loss + cfg.router_aux_coef * aux
        return loss, {"lm_loss": loss, "moe_aux": aux}
    return loss_fn


def default_microbatches(cfg: ModelConfig, global_batch: int,
                         data_shards: int) -> int:
    """Gradient-accumulation factor so per-micro activations fit HBM."""
    per_shard = max(global_batch // max(data_shards, 1), 1)
    want = 8 if cfg.param_count() > 2e9 else 4
    m = 1
    while m < want and per_shard % (m * 2) == 0:
        m *= 2
    return m


def make_train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, *,
                    remat: bool = True, microbatches: int = 1,
                    remat_policy=None, ctx=None) -> Callable:
    """train_step with optional gradient accumulation.

    ``microbatches > 1`` splits the global batch into M sequential
    microbatches (lax.scan), accumulating f32 grads — the standard way a
    256x4096-token global batch fits per-chip HBM on the production mesh.
    """
    loss_fn = make_loss_fn(cfg, remat=remat, remat_policy=remat_policy,
                           ctx=ctx)

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn, has_aux=True)(params, batch)

    def train_step(state: TrainState, batch: Dict[str, jax.Array]):
        if microbatches == 1:
            (loss, metrics), grads = grads_of(state.params, batch)
        else:
            def split(leaf):
                b = leaf.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return leaf.reshape(microbatches, b // microbatches,
                                    *leaf.shape[1:])

            micro = jax.tree.map(split, batch)
            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)

            def acc(carry, mb):
                g_acc, loss_acc, aux_acc = carry
                (loss, metrics), g = grads_of(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, loss_acc + loss,
                        aux_acc + metrics["moe_aux"]), None

            (g_sum, loss_sum, aux_sum), _ = jax.lax.scan(
                acc, (zero_g, jnp.zeros((), jnp.float32),
                      jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = loss_sum / microbatches
            metrics = {"lm_loss": loss, "moe_aux": aux_sum / microbatches}
        new_params, new_opt, opt_metrics = adamw.apply(
            opt_cfg, grads, state.opt, state.params)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, *, cache_len: Optional[int] = None,
                      window: Optional[int] = None, ctx=None) -> Callable:
    def prefill_step(params, batch: Dict[str, jax.Array]):
        return T.prefill(cfg, params, batch["tokens"],
                         cache_len=cache_len,
                         audio_frames=batch.get("audio_frames"),
                         img_embeds=batch.get("img_embeds"),
                         window=window, ctx=ctx)
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, window: Optional[int] = None,
                     ctx=None) -> Callable:
    def decode_step(params, cache, token):
        return T.decode_step(cfg, params, cache, token, window=window, ctx=ctx)
    return decode_step


def make_classify_fn(cfg: ModelConfig, ctx=None) -> Callable:
    """CQ-specific classifier forward (cascade edge/cloud models)."""
    def classify(params, batch: Dict[str, jax.Array]):
        h, _ = T.forward(cfg, params, batch["tokens"],
                         img_embeds=batch.get("img_embeds"),
                         audio_frames=batch.get("audio_frames"),
                         remat=False, ctx=ctx)
        return T.classify(cfg, params, h)
    return classify
