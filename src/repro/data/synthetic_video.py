"""Procedural surveillance-video generator (numpy, deterministic).

Replaces the paper's 170h of YouTube-live streams with reproducible synthetic
footage: each camera has a static background, a context (class mix — the
"scene"), and a periodic busy profile.  Object sprites are class-specific
textures moving linearly; ground truth (class, box) is known exactly, which
lets the benchmarks score accuracy without a human-labeled dataset.

Classes (12): 0 background-noise, 1 car, 2 person, 3 moped, 4 bus, 5 bike,
6 truck, 7 dog, 8 cart, 9 van, 10 scooter, 11 tractor.  'moped' (3) is the
paper's example query object.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

NUM_CLASSES = 12
QUERY_CLASS = 3          # moped, as in the paper
SPRITE = 16              # sprite side (pixels)
CAMERA_FIELD_W = 128     # world width (px) each camera's field of view
#                          covers on the 1-D camera chain the trajectory
#                          ground truth (scenario._track_substream) uses —
#                          matches CameraSpec.width, so object speeds in
#                          px/s mean the same thing in both worlds


def _class_texture(cls: int, size: int = SPRITE) -> np.ndarray:
    """Deterministic, distinctive texture per class: oriented gratings +
    class-coloured base — separable by a small classifier but not trivial."""
    rng = np.random.default_rng(1000 + cls)
    yy, xx = np.mgrid[0:size, 0:size]
    theta = cls * np.pi / NUM_CLASSES
    freq = 0.5 + 0.35 * (cls % 5)
    wave = np.sin(freq * (np.cos(theta) * xx + np.sin(theta) * yy))
    base = rng.integers(40, 216, size=3)
    tex = np.stack([(base[c] + 70 * wave) for c in range(3)], axis=-1)
    tex += rng.normal(0, 6, tex.shape)
    return np.clip(tex, 0, 255).astype(np.uint8)


_TEXTURES = [_class_texture(c) for c in range(NUM_CLASSES)]


@dataclasses.dataclass
class CameraSpec:
    cam_id: int
    class_mix: np.ndarray            # (NUM_CLASSES,) arrival probabilities
    busy_period_s: float = 120.0     # periodicity of busy times (paper §III-A)
    busy_phase: float = 0.0
    base_rate: float = 0.8           # objects per sampled frame, off-peak
    busy_boost: float = 3.0
    height: int = 96
    width: int = 128

    def rate_at(self, t_s: float) -> float:
        phase = 2 * np.pi * (t_s / self.busy_period_s) + self.busy_phase
        return self.base_rate * (1.0 + self.busy_boost *
                                 max(0.0, np.sin(phase)) ** 2)


def make_cameras(n: int, seed: int = 0,
                 contexts: int = 2) -> List[CameraSpec]:
    """n cameras split across `contexts` scene types (road-like vs
    plaza-like), with per-camera jitter — clusterable by K-means."""
    rng = np.random.default_rng(seed)
    cams = []
    for i in range(n):
        ctx = i % contexts
        mix = np.full(NUM_CLASSES, 0.02)
        if ctx == 0:                          # road: vehicles dominate
            mix[[1, 3, 4, 6, 9]] += [0.30, 0.16, 0.08, 0.10, 0.08]
        else:                                 # plaza: people dominate
            mix[[2, 5, 7, 10]] += [0.38, 0.12, 0.10, 0.12]
        mix += rng.uniform(0, 0.03, NUM_CLASSES)
        mix /= mix.sum()
        cams.append(CameraSpec(
            cam_id=i, class_mix=mix,
            busy_period_s=rng.uniform(90, 180),
            busy_phase=rng.uniform(0, 2 * np.pi),
            base_rate=rng.uniform(0.5, 1.2)))
    return cams


@dataclasses.dataclass
class FrameTruth:
    classes: List[int]
    boxes: List[Tuple[int, int]]             # top-left corners


def _background(cam: CameraSpec) -> np.ndarray:
    rng = np.random.default_rng(500 + cam.cam_id)
    H, W = cam.height, cam.width
    yy, xx = np.mgrid[0:H, 0:W]
    bg = 90 + 40 * np.sin(xx / rng.uniform(15, 40)) \
        + 30 * np.cos(yy / rng.uniform(10, 30))
    bg = np.stack([bg + rng.uniform(-20, 20) for _ in range(3)], axis=-1)
    return np.clip(bg, 0, 255).astype(np.uint8)


def render_triple(cam: CameraSpec, t_s: float, rng: np.random.Generator
                  ) -> Tuple[np.ndarray, FrameTruth]:
    """Three consecutive frames (for frame differencing) + middle-frame truth.

    Objects move ~3 px/frame; sensor noise ~N(0, 2).
    """
    H, W = cam.height, cam.width
    bg = _background(cam)
    n_obj = rng.poisson(cam.rate_at(t_s))
    classes, boxes = [], []
    frames = np.stack([bg.copy() for _ in range(3)]).astype(np.int32)
    for _ in range(int(n_obj)):
        cls = int(rng.choice(NUM_CLASSES, p=cam.class_mix))
        y = int(rng.integers(0, H - SPRITE))
        x = int(rng.integers(4, W - SPRITE - 4))
        vy, vx = int(rng.integers(-2, 3)), int(rng.integers(2, 5))
        tex = _TEXTURES[cls]
        for fi, dt in enumerate((-1, 0, 1)):
            yy = np.clip(y + vy * dt * 3, 0, H - SPRITE)
            xx = np.clip(x + vx * dt * 3, 0, W - SPRITE)
            frames[fi, yy:yy + SPRITE, xx:xx + SPRITE] = tex
        classes.append(cls)
        boxes.append((y, x))
    frames = frames + rng.normal(0, 2.0, frames.shape)
    frames = np.clip(frames, 0, 255).astype(np.uint8)
    return frames, FrameTruth(classes, boxes)


def object_crop(cls: int, rng: np.random.Generator, size: int = 32
                ) -> np.ndarray:
    """A labeled 'detected object' image (training data for CQ models)."""
    canvas = rng.integers(60, 180, (size, size, 3)).astype(np.float64)
    tex = _TEXTURES[cls].astype(np.float64)
    off = (size - SPRITE) // 2 + rng.integers(-4, 5)
    off = int(np.clip(off, 0, size - SPRITE))
    canvas[off:off + SPRITE, off:off + SPRITE] = tex
    canvas += rng.normal(0, 8, canvas.shape)
    return np.clip(canvas, 0, 255).astype(np.uint8)


# --- crop -> token sequence for the transformer classifiers -------------------

_PATCH = 8


def crops_to_tokens(crops: np.ndarray, vocab_size: int,
                    seed: int = 7) -> np.ndarray:
    """(N, S, S, 3) uint8 -> (N, T) int32 patch tokens.

    Patches are quantized with a fixed random projection + sign hash (an
    LSH codebook): deterministic, collision-sparse, and learnable.
    """
    N, S, _, _ = crops.shape
    t = S // _PATCH
    x = crops.reshape(N, t, _PATCH, t, _PATCH, 3).transpose(0, 1, 3, 2, 4, 5)
    x = x.reshape(N, t * t, _PATCH * _PATCH * 3).astype(np.float64)
    x = (x - x.mean(-1, keepdims=True)) / (x.std(-1, keepdims=True) + 1e-6)
    rng = np.random.default_rng(seed)
    nbits = max(int(np.floor(np.log2(max(vocab_size - 1, 2)))), 1)
    proj = rng.normal(size=(_PATCH * _PATCH * 3, nbits))
    bits = (x @ proj) > 0
    tokens = bits @ (1 << np.arange(nbits))
    return np.minimum(tokens, vocab_size - 1).astype(np.int32)


def labeled_crop_batch(classes: Sequence[int], rng: np.random.Generator,
                       vocab_size: int, size: int = 32
                       ) -> Tuple[np.ndarray, np.ndarray]:
    crops = np.stack([object_crop(c, rng, size) for c in classes])
    return crops_to_tokens(crops, vocab_size), np.asarray(classes, np.int32)


def crop_embedding(crop: np.ndarray, dim: int) -> np.ndarray:
    """Cheap appearance embedding for one detection crop: 4x4 average-
    pooled RGB, mean-centered, L2-normalized, truncated/zero-padded to
    ``dim``.  Deterministic in the pixels, so the pixel frontend's re-ID
    embeddings are reproducible without a model in the loop; crops of the
    same class texture land close in cosine, different textures far."""
    S = crop.shape[0]
    p = crop.reshape(4, S // 4, 4, S // 4, 3).mean(axis=(1, 3)).reshape(-1)
    p = p - p.mean()
    v = np.zeros(dim, np.float32)
    n = min(dim, p.size)
    v[:n] = p[:n]
    nrm = float(np.linalg.norm(v))
    return v / nrm if nrm > 0 else v
