"""Deterministic, host-sharded token data pipeline for the production mesh.

Each host materializes only its shard of the global batch (standard
multi-host JAX input pipeline): the global batch of B sequences is split
over the ("pod","data") axes; `global_shard` builds the per-host numpy
block and `device_put`s it with the global sharding so pjit sees one
logical array.  Synthetic-but-learnable streams (affine next-token rule +
noise) keep loss curves meaningful without external data.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class LoaderConfig:
    global_batch: int
    seq_len: int
    seed: int = 0
    noise: float = 0.3          # fraction of random (non-rule) next tokens


def _rule_stream(rng: np.random.Generator, n: int, s: int,
                 vocab: int, noise: float):
    base = rng.integers(0, vocab, size=(n, s + 1), dtype=np.int64)
    shifted = (base[:, :-1] * 31 + 17) % vocab
    mask = rng.random((n, s)) < noise
    tokens = base[:, :-1].astype(np.int32)
    labels = np.where(mask, base[:, 1:], shifted).astype(np.int32)
    return tokens, labels


def host_batches(cfg: ModelConfig, lc: LoaderConfig, *,
                 host_id: int = 0, num_hosts: int = 1
                 ) -> Iterator[Dict[str, np.ndarray]]:
    """Per-host shard of the global batch, deterministic in (step, host)."""
    assert lc.global_batch % num_hosts == 0
    per_host = lc.global_batch // num_hosts
    s_text = lc.seq_len - (cfg.num_img_tokens or 0)
    step = 0
    while True:
        rng = np.random.default_rng(
            (lc.seed * 1_000_003 + step) * 4096 + host_id)
        tokens, labels = _rule_stream(rng, per_host, s_text,
                                      cfg.vocab_size, lc.noise)
        batch: Dict[str, np.ndarray] = {"tokens": tokens, "labels": labels}
        if cfg.num_img_tokens:
            batch["img_embeds"] = rng.normal(
                0, 0.1, (per_host, cfg.num_img_tokens, 1024)).astype(np.float32)
        if cfg.is_encdec:
            batch["audio_frames"] = rng.normal(
                0, 0.1, (per_host, cfg.enc_seq, cfg.d_model)).astype(np.float32)
        yield batch
        step += 1


def global_shard(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    """device_put each host shard with its global NamedSharding.

    On a single host this is a plain device_put; on multi-host it uses
    ``jax.make_array_from_process_local_data`` so every process contributes
    its slice of the global array.
    """
    out = {}
    for k, v in batch.items():
        sh = shardings[k] if isinstance(shardings, dict) else shardings
        if jax.process_count() > 1:
            out[k] = jax.make_array_from_process_local_data(sh, v)
        else:
            out[k] = jax.device_put(v, sh)
    return out
