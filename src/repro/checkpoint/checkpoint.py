"""Sharding-aware checkpointing (npz-based, no external deps).

Saves a param/opt tree as flat npz entries keyed by tree path; restore
re-builds the tree and (optionally) device_put's each leaf with the sharding
tree.  Works for TrainState and raw param trees.
"""
from __future__ import annotations

import os
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    return {jax.tree_util.keystr(path): leaf for path, leaf in leaves}, treedef


def save(path: str, tree: Any, step: Optional[int] = None) -> None:
    flat, _ = _flatten(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    if step is not None:
        arrays["__step__"] = np.asarray(step)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = path + ".tmp.npz"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def restore(path: str, like: Any, shardings: Any = None) -> Any:
    """Rebuild a tree shaped like ``like`` from ``path``.

    ``shardings``: optional matching tree of NamedSharding for device_put.
    """
    with np.load(path) as data:
        flat, treedef = _flatten(like)
        leaves = []
        for key, ref in flat.items():
            arr = data[key]
            assert arr.shape == tuple(ref.shape), (key, arr.shape, ref.shape)
            leaves.append(arr.astype(ref.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, shardings)
    else:
        tree = jax.tree.map(jnp.asarray, tree)
    return tree


def latest_step(path: str) -> Optional[int]:
    if not os.path.exists(path):
        return None
    with np.load(path) as data:
        if "__step__" in data:
            return int(data["__step__"])
    return None
