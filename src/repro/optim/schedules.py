"""Learning-rate schedules (multipliers in [0,1]; compose with AdamWConfig.lr)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(warmup_steps: int, total_steps: int, floor: float = 0.1):
    def sched(step):
        s = step.astype(jnp.float32)
        warm = s / max(warmup_steps, 1)
        prog = jnp.clip((s - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return sched


def linear_warmup(warmup_steps: int):
    def sched(step):
        return jnp.minimum(step.astype(jnp.float32) / max(warmup_steps, 1), 1.0)
    return sched
