"""AdamW with decoupled weight decay and global-norm clipping (from scratch).

State layout mirrors the param tree (``m``/``v`` are f32 regardless of param
dtype) so the sharding rules for params apply verbatim to optimizer state —
this is what lets the FSDP axis shard Adam moments on the production mesh.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    count: jax.Array           # int32 ()
    m: Any                     # f32 tree
    v: Any                     # f32 tree


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    schedule: Optional[Callable[[jax.Array], jax.Array]] = None

    def lr_at(self, step: jax.Array) -> jax.Array:
        if self.schedule is None:
            return jnp.asarray(self.lr, jnp.float32)
        return self.lr * self.schedule(step)


def init(params: Any) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(count=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def abstract_state(params: Any) -> AdamWState:
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
    return AdamWState(count=jax.ShapeDtypeStruct((), jnp.int32), m=z, v=z)


def global_norm(tree: Any) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(cfg: AdamWConfig, grads: Any, state: AdamWState, params: Any):
    """Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    count = state.count + 1
    cf = count.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** cf
    bc2 = 1.0 - cfg.b2 ** cf
    lr = cfg.lr_at(count)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        newp = p.astype(jnp.float32) - lr * (step + decay)
        return newp.astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, AdamWState(count, new_m, new_v), metrics
