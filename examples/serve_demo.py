"""Live query submission against the real-time driver.

The same engine that runs the DES benchmarks here runs as a *serving
process*: an ``AsyncDriver`` pumps the event heap from asyncio, and a
client submits continuous queries mid-run through ``QueryAPI`` — each
submission rides the full control plane (per-tenant token-bucket quota,
fine-tune-backlog shedding by priority tier, Fig. 5 cloud fine-tune,
per-edge weight shipment) before the fleet starts answering it.

Two clocks:

  * default (virtual): deterministic, finishes instantly — the mode the
    differential tests pin bit-identical to the DES ``SimDriver``, and
    what CI smokes.
  * ``--wall --speed N``: real time, N simulated seconds per wall second
    — watch the rush hour actually unfold (~duration/N wall seconds).

  PYTHONPATH=src python examples/serve_demo.py
  PYTHONPATH=src python examples/serve_demo.py --wall --speed 100
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.serving.api import QueryAPI                      # noqa: E402
from repro.serving.engine import (                          # noqa: E402
    AsyncDriver,
    VirtualClock,
    WallClock,
)
from repro.system import QueryPipeline, QuerySpec, rush_hour  # noqa: E402
from repro.system.scenario import synthetic_confidence_stream  # noqa: E402


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--cameras", type=int, default=4)
    ap.add_argument("--duration", type=float, default=40.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--wall", action="store_true",
                    help="run on the wall clock instead of virtual time")
    ap.add_argument("--speed", type=float, default=100.0,
                    help="simulated seconds per wall second (with --wall)")
    args = ap.parse_args()

    sc = rush_hour(num_cameras=args.cameras, duration_s=args.duration,
                   seed=args.seed)
    clock = WallClock(args.speed) if args.wall else VirtualClock()
    driver = AsyncDriver(clock)
    pipe = QueryPipeline(sc, driver=driver)
    api = QueryAPI(pipe)

    # live submissions on top of the scenario's declared query book: a
    # priority customer onboarding mid-rush (tier 0: backlog-exempt, so
    # it trains even while the flood queues) and one more best-effort
    # straggler (tier 2: sheds against the by-then-deep backlog)
    d = args.duration
    live = [
        QuerySpec(100, t_arrive_s=d * 0.35, train_scheme="surveiledge",
                  tenant="metro-pd", tier=0),
        QuerySpec(101, t_arrive_s=d * 0.45, train_scheme="surveiledge",
                  tenant="hobby", tier=2),
    ]
    for sp in live:
        driver.call_at(sp.t_arrive_s, lambda t, sp=sp: api.submit(t, sp))

    report = pipe.run(synthetic_confidence_stream(sc))

    print(f"== serve_demo [{'wall' if args.wall else 'virtual'} clock] — "
          f"{driver.events_pumped} events pumped, "
          f"{driver.hooks_run} live submissions ==")
    for sp in live:
        print(f"  live query {sp.query} (tenant={sp.tenant}, "
              f"tier={sp.tier}): {api.status(sp.query)}")
    s = report.summary()
    print(f"  submitted={s['submitted_queries']} "
          f"shed={s['shed_queries']} shed_rate={s['shed_rate']}")
    print(f"  alerts: {report.alerts}")
    for k, row in sorted(report.tier_latency.items()):
        print(f"  tier {k}: n={row['n']} "
              f"p99={row['p99_latency_s']:.3f}s "
              f"slo={row['slo_s']:.1f}s breaches={row['slo_breaches']}")
    # the acceptance property the rush_hour preset is built around
    top = min(report.tier_latency)
    if report.tier_latency[top]["slo_breaches"] > 0:
        sys.exit("FAIL: top-priority tier breached its SLO")
    if s["shed_queries"] == 0:
        sys.exit("FAIL: rush hour shed nothing — admission never engaged")
    print("OK: top tier held its SLO while lower tiers shed")


if __name__ == "__main__":
    main()
