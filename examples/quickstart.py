"""Quickstart: the SurveilEdge cascade in five minutes (CPU-friendly).

Builds a (edge CQ-specific, cloud high-accuracy) pair from one assigned
architecture, runs the confidence-thresholded cascade over a batch of
synthetic detections, and prints the triage/bandwidth stats.

  PYTHONPATH=src python examples/quickstart.py --arch qwen1.5-0.5b
"""
import argparse
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, "src")

from repro.configs import get_config
from repro.core import cascade as C
from repro.core.thresholds import ThresholdState
from repro.data import synthetic_video as SV
from repro.models import meta, transformer as T


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="surveiledge-cls")
    ap.add_argument("--batch", type=int, default=64)
    args = ap.parse_args()

    full = get_config(args.arch)
    edge_cfg = full.edge_variant()          # 2-layer CQ-specific model
    cloud_cfg = full.reduced()              # stand-in for the big model on CPU
    print(f"arch={full.name}  edge={edge_cfg.d_model}d x {edge_cfg.num_layers}L  "
          f"cloud={cloud_cfg.d_model}d x {cloud_cfg.num_layers}L")

    key = jax.random.PRNGKey(0)
    edge_params = meta.init_params(edge_cfg, key)
    cloud_params = meta.init_params(cloud_cfg, jax.random.PRNGKey(1))

    # synthetic detected-object crops -> patch tokens
    rng = np.random.default_rng(0)
    classes = rng.integers(0, SV.NUM_CLASSES, size=args.batch)
    tokens, _ = SV.labeled_crop_batch(classes, rng, edge_cfg.vocab_size)
    tokens = jnp.asarray(tokens)

    @jax.jit
    def edge_conf(tokens):
        h, _ = T.forward(edge_cfg, edge_params, tokens)
        return C.confidence_from_logits(T.classify(edge_cfg, edge_params, h))

    @jax.jit
    def cloud_conf(tokens):
        h, _ = T.forward(cloud_cfg, cloud_params, tokens)
        return C.confidence_from_logits(T.classify(cloud_cfg, cloud_params, h))

    th = ThresholdState(alpha=0.8, beta=0.1)
    conf = edge_conf(tokens)
    out = C.cascade_batch(conf, cloud_conf, tokens,
                          alpha=jnp.float32(th.alpha),
                          beta=jnp.float32(th.beta),
                          capacity=args.batch)
    routes = np.asarray(out["routes"])
    print(f"edge accepts : {(routes == C.ACCEPT).sum()}")
    print(f"edge rejects : {(routes == C.REJECT).sum()}")
    print(f"escalated    : {int(out['n_escalated'])} "
          f"({float(out['escalated_frac']):.1%} of the batch -> cloud)")
    print(f"bandwidth    : {int(out['n_escalated']) * 3 * 128 * 128 / 1e6:.2f} MB "
          f"(vs {args.batch * 3 * 128 * 128 / 1e6:.2f} MB cloud-only)")


if __name__ == "__main__":
    main()
