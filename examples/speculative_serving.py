"""Cascade speculative decoding demo (beyond-paper, see core/speculative.py).

SurveilEdge's confidence cascade, applied per token: the edge CQ-style draft
model proposes k tokens, the cloud model verifies them in batch and accepts
the agreeing prefix — output is provably identical to cloud-only greedy
decoding, but the cloud steps ~tokens_per_cloud_step times less often.

  PYTHONPATH=src python examples/speculative_serving.py --steps 16 --k 4
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import speculative as SP
from repro.models import meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=12)
    args = ap.parse_args()

    cloud_cfg = get_config(args.arch).reduced()
    edge_cfg = get_config(args.arch).edge_variant()
    cloud = meta.init_params(cloud_cfg, jax.random.PRNGKey(0))
    edge = meta.init_params(edge_cfg, jax.random.PRNGKey(1))
    prompt = jax.random.randint(jax.random.PRNGKey(2), (1, args.prompt_len),
                                0, cloud_cfg.vocab_size)

    t0 = time.perf_counter()
    want = SP.cloud_greedy_generate(cloud_cfg, cloud, prompt, args.steps)
    t_ref = time.perf_counter() - t0

    t0 = time.perf_counter()
    got, stats = SP.speculative_generate(edge_cfg, edge, cloud_cfg, cloud,
                                         prompt, steps=args.steps, k=args.k)
    t_spec = time.perf_counter() - t0

    identical = bool(np.array_equal(np.asarray(got), np.asarray(want)))
    print(f"output identical to cloud-greedy: {identical}")
    print(f"draft acceptance rate : {stats.acceptance_rate:.1%}")
    print(f"tokens per cloud round: {stats.tokens_per_cloud_step:.2f}")
    print(f"(host wall-times here include re-prefill bookkeeping; the "
          f"roofline win is the {stats.tokens_per_cloud_step:.1f}x fewer "
          f"cloud decode rounds)")


if __name__ == "__main__":
    main()
