"""End-to-end driver (deliverable b): serve a small model with batched
requests through the full SurveilEdge system.

Pipeline: synthetic cameras -> frame-difference detection (Pallas kernels)
-> camera profiling + K-means clustering -> CQ-specific fine-tuning ->
cloud-edge cascade serving with the intelligent task allocator -> metrics.

  PYTHONPATH=src python examples/serve_cascade.py --duration 120
"""
import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.serving.simulator import CloudEdgeSim, LinkSpec, NodeSpec
from repro.serving.workload import build_workload


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--duration", type=float, default=120.0)
    ap.add_argument("--cameras", type=int, default=8)
    ap.add_argument("--edges", type=int, default=3)
    ap.add_argument("--uplink-MBps", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    print("building workload (offline stage: profiles -> clusters -> "
          "CQ fine-tune; online stage: detection + scoring)...")
    wl = build_workload(num_cameras=args.cameras, num_edges=args.edges,
                        duration_s=args.duration, seed=args.seed)
    print(f"  camera clusters : {wl.clusters.tolist()}")
    print(f"  edge model acc  : {wl.edge_accuracy:.3f}")
    print(f"  detections      : {len(wl.items)}")

    edges = [NodeSpec(i + 1, service_s=0.30) for i in range(args.edges)]
    cloud = NodeSpec(0, service_s=0.05)
    link = LinkSpec(uplink_MBps=args.uplink_MBps, rtt_s=0.1)

    print(f"\n{'scheme':20s}{'F2':>8s}{'avg lat':>10s}{'p99':>9s}"
          f"{'var':>9s}{'MB up':>8s}")
    for scheme in ("surveiledge", "surveiledge_fixed", "edge_only",
                   "cloud_only"):
        sim = CloudEdgeSim(edges, cloud, link, scheme=scheme, seed=1)
        r = sim.run(wl.items)
        print(f"{scheme:20s}{r.f_score():8.3f}{r.avg_latency:10.3f}"
              f"{r.p99_latency:9.2f}{r.latency_var:9.2f}"
              f"{r.uploaded_bytes / 1e6:8.2f}")
    print("\nSurveilEdge should show: near-cloud accuracy, lowest latency, "
          "bandwidth well below cloud-only.")


if __name__ == "__main__":
    main()
