"""CQ-specific fine-tuning walkthrough (paper §IV-A/B, Fig. 5).

Shows the offline + online training stages in isolation: build camera
profiles, cluster them, select a context-specific training set (negatives
proportional to the cluster profile), fine-tune the edge model for a
user-defined query, and compare the three training schemes.

  PYTHONPATH=src python examples/finetune_cq.py --query-class 3
"""
import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import get_config
from repro.core import finetune as FT
from repro.core import profiles as PR
from repro.data import synthetic_video as SV
from repro.models import meta as M
from repro.serving.workload import _binary_batches


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--query-class", type=int, default=SV.QUERY_CLASS)
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    # --- offline: profiles + clustering -----------------------------------
    cams = SV.make_cameras(8, seed=0)
    rng = np.random.default_rng(0)
    leisure = {c.cam_id: rng.choice(SV.NUM_CLASSES, size=400, p=c.class_mix)
               for c in cams}
    cam_ids, profs = PR.build_profiles(leisure, SV.NUM_CLASSES)
    assign, centers = PR.cluster_cameras(profs, k=2)
    print("camera -> cluster:", dict(zip(cam_ids, assign.tolist())))

    # --- online: context-specific training set + fine-tune ------------------
    full = get_config("surveiledge-cls")
    cfg = dataclasses.replace(full.edge_variant(), num_query_classes=2,
                              vocab_size=full.vocab_size)
    cluster = int(np.argmax(np.bincount(assign)))
    profile = centers[cluster]

    labels_pool = rng.choice(SV.NUM_CLASSES, size=2000, p=profile / profile.sum())
    idx = PR.select_training_set(labels_pool, profile, args.query_class,
                                 n_positive=200, n_negative=400, rng=rng)
    print(f"selected {len(idx)} training samples "
          f"({(labels_pool[idx] == args.query_class).mean():.0%} positive)")

    pre = M.init_params(cfg, jax.random.PRNGKey(0))
    ev = next(_binary_batches(np.random.default_rng(9), cfg, profile, None,
                              args.query_class, batch=256))
    res = FT.finetune(cfg, pre,
                      _binary_batches(rng, cfg, profile, None,
                                      args.query_class),
                      steps=args.steps, lr=1e-3, eval_set=ev)
    print(f"fine-tuned {res.steps} steps in {res.train_seconds:.1f}s "
          f"-> accuracy {res.accuracy:.3f} (loss {res.final_loss:.3f})")

    head = FT.finetune(cfg, pre,
                       _binary_batches(np.random.default_rng(1), cfg, profile,
                                       None, args.query_class),
                       steps=args.steps, lr=1e-3, head_only=True, eval_set=ev)
    print(f"head-only probe: accuracy {head.accuracy:.3f} "
          f"in {head.train_seconds:.1f}s")


if __name__ == "__main__":
    main()
