"""Run the end-to-end query pipeline over every registered scenario.

Each scenario is simulated under all four query schemes with a model-free
synthetic detection stream (fast; no training in the loop).  For the full
CQ-model-scored workload, see ``benchmarks/table2_single_edge.py`` etc.

  PYTHONPATH=src python examples/run_scenarios.py
  PYTHONPATH=src python examples/run_scenarios.py --scenario bursty_crowds
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.system import SCENARIOS, SCHEMES, run_query, \
    synthetic_confidence_stream  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="run just one scenario (default: all)")
    ap.add_argument("--cameras", type=int, default=6)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scenario:
        names = [args.scenario]
    else:
        # city_scale pins 64 edges / 512 cameras regardless of --cameras;
        # the default sweep stays small-fleet (run it explicitly, as
        # `make bench-smoke` does)
        names = [n for n in sorted(SCENARIOS) if n != "city_scale"]
    for name in names:
        sc = SCENARIOS[name](num_cameras=args.cameras,
                             duration_s=args.duration, seed=args.seed)
        stream = synthetic_confidence_stream(sc)
        print(f"\n== {name} — {len(stream)} detections, "
              f"{sc.num_edges} edge(s) + cloud ==")
        print(f"{'scheme':20s}{'F2':>8s}{'avg_lat':>9s}{'p99':>9s}"
              f"{'WAN_MB':>8s}{'LAN_MB':>8s}{'escal':>7s}{'rerouted':>9s}"
              f"{'launches':>9s}{'l/tick':>7s}")
        for scheme in SCHEMES:
            r = run_query(sc.with_scheme(scheme), items=stream)
            s = r.summary()
            print(f"{scheme:20s}{s['accuracy_F2']:8.3f}"
                  f"{s['avg_latency_s']:9.3f}{s['p99_latency_s']:9.3f}"
                  f"{s['bandwidth_MB']:8.2f}{s['lan_MB']:8.2f}"
                  f"{s['escalated']:7d}{s['rerouted']:9d}"
                  f"{s['kernel_launches']:9d}"
                  f"{s['launches_per_tick']:7.2f}")


if __name__ == "__main__":
    main()
