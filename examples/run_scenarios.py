"""Run the end-to-end query pipeline over every registered scenario.

Each scenario is simulated under all four query schemes.  The default
frontend is the model-free synthetic confidence stream (fast; no model in
the loop); ``--frontend pixel`` runs the paper's full pixel path instead
(rendered frames -> Pallas framediff/morphology -> motion crops -> CQ
scores).  For the CQ-model-scored workload, see
``benchmarks/table2_single_edge.py`` etc.

``--json-out DIR`` writes one ``<scenario>-<frontend>.json`` report per
scenario (the CI smoke job uploads these as build artifacts) and fails the
run if any metric comes back NaN or the pipeline answered zero items — a
smoke artifact full of NaNs must fail loudly, not upload quietly.

  PYTHONPATH=src python examples/run_scenarios.py
  PYTHONPATH=src python examples/run_scenarios.py --scenario bursty_crowds
  PYTHONPATH=src python examples/run_scenarios.py \
      --scenario pixel_city --frontend pixel --json-out reports
"""
import argparse
import json
import math
import os
import sys

sys.path.insert(0, "src")

from repro.system import (  # noqa: E402
    SCENARIOS,
    SCHEMES,
    PixelFrontend,
    run_query,
    synthetic_confidence_stream,
)


def validate(name: str, scheme: str, report) -> None:
    """Empty or NaN metrics make the JSON artifact meaningless: die loudly."""
    if len(report.latencies) == 0:
        sys.exit(f"FAIL {name}/{scheme}: pipeline answered zero items")
    bad = [k for k, v in report.summary().items()
           if isinstance(v, (int, float)) and not math.isfinite(v)]
    if bad:
        sys.exit(f"FAIL {name}/{scheme}: non-finite metrics {bad}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="run just one scenario (default: all)")
    ap.add_argument("--frontend", choices=("confidence", "pixel"),
                    default="confidence",
                    help="detection stream: model-free confidence synthesis "
                         "(default) or the rendered-frames pixel path")
    ap.add_argument("--json-out", metavar="DIR", default=None,
                    help="write per-scenario JSON reports to DIR and fail "
                         "on NaN/empty metrics")
    ap.add_argument("--cameras", type=int, default=6)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scenario:
        names = [args.scenario]
    else:
        # city_scale pins 64 edges / 512 cameras regardless of --cameras;
        # the default sweep stays small-fleet (run it explicitly, as
        # `make bench-smoke` does)
        names = [n for n in sorted(SCENARIOS) if n != "city_scale"]
    frontend = PixelFrontend(seed=args.seed) \
        if args.frontend == "pixel" else None
    for name in names:
        sc = SCENARIOS[name](num_cameras=args.cameras,
                             duration_s=args.duration, seed=args.seed)
        if frontend is not None:
            stream = frontend.stream(sc)     # cached across the scheme sweep
        else:
            stream = synthetic_confidence_stream(sc)
        print(f"\n== {name} [{args.frontend}] — {len(stream)} detections, "
              f"{sc.num_edges} edge(s) + cloud ==")
        print(f"{'scheme':20s}{'F2':>8s}{'avg_lat':>9s}{'p99':>9s}"
              f"{'WAN_MB':>8s}{'LAN_MB':>8s}{'escal':>7s}{'rerouted':>9s}"
              f"{'launches':>9s}{'l/tick':>7s}")
        per_scheme = {}
        for scheme in SCHEMES:
            if frontend is not None:
                r = run_query(sc.with_scheme(scheme), frontend=frontend)
            else:
                r = run_query(sc.with_scheme(scheme), items=stream)
            if args.json_out:
                validate(name, scheme, r)
            s = r.summary()
            per_scheme[scheme] = {
                **s, "n_items": len(r.latencies),
                "stage_timings": {k: round(v, 4)
                                  for k, v in r.stage_timings.items()}}
            print(f"{scheme:20s}{s['accuracy_F2']:8.3f}"
                  f"{s['avg_latency_s']:9.3f}{s['p99_latency_s']:9.3f}"
                  f"{s['bandwidth_MB']:8.2f}{s['lan_MB']:8.2f}"
                  f"{s['escalated']:7d}{s['rerouted']:9d}"
                  f"{s['kernel_launches']:9d}"
                  f"{s['launches_per_tick']:7.2f}")
        if args.json_out:
            os.makedirs(args.json_out, exist_ok=True)
            path = os.path.join(args.json_out,
                                f"{name}-{args.frontend}.json")
            with open(path, "w") as fh:
                json.dump({"scenario": name, "frontend": args.frontend,
                           "n_detections": len(stream),
                           "num_edges": sc.num_edges,
                           "schemes": per_scheme}, fh, indent=2)
            print(f"   -> {path}")


if __name__ == "__main__":
    main()
