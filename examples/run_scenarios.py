"""Run the end-to-end query pipeline over every registered scenario.

Each scenario is simulated under all four query schemes.  The default
frontend is the model-free synthetic confidence stream (fast; no model in
the loop); ``--frontend pixel`` runs the paper's full pixel path instead
(rendered frames -> Pallas framediff/morphology -> motion crops -> CQ
scores).  For the CQ-model-scored workload, see
``benchmarks/table2_single_edge.py`` etc.

``--scenario all`` runs EVERY registered preset in this one process —
each with its designated frontend and smoke-sized overrides (the
``SMOKE_OVERRIDES`` table below) — so ``make bench-smoke`` and CI pay one
interpreter/jit warmup instead of five.  Scenarios with the cloud->edge
feedback loop enabled (``update_period_s`` set) additionally run the
open-loop ablation (``update_period_s=None``) as a fifth
``surveiledge_no_update`` row; scenarios with the bandwidth endgame on
(``quantize_downlink`` / ``speculative_escalation``) add a
``surveiledge_fp_wire`` ablation (full-width fp downlink, blocking
escalation) so the quantized reduction and the speculative latency win
are differential within one report; multi-query scenarios add per-query rows
(``queries``) to the JSON so the Fig. 5 training-scheme trade is visible
per query.

``--json-out DIR`` writes one ``<scenario>-<frontend>.json`` report per
scenario (CI diffs these against the committed ``reports/`` baselines via
``benchmarks/report_gate.py``) and fails the run if any metric comes back
NaN, the pipeline answered zero items, or a row is internally
inconsistent (``model_updates > 0`` with zero downlink bytes means the
loop "ran" without shipping anything — a broken report must fail loudly,
not upload quietly).  ``load_report`` applies the same consistency gate
when reading an artifact back.

  PYTHONPATH=src python examples/run_scenarios.py
  PYTHONPATH=src python examples/run_scenarios.py --scenario all --json-out reports
  PYTHONPATH=src python examples/run_scenarios.py --scenario drifting_city
  PYTHONPATH=src python examples/run_scenarios.py \
      --scenario pixel_city --frontend pixel --json-out reports
"""
import argparse
import dataclasses
import json
import math
import os
import sys

sys.path.insert(0, "src")

from repro.system import (  # noqa: E402
    SCENARIOS,
    SCHEMES,
    PixelFrontend,
    run_query,
    synthetic_confidence_stream,
)

# ``--scenario all``: every preset in one process, each at its smoke-sized
# operating point (keys override the CLI defaults; ``frontend`` picks the
# pixel path where the scenario exists to exercise it).  These are also
# exactly the settings the committed ``reports/`` baselines are built
# from, so the report gate compares like with like.
SMOKE_OVERRIDES = {
    "city_scale": dict(duration=20.0),
    # metropolis pins >= 1024 edges; smoke shrinks cameras/duration only.
    # Its report rows stream (Scenario.metrics_window_s), so n_items comes
    # from QueryReport.n_items — the per-item arrays are intentionally empty.
    "metropolis": dict(cameras=1024, duration=12.0),
    "drifting_city": dict(cameras=8, duration=60.0),
    "multi_query_city": dict(cameras=8, duration=60.0),
    "query_churn": dict(cameras=8, duration=60.0),
    "pixel_city": dict(frontend="pixel", duration=10.0),
    "rush_hour": dict(cameras=4, duration=40.0),
    # track presets pin their own camera/edge geometry (the CLI default of
    # 6 cameras would break the alternating-edge chain the hand-off rides)
    "vehicle_pursuit": dict(cameras=12, duration=60.0),
    "crowd_flow": dict(cameras=8, duration=45.0),
}


def check_consistency(name: str, scheme: str, summary: dict) -> None:
    """Raise ``ValueError`` on internally inconsistent report rows.

    Shared by the writer (``validate``) and the reader (``load_report``):
    a run that claims fused recalibration launches but shipped zero bytes
    down the WAN downlink cannot have closed the loop.  Gates on the RAW
    byte counter — MB rounding would wave through (or falsely damn) tiny
    ``update_nbytes`` payloads."""
    bytes_down = summary.get("downloaded_bytes",
                             summary.get("downloaded_MB", 0.0))
    if summary.get("model_updates", 0) > 0 and bytes_down == 0:
        raise ValueError(
            f"{name}/{scheme}: model_updates="
            f"{summary['model_updates']} but zero downlink bytes — model "
            f"updates that never crossed the downlink")
    # quantized-payload case: the charged wire bytes can never exceed the
    # fp-equivalent cost of the same shipments — quantized > fp means the
    # wire accounting double-charged (or the codec inflated the payload)
    fp_down = summary.get("downlink_fp_bytes")
    if fp_down is not None and bytes_down > fp_down:
        raise ValueError(
            f"{name}/{scheme}: downloaded_bytes={bytes_down} exceeds the "
            f"fp-equivalent reference downlink_fp_bytes={fp_down} — "
            f"quantized shipping cannot cost more than full-width fp")
    # admission sheds publish alerts/admission/<reason> events: a row
    # claiming shed queries with a silent alert stream means the control
    # plane dropped work without telling anyone — an unobservable shed is
    # an outage, not a policy
    if summary.get("shed_queries", 0) > 0 \
            and summary.get("alerts_total", 0) == 0:
        raise ValueError(
            f"{name}/{scheme}: shed_queries={summary['shed_queries']} but "
            f"alerts_total=0 — admission shed queries without publishing "
            f"alert events")


def validate(name: str, scheme: str, report) -> None:
    """Empty or NaN metrics make the JSON artifact meaningless: die loudly."""
    if report.n_items == 0:
        sys.exit(f"FAIL {name}/{scheme}: pipeline answered zero items")
    s = report.summary()
    bad = [k for k, v in s.items()
           if isinstance(v, (int, float)) and not math.isfinite(v)]
    if bad:
        sys.exit(f"FAIL {name}/{scheme}: non-finite metrics {bad}")
    try:
        check_consistency(name, scheme, s)
    except ValueError as e:
        sys.exit(f"FAIL {e}")


def load_report(path: str) -> dict:
    """Read a scenario JSON artifact back, re-checking row consistency.

    Raises ``ValueError`` for inconsistent rows (e.g. ``model_updates > 0``
    with zero downlink bytes), so downstream consumers never aggregate a
    physically impossible run."""
    with open(path) as fh:
        doc = json.load(fh)
    for scheme, row in doc.get("schemes", {}).items():
        check_consistency(doc.get("scenario", path), scheme, row)
    return doc


def compact_query_row(row: dict) -> dict:
    """Per-query JSON row with the per-edge payloads summarized to counts.

    ``per_query_summary`` rows carry each query's full ``live_edges`` list
    and per-edge ``thresholds`` dict — at metropolis scale (1024 edges x
    24 queries x 4 scheme rows) that is megabytes of JSON per report.  The
    gate (``benchmarks/report_gate.py``) compares only the scalar metrics,
    so the artifact keeps the counts and drops the per-edge bodies."""
    out = {k: v for k, v in row.items()
           if k not in ("live_edges", "thresholds")}
    if "live_edges" in row:
        out["n_live_edges"] = len(row["live_edges"])
    if "thresholds" in row:
        out["n_threshold_rows"] = len(row["thresholds"])
    return out


def run_scenario(name: str, frontend_name: str, cameras: int,
                 duration: float, seed: int, json_out: str = None) -> None:
    """Simulate one scenario under every scheme (+ ablation rows); print
    the table and optionally write/validate its JSON artifact."""
    sc = SCENARIOS[name](num_cameras=cameras, duration_s=duration, seed=seed)
    frontend = PixelFrontend(seed=seed) if frontend_name == "pixel" else None
    if frontend is not None:
        stream = frontend.stream(sc)         # cached across the scheme sweep
    else:
        stream = synthetic_confidence_stream(sc)
    print(f"\n== {name} [{frontend_name}] — {len(stream)} detections, "
          f"{sc.num_edges} edge(s) + cloud, {len(sc.query_ids)} "
          f"quer{'y' if len(sc.query_ids) == 1 else 'ies'} ==")
    print(f"{'scheme':22s}{'F2':>8s}{'avg_lat':>9s}{'p99':>9s}"
          f"{'WAN_MB':>8s}{'LAN_MB':>8s}{'DL_MB':>7s}{'upd':>5s}"
          f"{'escal':>7s}{'flip':>7s}{'rerouted':>9s}{'launches':>9s}"
          f"{'l/tick':>7s}")
    # the feedback loop's ablation rides along as a fifth row wherever
    # the loop is enabled: same stream, update_period_s=None
    variants = [(s, sc.with_scheme(s)) for s in SCHEMES]
    if sc.update_period_s is not None:
        variants.append(("surveiledge_no_update", dataclasses.replace(
            sc.with_scheme("surveiledge"), update_period_s=None)))
    # the bandwidth-endgame ablation rides along wherever either knob is
    # on: same stream, full-width fp downlink + blocking escalation.  The
    # committed row pair is what lets the report gate check the quantized
    # downlink reduction and the speculative latency win differentially.
    if sc.quantize_downlink or sc.speculative_escalation:
        variants.append(("surveiledge_fp_wire", dataclasses.replace(
            sc.with_scheme("surveiledge"), quantize_downlink=False,
            speculative_escalation=False)))
    # the cross-camera track ablation rides along wherever predictive
    # hand-off is on: same stream, hand-off disabled.  The committed row
    # pair is what lets the report gate check the ID-switch win
    # differentially (no_handoff must switch identities MORE).
    if sc.track_query_ids and sc.predictive_handoff:
        variants.append(("surveiledge_no_handoff", dataclasses.replace(
            sc.with_scheme("surveiledge"), predictive_handoff=False)))
    per_scheme = {}
    for label, variant in variants:
        if frontend is not None:
            r = run_query(variant, frontend=frontend)
        else:
            r = run_query(variant, items=stream)
        if json_out:
            validate(name, label, r)
        s = r.summary()
        per_scheme[label] = {
            **s, "n_items": r.n_items,
            "accuracy_timeline": r.accuracy_timeline(),
            "stage_timings": {k: round(v, 4)
                              for k, v in r.stage_timings.items()}}
        if r.queries:
            # per-query rows: the runtime Fig. 5 trade (train_s vs f2 vs
            # head-of-query latency), one dict per live query
            per_scheme[label]["queries"] = {
                str(q): compact_query_row(row)
                for q, row in r.per_query_summary().items()}
        print(f"{label:22s}{s['accuracy_F2']:8.3f}"
              f"{s['avg_latency_s']:9.3f}{s['p99_latency_s']:9.3f}"
              f"{s['bandwidth_MB']:8.2f}{s['lan_MB']:8.2f}"
              f"{s['downloaded_MB']:7.2f}{s['model_updates']:5d}"
              f"{s['escalated']:7d}{s['reconciliation_flip_rate']:7.3f}"
              f"{s['rerouted']:9d}{s['kernel_launches']:9d}"
              f"{s['launches_per_tick']:7.2f}")
        if s.get("track_items"):
            print(f"   tracks: {s['tracks_born']} born, "
                  f"continuity {s['track_continuity']:.3f} "
                  f"({s['id_switches']} switches), "
                  f"{s['track_handoffs']} handoffs, "
                  f"{s['prewarm_hits']}/{s['prewarms_shipped']} "
                  f"prewarm hits, {s['track_launches_per_tick']:.2f} "
                  f"assoc launches/tick")
        if r.queries and label == "surveiledge":
            for q, row in sorted(r.per_query_summary().items()):
                print(f"   q{q} [{row.get('train_scheme', '?'):>12s}]"
                      f"{row['f2']:8.3f}{row['avg_latency_s']:9.3f}"
                      f"  train {row.get('train_s', 0.0):6.2f}s"
                      f"  deferred {row.get('deferred', 0):4d}"
                      f"  n {row['n_items']}")
    if json_out:
        os.makedirs(json_out, exist_ok=True)
        path = os.path.join(json_out, f"{name}-{frontend_name}.json")
        with open(path, "w") as fh:
            json.dump({"scenario": name, "frontend": frontend_name,
                       "n_detections": len(stream),
                       "num_edges": sc.num_edges,
                       "schemes": per_scheme}, fh, indent=2)
        load_report(path)            # round-trip the consistency gate
        print(f"   -> {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=sorted(SCENARIOS) + ["all"],
                    default=None,
                    help="run just one scenario, or 'all' for every preset "
                         "in one process with per-scenario smoke overrides "
                         "(default: the small-fleet sweep)")
    ap.add_argument("--frontend", choices=("confidence", "pixel"),
                    default="confidence",
                    help="detection stream: model-free confidence synthesis "
                         "(default) or the rendered-frames pixel path")
    ap.add_argument("--json-out", metavar="DIR", default=None,
                    help="write per-scenario JSON reports to DIR and fail "
                         "on NaN/empty/inconsistent metrics")
    ap.add_argument("--cameras", type=int, default=6)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scenario == "all":
        # every preset, one process: per-scenario frontend + smoke-sized
        # overrides from SMOKE_OVERRIDES, CLI values as the fallback
        for name in sorted(SCENARIOS):
            ov = SMOKE_OVERRIDES.get(name, {})
            run_scenario(name,
                         ov.get("frontend", args.frontend),
                         ov.get("cameras", args.cameras),
                         ov.get("duration", args.duration),
                         args.seed, args.json_out)
        return
    if args.scenario:
        names = [args.scenario]
    else:
        # city_scale pins 64 edges / 512 cameras regardless of --cameras;
        # the default sweep stays small-fleet (run it explicitly, or via
        # `--scenario all` as `make bench-smoke` does)
        names = [n for n in sorted(SCENARIOS) if n != "city_scale"]
    for name in names:
        run_scenario(name, args.frontend, args.cameras, args.duration,
                     args.seed, args.json_out)


if __name__ == "__main__":
    main()
