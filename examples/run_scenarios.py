"""Run the end-to-end query pipeline over every registered scenario.

Each scenario is simulated under all four query schemes.  The default
frontend is the model-free synthetic confidence stream (fast; no model in
the loop); ``--frontend pixel`` runs the paper's full pixel path instead
(rendered frames -> Pallas framediff/morphology -> motion crops -> CQ
scores).  For the CQ-model-scored workload, see
``benchmarks/table2_single_edge.py`` etc.

Scenarios with the cloud->edge feedback loop enabled (``update_period_s``
set, e.g. ``drifting_city``) additionally run the open-loop ablation
(``update_period_s=None``) as a fifth ``surveiledge_no_update`` row, so
one report carries the closed-vs-open comparison — including the windowed
``accuracy_timeline`` that makes post-drift recovery visible.

``--json-out DIR`` writes one ``<scenario>-<frontend>.json`` report per
scenario (the CI smoke job uploads these as build artifacts) and fails the
run if any metric comes back NaN, the pipeline answered zero items, or a
row is internally inconsistent (``model_updates > 0`` with zero downlink
bytes means the loop "ran" without shipping anything — a broken report
must fail loudly, not upload quietly).  ``load_report`` applies the same
consistency gate when reading an artifact back.

  PYTHONPATH=src python examples/run_scenarios.py
  PYTHONPATH=src python examples/run_scenarios.py --scenario drifting_city
  PYTHONPATH=src python examples/run_scenarios.py \
      --scenario pixel_city --frontend pixel --json-out reports
"""
import argparse
import dataclasses
import json
import math
import os
import sys

sys.path.insert(0, "src")

from repro.system import (  # noqa: E402
    SCENARIOS,
    SCHEMES,
    PixelFrontend,
    run_query,
    synthetic_confidence_stream,
)


def check_consistency(name: str, scheme: str, summary: dict) -> None:
    """Raise ``ValueError`` on internally inconsistent report rows.

    Shared by the writer (``validate``) and the reader (``load_report``):
    a run that claims fused recalibration launches but shipped zero bytes
    down the WAN downlink cannot have closed the loop.  Gates on the RAW
    byte counter — MB rounding would wave through (or falsely damn) tiny
    ``update_nbytes`` payloads."""
    bytes_down = summary.get("downloaded_bytes",
                             summary.get("downloaded_MB", 0.0))
    if summary.get("model_updates", 0) > 0 and bytes_down == 0:
        raise ValueError(
            f"{name}/{scheme}: model_updates="
            f"{summary['model_updates']} but zero downlink bytes — model "
            f"updates that never crossed the downlink")


def validate(name: str, scheme: str, report) -> None:
    """Empty or NaN metrics make the JSON artifact meaningless: die loudly."""
    if len(report.latencies) == 0:
        sys.exit(f"FAIL {name}/{scheme}: pipeline answered zero items")
    s = report.summary()
    bad = [k for k, v in s.items()
           if isinstance(v, (int, float)) and not math.isfinite(v)]
    if bad:
        sys.exit(f"FAIL {name}/{scheme}: non-finite metrics {bad}")
    try:
        check_consistency(name, scheme, s)
    except ValueError as e:
        sys.exit(f"FAIL {e}")


def load_report(path: str) -> dict:
    """Read a scenario JSON artifact back, re-checking row consistency.

    Raises ``ValueError`` for inconsistent rows (e.g. ``model_updates > 0``
    with zero downlink bytes), so downstream consumers never aggregate a
    physically impossible run."""
    with open(path) as fh:
        doc = json.load(fh)
    for scheme, row in doc.get("schemes", {}).items():
        check_consistency(doc.get("scenario", path), scheme, row)
    return doc


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default=None,
                    help="run just one scenario (default: all)")
    ap.add_argument("--frontend", choices=("confidence", "pixel"),
                    default="confidence",
                    help="detection stream: model-free confidence synthesis "
                         "(default) or the rendered-frames pixel path")
    ap.add_argument("--json-out", metavar="DIR", default=None,
                    help="write per-scenario JSON reports to DIR and fail "
                         "on NaN/empty/inconsistent metrics")
    ap.add_argument("--cameras", type=int, default=6)
    ap.add_argument("--duration", type=float, default=60.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.scenario:
        names = [args.scenario]
    else:
        # city_scale pins 64 edges / 512 cameras regardless of --cameras;
        # the default sweep stays small-fleet (run it explicitly, as
        # `make bench-smoke` does)
        names = [n for n in sorted(SCENARIOS) if n != "city_scale"]
    frontend = PixelFrontend(seed=args.seed) \
        if args.frontend == "pixel" else None
    for name in names:
        sc = SCENARIOS[name](num_cameras=args.cameras,
                             duration_s=args.duration, seed=args.seed)
        if frontend is not None:
            stream = frontend.stream(sc)     # cached across the scheme sweep
        else:
            stream = synthetic_confidence_stream(sc)
        print(f"\n== {name} [{args.frontend}] — {len(stream)} detections, "
              f"{sc.num_edges} edge(s) + cloud ==")
        print(f"{'scheme':22s}{'F2':>8s}{'avg_lat':>9s}{'p99':>9s}"
              f"{'WAN_MB':>8s}{'LAN_MB':>8s}{'DL_MB':>7s}{'upd':>5s}"
              f"{'escal':>7s}{'rerouted':>9s}{'launches':>9s}{'l/tick':>7s}")
        # the feedback loop's ablation rides along as a fifth row wherever
        # the loop is enabled: same stream, update_period_s=None
        variants = [(s, sc.with_scheme(s)) for s in SCHEMES]
        if sc.update_period_s is not None:
            variants.append(("surveiledge_no_update", dataclasses.replace(
                sc.with_scheme("surveiledge"), update_period_s=None)))
        per_scheme = {}
        for label, variant in variants:
            if frontend is not None:
                r = run_query(variant, frontend=frontend)
            else:
                r = run_query(variant, items=stream)
            if args.json_out:
                validate(name, label, r)
            s = r.summary()
            per_scheme[label] = {
                **s, "n_items": len(r.latencies),
                "accuracy_timeline": r.accuracy_timeline(),
                "stage_timings": {k: round(v, 4)
                                  for k, v in r.stage_timings.items()}}
            print(f"{label:22s}{s['accuracy_F2']:8.3f}"
                  f"{s['avg_latency_s']:9.3f}{s['p99_latency_s']:9.3f}"
                  f"{s['bandwidth_MB']:8.2f}{s['lan_MB']:8.2f}"
                  f"{s['downloaded_MB']:7.2f}{s['model_updates']:5d}"
                  f"{s['escalated']:7d}{s['rerouted']:9d}"
                  f"{s['kernel_launches']:9d}"
                  f"{s['launches_per_tick']:7.2f}")
        if args.json_out:
            os.makedirs(args.json_out, exist_ok=True)
            path = os.path.join(args.json_out,
                                f"{name}-{args.frontend}.json")
            with open(path, "w") as fh:
                json.dump({"scenario": name, "frontend": args.frontend,
                           "n_detections": len(stream),
                           "num_edges": sc.num_edges,
                           "schemes": per_scheme}, fh, indent=2)
            load_report(path)            # round-trip the consistency gate
            print(f"   -> {path}")


if __name__ == "__main__":
    main()
